"""Pure-jnp oracles for the Pallas kernels (the correctness ground
truth every kernel is tested against)."""

import jax.numpy as jnp


def attention_ref(q, k, v):
    """Plain softmax attention, f32 accumulation."""
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / (d**0.5)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
