"""L1: tiled flash-attention Pallas kernel (TPU-shaped, interpret mode).

The workload's compute hot-spot. GPU flash-attention tiles for shared
memory and tensor cores; the TPU adaptation (DESIGN.md
§Hardware-Adaptation) tiles for VMEM via `BlockSpec`s — one (block_q, d)
query panel resident per grid step, K/V panels streamed HBM→VMEM by the
index maps — and feeds the MXU with `jnp.dot` panels, accumulating with
the online-softmax recurrence in f32.

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered to plain HLO for both the pytest
oracle checks and the AOT artifacts the Rust runtime loads. Real-TPU
perf is estimated from the block shapes' VMEM footprint in
EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_Q = 64
DEFAULT_BLOCK_K = 64


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, sm_scale: float):
    """One (block_q, d) query panel against all K/V, online softmax."""
    q = q_ref[...].astype(jnp.float32) * sm_scale  # (bq, d)
    k = k_ref[...].astype(jnp.float32)  # (S, d)
    v = v_ref[...].astype(jnp.float32)  # (S, d)
    seq_len = k.shape[0]
    bq = q.shape[0]

    # online-softmax accumulators
    m0 = jnp.full((bq,), -jnp.inf, dtype=jnp.float32)
    l0 = jnp.zeros((bq,), dtype=jnp.float32)
    acc0 = jnp.zeros((bq, q.shape[1]), dtype=jnp.float32)

    def body(start, carry):
        m, l, acc = carry
        kb = jax.lax.dynamic_slice_in_dim(k, start * block_k, block_k)
        vb = jax.lax.dynamic_slice_in_dim(v, start * block_k, block_k)
        s = q @ kb.T  # (bq, bk) — MXU panel
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[:, None] + p @ vb
        return m_new, l_new, acc_new

    num_blocks = seq_len // block_k
    m, l, acc = jax.lax.fori_loop(0, num_blocks, body, (m0, l0, acc0))
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k"))
def flash_attention(q, k, v, block_q: int = DEFAULT_BLOCK_Q, block_k: int = DEFAULT_BLOCK_K):
    """Non-causal single-head attention over (B, S, D) tensors.

    S must be divisible by the block sizes (padded by callers otherwise).
    """
    b, s, d = q.shape
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    sm_scale = 1.0 / (d**0.5)

    kernel = functools.partial(_attn_kernel, block_k=block_k, sm_scale=sm_scale)
    return pl.pallas_call(
        kernel,
        grid=(b, s // block_q),
        in_specs=[
            # query panel: one (block_q, d) tile per grid step in VMEM
            pl.BlockSpec((None, block_q, d), lambda ib, iq: (ib, iq, 0)),
            # K/V: full sequence per batch element (streamed inside the
            # kernel block_k at a time)
            pl.BlockSpec((None, s, d), lambda ib, iq: (ib, 0, 0)),
            pl.BlockSpec((None, s, d), lambda ib, iq: (ib, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d), lambda ib, iq: (ib, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, d), q.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(q, k, v)
