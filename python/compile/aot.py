"""AOT lowering: JAX segments → HLO text artifacts for the Rust runtime.

HLO *text* (not serialized HloModuleProto) is the interchange format:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the xla crate's
XLA (xla_extension 0.5.1) rejects; the text parser reassigns ids. Each
function is lowered with `return_tuple=True`, so the Rust side unwraps a
tuple even for single outputs (see /opt/xla-example/README.md).

Run as `python -m compile.aot --out-dir ../artifacts` (the Makefile's
`artifacts` target). Also writes `manifest.json` describing shapes so
the Rust executor can size its tensor pool without parsing HLO.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .model import DIMS


def to_hlo_text(fn, *example_args) -> str:
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    d = DIMS
    act = f32(d.batch, d.seq, d.d_model)
    specs = {
        "embed_fwd": (model.embed_fwd, [i32(d.batch, d.seq), f32(d.vocab, d.d_model)]),
        "block_fwd": (
            model.block_fwd,
            [
                act,
                f32(d.d_model, 3 * d.d_model),
                f32(d.d_model, d.d_model),
                f32(d.d_model, d.d_ff),
                f32(d.d_ff, d.d_model),
            ],
        ),
        "block_bwd": (
            model.block_bwd,
            [
                act,
                f32(d.d_model, 3 * d.d_model),
                f32(d.d_model, d.d_model),
                f32(d.d_model, d.d_ff),
                f32(d.d_ff, d.d_model),
                act,
            ],
        ),
        "loss_grad": (
            model.loss_grad,
            [act, f32(d.d_model, d.vocab), i32(d.batch, d.seq)],
        ),
    }

    manifest = {
        "dims": d._asdict(),
        "activation_bytes": 4 * d.batch * d.seq * d.d_model,
        "artifacts": {},
    }
    for name, (fn, ex) in specs.items():
        text = to_hlo_text(fn, *ex)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "num_inputs": len(ex),
            "input_shapes": [list(s.shape) for s in ex],
        }
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print("wrote manifest.json")


if __name__ == "__main__":
    main()
