"""L2: the workload model — a small transformer LM, segmented.

The training compute graph of this model (embed → K transformer blocks →
loss head, mirrored by the backward chain) is exactly the "U-net-like"
structure the paper identifies as the profitable case for
rematerialization (§1.1). Each segment is AOT-lowered to one HLO
artifact by `aot.py`; the Rust executor runs the MOCCASIN schedule over
these artifacts with a budget-enforcing tensor pool, re-invoking
`block_fwd` whenever the schedule rematerializes an activation.

The attention hot-spot inside `block_fwd` is the L1 Pallas
flash-attention kernel. The backward segment uses the reference math
(autodiff through an interpret-mode Pallas call is not supported for
export); pytest asserts the two forwards agree, so the gradients are
gradients of the function the kernel computes.
"""

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels.flash_attention import flash_attention
from .kernels.ref import attention_ref


class ModelDims(NamedTuple):
    vocab: int = 256
    d_model: int = 128
    d_ff: int = 512
    seq: int = 64
    batch: int = 8
    blocks: int = 4


DIMS = ModelDims()


def init_params(dims: ModelDims, seed: int = 0):
    """Embedding, per-block weights, unembedding."""
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 2 + 4 * dims.blocks)
    scale = lambda *shape: 1.0 / (shape[0] ** 0.5)
    embed = jax.random.normal(ks[0], (dims.vocab, dims.d_model)) * 0.02
    unembed = jax.random.normal(ks[1], (dims.d_model, dims.vocab)) * scale(dims.d_model)
    blocks = []
    for i in range(dims.blocks):
        b = ks[2 + 4 * i : 6 + 4 * i]
        blocks.append(
            dict(
                wqkv=jax.random.normal(b[0], (dims.d_model, 3 * dims.d_model))
                * scale(dims.d_model),
                wo=jax.random.normal(b[1], (dims.d_model, dims.d_model)) * scale(dims.d_model),
                w1=jax.random.normal(b[2], (dims.d_model, dims.d_ff)) * scale(dims.d_model),
                w2=jax.random.normal(b[3], (dims.d_ff, dims.d_model)) * scale(dims.d_ff),
            )
        )
    return embed, blocks, unembed


def _rms_norm(x):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6)


def _block_body(x, wqkv, wo, w1, w2, attn_fn):
    """Pre-norm transformer block: attention + MLP with residuals."""
    h = _rms_norm(x)
    qkv = h @ wqkv
    q, k, v = jnp.split(qkv, 3, axis=-1)
    a = attn_fn(q, k, v)
    x = x + a @ wo
    h = _rms_norm(x)
    x = x + jax.nn.gelu(h @ w1) @ w2
    return x


def embed_fwd(tokens, embed):
    """tokens (B,S) i32 → activations (B,S,D)."""
    return (embed[tokens],)


def block_fwd(x, wqkv, wo, w1, w2):
    """Forward segment with the Pallas attention kernel."""
    return (_block_body(x, wqkv, wo, w1, w2, flash_attention),)


def block_fwd_ref(x, wqkv, wo, w1, w2):
    """Same segment on the pure-jnp oracle (bwd path + tests)."""
    return (_block_body(x, wqkv, wo, w1, w2, attention_ref),)


def block_bwd(x, wqkv, wo, w1, w2, dy):
    """VJP of the block wrt input and weights."""
    def f(x, wqkv, wo, w1, w2):
        return _block_body(x, wqkv, wo, w1, w2, attention_ref)

    _, vjp = jax.vjp(f, x, wqkv, wo, w1, w2)
    return tuple(vjp(dy))  # (dx, dwqkv, dwo, dw1, dw2)


def loss_grad(a, unembed, targets):
    """Cross-entropy over the unembedding; returns (loss, da, dunembed)."""

    def f(a, unembed):
        logits = a @ unembed
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1).squeeze(-1)
        return nll.mean()

    loss, (da, dun) = jax.value_and_grad(f, argnums=(0, 1))(a, unembed)
    return (loss, da, dun)


def train_reference_step(tokens, targets, embed, blocks, unembed, lr):
    """Pure-JAX full training step (oracle for the Rust executor)."""
    def loss_fn(blocks, unembed):
        (a,) = embed_fwd(tokens, embed)
        for b in blocks:
            (a,) = block_fwd_ref(a, b["wqkv"], b["wo"], b["w1"], b["w2"])
        logits = a @ unembed
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1).squeeze(-1)
        return nll.mean()

    loss, grads = jax.value_and_grad(loss_fn, argnums=(0, 1))(blocks, unembed)
    gblocks, gun = grads
    new_blocks = [
        {k: b[k] - lr * gb[k] for k in b} for b, gb in zip(blocks, gblocks)
    ]
    return loss, new_blocks, unembed - lr * gun
