"""L1 correctness: Pallas flash-attention vs the pure-jnp oracle.

Hypothesis sweeps shapes and block sizes; assert_allclose against
`ref.attention_ref` is the core correctness signal for everything the
Rust runtime later executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.flash_attention import flash_attention
from compile.kernels.ref import attention_ref


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype=jnp.float32)


@pytest.mark.parametrize("b,s,d", [(1, 64, 32), (2, 128, 64), (4, 64, 128)])
def test_matches_reference_basic(b, s, d):
    q, k, v = rand(0, b, s, d), rand(1, b, s, d), rand(2, b, s, d)
    out = flash_attention(q, k, v)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 3),
    s_blocks=st.integers(1, 4),
    d=st.sampled_from([16, 32, 64]),
    block=st.sampled_from([16, 32, 64]),
    seed=st.integers(0, 2**16),
)
def test_matches_reference_hypothesis(b, s_blocks, d, block, seed):
    s = block * s_blocks
    q = rand(seed, b, s, d)
    k = rand(seed + 1, b, s, d)
    v = rand(seed + 2, b, s, d)
    out = flash_attention(q, k, v, block_q=block, block_k=block)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(out, ref, rtol=3e-5, atol=3e-5)


def test_block_size_invariance():
    q, k, v = rand(7, 2, 128, 32), rand(8, 2, 128, 32), rand(9, 2, 128, 32)
    a = flash_attention(q, k, v, block_q=32, block_k=32)
    b = flash_attention(q, k, v, block_q=128, block_k=64)
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)


def test_scale_extremes():
    # large-magnitude logits exercise the online-softmax max-shift
    q = rand(3, 1, 64, 32) * 10.0
    k = rand(4, 1, 64, 32) * 10.0
    v = rand(5, 1, 64, 32)
    out = flash_attention(q, k, v)
    ref = attention_ref(q, k, v)
    assert not np.any(np.isnan(np.asarray(out)))
    # near-one-hot softmax amplifies f32 noise; shape-level agreement
    np.testing.assert_allclose(out, ref, rtol=1e-3, atol=1e-3)
