"""L2 correctness: segment shapes, kernel-vs-ref block equivalence,
gradient sanity, and a few reference training steps that must reduce
the loss (the oracle the Rust executor's loss curve is compared to)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.model import DIMS, ModelDims


def small_dims():
    return ModelDims(vocab=64, d_model=32, d_ff=64, seq=32, batch=2, blocks=2)


def test_block_fwd_matches_ref():
    d = small_dims()
    embed, blocks, _ = model.init_params(d, seed=1)
    x = jax.random.normal(jax.random.PRNGKey(2), (d.batch, d.seq, d.d_model))
    b = blocks[0]
    (y_kernel,) = model.block_fwd(x, b["wqkv"], b["wo"], b["w1"], b["w2"])
    (y_ref,) = model.block_fwd_ref(x, b["wqkv"], b["wo"], b["w1"], b["w2"])
    np.testing.assert_allclose(y_kernel, y_ref, rtol=3e-5, atol=3e-5)


def test_block_bwd_shapes_and_finite():
    d = small_dims()
    _, blocks, _ = model.init_params(d, seed=1)
    b = blocks[0]
    x = jax.random.normal(jax.random.PRNGKey(3), (d.batch, d.seq, d.d_model))
    dy = jax.random.normal(jax.random.PRNGKey(4), (d.batch, d.seq, d.d_model))
    dx, dwqkv, dwo, dw1, dw2 = model.block_bwd(x, b["wqkv"], b["wo"], b["w1"], b["w2"], dy)
    assert dx.shape == x.shape
    assert dwqkv.shape == b["wqkv"].shape
    assert dwo.shape == b["wo"].shape
    assert dw1.shape == b["w1"].shape
    assert dw2.shape == b["w2"].shape
    for g in (dx, dwqkv, dwo, dw1, dw2):
        assert np.all(np.isfinite(np.asarray(g)))


def test_block_bwd_is_vjp_of_fwd():
    # directional-derivative check: <f(x+eps u) - f(x-eps u)>/2eps ≈ <dy, J u>
    d = small_dims()
    _, blocks, _ = model.init_params(d, seed=5)
    b = blocks[0]
    key = jax.random.PRNGKey(6)
    x = jax.random.normal(key, (d.batch, d.seq, d.d_model))
    u = jax.random.normal(jax.random.PRNGKey(7), x.shape)
    dy = jax.random.normal(jax.random.PRNGKey(8), x.shape)
    eps = 1e-3
    (fp,) = model.block_fwd_ref(x + eps * u, b["wqkv"], b["wo"], b["w1"], b["w2"])
    (fm,) = model.block_fwd_ref(x - eps * u, b["wqkv"], b["wo"], b["w1"], b["w2"])
    lhs = jnp.vdot(dy, (fp - fm) / (2 * eps))
    dx = model.block_bwd(x, b["wqkv"], b["wo"], b["w1"], b["w2"], dy)[0]
    rhs = jnp.vdot(dx, u)
    np.testing.assert_allclose(lhs, rhs, rtol=2e-2, atol=1e-3)


def test_loss_grad_outputs():
    d = small_dims()
    _, _, unembed = model.init_params(d, seed=2)
    a = jax.random.normal(jax.random.PRNGKey(9), (d.batch, d.seq, d.d_model))
    targets = jax.random.randint(jax.random.PRNGKey(10), (d.batch, d.seq), 0, d.vocab)
    loss, da, dun = model.loss_grad(a, unembed, targets)
    assert loss.shape == ()
    assert float(loss) > 0.0
    assert da.shape == a.shape
    assert dun.shape == unembed.shape


def test_reference_training_reduces_loss():
    d = small_dims()
    embed, blocks, unembed = model.init_params(d, seed=3)
    key = jax.random.PRNGKey(11)
    # tiny synthetic corpus: next-token = (token + 1) % vocab
    tokens = jax.random.randint(key, (d.batch, d.seq), 0, d.vocab)
    targets = (tokens + 1) % d.vocab
    losses = []
    for _ in range(10):
        loss, blocks, unembed = model.train_reference_step(
            tokens, targets, embed, blocks, unembed, lr=0.2
        )
        losses.append(float(loss))
    assert min(losses) < losses[0] * 0.9, losses


def test_default_dims_consistent():
    assert DIMS.seq % 64 == 0 or DIMS.seq % 32 == 0
    assert DIMS.d_ff == 4 * DIMS.d_model
