//! Budget sweep (paper contribution 4: "impact of memory limit"):
//! sweep the budget from 95% down toward the structural floor and
//! report the duration/memory trade-off curve plus solve time.

use moccasin::coordinator::{Coordinator, SolveRequest};
use moccasin::generators::paper_graph;
use moccasin::graph::topological_order;
use moccasin::util::fmt_u64;
use std::time::{Duration, Instant};

fn main() {
    let name = std::env::args().nth(1).unwrap_or_else(|| "G1".into());
    let g = paper_graph(&name).expect("G1..G4, RW1..RW4, CM1, CM2");
    let order = topological_order(&g).unwrap();
    let peak = g.peak_mem_no_remat(&order).unwrap();
    let floor = g.working_set_floor();
    println!(
        "{name}: n={} m={}, peak={}, working-set floor={} ({:.0}%)",
        g.n(), g.m(), fmt_u64(peak), fmt_u64(floor),
        100.0 * floor as f64 / peak as f64
    );
    println!("{:>8} {:>12} {:>8} {:>8} {:>9}", "budget%", "budget", "TDI%", "remats", "time(s)");
    let mut coord = Coordinator::new();
    for pct in [95, 90, 85, 80, 75, 70, 65, 60] {
        let budget = peak * pct / 100;
        if budget < floor {
            println!(
                "{pct:>7}% {:>12} below working-set floor — provably infeasible",
                fmt_u64(budget)
            );
            continue;
        }
        let t0 = Instant::now();
        let resp = coord.solve(
            &g,
            &SolveRequest { budget, time_limit: Duration::from_secs(20), ..Default::default() },
        );
        match resp.solution {
            Some(sol) => println!(
                "{pct:>7}% {:>12} {:>8.2} {:>8} {:>9.2}",
                fmt_u64(budget), sol.eval.tdi_percent, sol.eval.remat_count,
                t0.elapsed().as_secs_f64()
            ),
            None => println!("{pct:>7}% {:>12} no solution found", fmt_u64(budget)),
        }
    }
}
