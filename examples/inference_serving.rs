//! Inference-serving scenario: a mobile-class inference graph (the
//! paper's RW class) deployed against several device memory classes;
//! for each class the coordinator computes a schedule and reports the
//! achievable latency overhead — the compile-time product a deployment
//! toolchain would ship.

use moccasin::coordinator::{Coordinator, SolveRequest};
use moccasin::generators::real_world_like;
use moccasin::graph::topological_order;
use moccasin::util::fmt_u64;
use std::time::Duration;

fn main() {
    // mid-size commercial-like inference graph
    let g = real_world_like("mobile-vision", 200, 520, 42);
    let order = topological_order(&g).unwrap();
    let peak = g.peak_mem_no_remat(&order).unwrap();
    println!(
        "model graph: n={} m={}, unconstrained activation peak = {} units",
        g.n(), g.m(), fmt_u64(peak)
    );

    // hypothetical device tiers with shrinking local SRAM
    let tiers = [("flagship", 1.0f64), ("mid-tier", 0.85), ("budget", 0.7), ("iot", 0.55)];
    let mut coord = Coordinator::new();
    println!("{:<10} {:>12} {:>9} {:>8}", "device", "local mem", "TDI%", "remats");
    for (tier, frac) in tiers {
        let budget = (peak as f64 * frac) as u64;
        let resp = coord.solve(
            &g,
            &SolveRequest { budget, time_limit: Duration::from_secs(15), ..Default::default() },
        );
        match resp.solution {
            Some(sol) => println!(
                "{tier:<10} {:>12} {:>9.2} {:>8}",
                fmt_u64(budget), sol.eval.tdi_percent, sol.eval.remat_count
            ),
            None => println!(
                "{tier:<10} {:>12}   does not fit even with rematerialization",
                fmt_u64(budget)
            ),
        }
    }
    println!("(cache stats: {} misses, {} hits)", coord.misses, coord.hits);
}
