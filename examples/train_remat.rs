//! End-to-end driver (deliverable): train a small transformer LM under a
//! MOCCASIN rematerialization schedule executed through PJRT, proving
//! all three layers compose — L1 Pallas kernel inside the L2 JAX
//! segments, AOT artifacts executed by the L3 Rust coordinator with a
//! budget-enforcing tensor pool. Logs the loss curve and the
//! memory/duration trade. Run `make artifacts` first and build with
//! `--features pjrt` (the offline default build stubs the runtime).

use moccasin::executor::{train_with_remat, TrainConfig};
use moccasin::util::fmt_u64;

fn main() -> moccasin::util::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let steps = args.iter().position(|a| a == "--steps")
        .and_then(|i| args.get(i + 1)).and_then(|s| s.parse().ok()).unwrap_or(200);
    let budget_frac = args.iter().position(|a| a == "--budget-frac")
        .and_then(|i| args.get(i + 1)).and_then(|s| s.parse().ok()).unwrap_or(0.6);

    // dims must match python/compile/model.py::DIMS
    let (vocab, d_model, d_ff, seq, batch, blocks) = (256, 128, 512, 64, 8, 4);
    let cfg = TrainConfig { blocks, steps, lr: 0.05, budget_frac, seed: 0 };
    println!(
        "training {blocks}-block transformer (d={d_model}, seq={seq}, batch={batch}) \
         for {steps} steps at budget {budget_frac:.0}% of activation peak",
        budget_frac = budget_frac * 100.0
    );

    let report = train_with_remat("artifacts", vocab, d_model, d_ff, seq, batch, &cfg)?;

    println!("\nschedule: {} remats, TDI {:.1}%", report.remat_count, report.tdi_percent);
    println!("budget {} B, observed pool peak {} B",
        fmt_u64(report.budget_bytes), fmt_u64(report.peak_pool_bytes));
    println!("profiled segment durations (us): {:?}", report.durations_us);
    let n = report.losses.len();
    println!("\nloss curve:");
    for (i, l) in report.losses.iter().enumerate() {
        if i % (n / 20).max(1) == 0 || i == n - 1 {
            println!("  step {i:4}  loss {l:.4}");
        }
    }
    let avg_wall: u64 =
        report.step_wall_us.iter().sum::<u64>() / report.step_wall_us.len().max(1) as u64;
    println!("\navg step wall time: {} us", avg_wall);
    assert!(report.peak_pool_bytes <= report.budget_bytes, "budget violated");
    assert!(report.losses.last().unwrap() < &(report.losses[0] * 0.9), "loss did not drop");
    println!(
        "OK: loss dropped {:.3} -> {:.3} within budget",
        report.losses[0],
        report.losses.last().unwrap()
    );
    Ok(())
}
