//! Quickstart: build a compute graph, solve for a memory budget, print
//! the rematerialization schedule.

use moccasin::coordinator::{Coordinator, SolveRequest};
use moccasin::graph::{topological_order, Graph};
use moccasin::util::fmt_u64;
use std::time::Duration;

fn main() {
    // A toy inference graph: chain with a long skip connection and a
    // heavy early tensor — the classic case where rematerialization
    // pays (drop the early tensor, recompute it just before its late
    // consumer).
    let g = Graph::from_edges(
        "quickstart",
        6,
        &[(0, 1), (1, 2), (2, 3), (3, 4), (4, 5), (0, 5)],
        vec![4, 2, 2, 2, 2, 1],      // durations w_v
        vec![64, 48, 48, 48, 48, 8], // output sizes m_v
    )
    .unwrap();

    let order = topological_order(&g).unwrap();
    let peak = g.peak_mem_no_remat(&order).unwrap();
    println!("graph: n={} m={}, no-remat peak = {}", g.n(), g.m(), fmt_u64(peak));

    let budget = (peak as f64 * 0.8) as u64;
    let mut coord = Coordinator::new();
    let resp = coord.solve(
        &g,
        &SolveRequest { budget, time_limit: Duration::from_secs(5), ..Default::default() },
    );
    let sol = resp.solution.expect("feasible at 80%");
    println!(
        "budget {} -> schedule {:?}\n  duration {} (TDI {:.1}%), peak {}, {} remats, optimal: {}",
        fmt_u64(budget),
        sol.seq,
        sol.eval.duration,
        sol.eval.tdi_percent,
        fmt_u64(sol.eval.peak_mem),
        sol.eval.remat_count,
        resp.proved_optimal,
    );
    assert!(sol.eval.peak_mem <= budget);
}
