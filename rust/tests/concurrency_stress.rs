//! Deterministic interleaving stress tests for the two concurrency
//! invariants the serving tier leans on hardest:
//!
//! 1. **Exactly one terminal per job** — every delivery path (worker
//!    completion, cancel, preempt, expiry sweep, shutdown drain) races
//!    through one `compare_exchange` arbiter; whichever caller loses
//!    must drop its outcome silently.
//! 2. **`Incumbent::cancel` stickiness** — once any thread observes the
//!    flag set it must stay set for every later read on every thread
//!    (Release store / Acquire load on one `AtomicBool`).
//!
//! The tests are spawn-loops: each seed derives the whole interleaving
//! schedule (thread counts, per-thread op mixes, signal choices) from a
//! splitmix64 stream, so a failure reproduces from the seed printed in
//! the assertion message. No sleeps anywhere — contention comes from
//! running the same short race many times, not from timing.
//!
//! The nightly TSan CI tier re-runs this binary under
//! `-Zsanitizer=thread` with `MOCCASIN_PROP_CASES` reduced (TSan's
//! ~10× slowdown), so every interleaving exercised here is also a
//! data-race witness. Under Miri the seed counts shrink further —
//! interpreted execution is ~1000× slower, and Miri's weak-memory
//! emulation gets its value from the op *mix*, not the rep count.

use moccasin::graph::Graph;
use moccasin::serve::{ControlSignal, ServeConfig, ServeEvent, ServeRequest, SolverService, Terminal};
use moccasin::util::Incumbent;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

/// Case-count multiplier (same contract as the property suites): the
/// nightly deep-test job sets `MOCCASIN_PROP_CASES=10`, the TSan job
/// sets it back down to keep wall-clock bounded under the sanitizer.
fn prop_case_scale() -> u64 {
    std::env::var("MOCCASIN_PROP_CASES")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .filter(|&s| s >= 1)
        .unwrap_or(1)
}

/// Seeds per test: base count × env scale, shrunk under Miri (whose
/// interpreter is slow enough that one seed already takes seconds).
fn seed_count(base: u64) -> u64 {
    if cfg!(miri) {
        2
    } else {
        base * prop_case_scale()
    }
}

/// splitmix64 — the repo's standard deterministic stream (same
/// constants as `generators`): every schedule decision in these tests
/// is a pure function of (seed, draw index).
struct Rng(u64);

impl Rng {
    fn next(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9e3779b97f4a7c15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }

    fn below(&mut self, n: u64) -> u64 {
        self.next() % n
    }
}

/// Tiny chain with a known optimum (duration 6 at budget 10) — solves
/// in well under a millisecond, so the signal storm genuinely races
/// solve completion instead of always winning.
fn chain() -> Graph {
    Graph::from_edges(
        "stress",
        5,
        &[(0, 1), (1, 2), (2, 3), (3, 4), (0, 4)],
        vec![1; 5],
        vec![5, 4, 4, 4, 1],
    )
    .unwrap()
}

// ---------------------------------------------------------------------------
// Incumbent::cancel stickiness
// ---------------------------------------------------------------------------

/// N writer threads race records/beats/preempts against one cancelling
/// thread while reader threads assert the stickiness contract: after
/// the first `true` they observe, `is_cancelled()` never reads `false`
/// again. Also checks the fetch-min bound converges to the true
/// minimum published across all writers — cancellation must not tear
/// the bound.
#[test]
fn incumbent_cancel_is_sticky_across_threads() {
    for seed in 0..seed_count(40) {
        let mut rng = Rng(0xC0FFEE ^ seed);
        let writers = 2 + rng.below(3) as usize;
        let ops_per_writer = if cfg!(miri) { 50 } else { 400 + rng.below(400) };
        let cancel_after = rng.below(ops_per_writer);
        let inc = Arc::new(Incumbent::new());
        let regression = Arc::new(AtomicBool::new(false));
        let true_min = Arc::new(AtomicU64::new(u64::MAX));

        std::thread::scope(|s| {
            for w in 0..writers {
                let inc = Arc::clone(&inc);
                let true_min = Arc::clone(&true_min);
                let mut wrng = Rng(seed.wrapping_mul(0x9e37).wrapping_add(w as u64));
                s.spawn(move || {
                    for op in 0..ops_per_writer {
                        match wrng.below(4) {
                            0 => {
                                let d = 1 + wrng.below(1000);
                                true_min.fetch_min(d, Ordering::Relaxed);
                                inc.record(d);
                            }
                            1 => inc.beat(),
                            2 => {
                                let _ = inc.best();
                            }
                            _ => {
                                if w == 0 && op >= cancel_after {
                                    inc.cancel();
                                } else {
                                    let _ = inc.should_stop();
                                }
                            }
                        }
                    }
                    // writer 0 always cancels before exiting, so the
                    // post-join assertions below are unconditional
                    if w == 0 {
                        inc.cancel();
                    }
                });
            }
            // two readers watch for a true -> false regression
            for _ in 0..2 {
                let inc = Arc::clone(&inc);
                let regression = Arc::clone(&regression);
                s.spawn(move || {
                    let mut seen = false;
                    for _ in 0..(if cfg!(miri) { 200 } else { 4000 }) {
                        let now = inc.is_cancelled();
                        if seen && !now {
                            regression.store(true, Ordering::Release);
                            return;
                        }
                        seen = seen || now;
                        if seen {
                            // stickiness also implies should_stop stays up
                            if !inc.should_stop() {
                                regression.store(true, Ordering::Release);
                                return;
                            }
                        }
                    }
                });
            }
        });

        assert!(
            !regression.load(Ordering::Acquire),
            "cancel flag regressed from set to clear (seed {seed})"
        );
        assert!(inc.is_cancelled(), "cancel must be visible after join (seed {seed})");
        let min = true_min.load(Ordering::Relaxed);
        if min != u64::MAX {
            assert_eq!(
                inc.best(),
                Some(min),
                "shared bound must converge to the true minimum (seed {seed})"
            );
        } else {
            assert_eq!(inc.best(), None, "no record, no bound (seed {seed})");
        }
    }
}

/// Preemption and cancellation are independent sticky flags sharing the
/// stop surface: racing both must end with both set and neither state
/// leaking into the other's accessor.
#[test]
fn incumbent_preempt_and_cancel_race_without_crosstalk() {
    for seed in 0..seed_count(40) {
        let inc = Arc::new(Incumbent::new());
        std::thread::scope(|s| {
            for flag in 0..2 {
                let inc = Arc::clone(&inc);
                let mut rng = Rng(seed ^ ((flag as u64) << 32));
                s.spawn(move || {
                    for _ in 0..rng.below(64) {
                        inc.beat();
                    }
                    if flag == 0 {
                        inc.cancel();
                    } else {
                        inc.preempt();
                    }
                });
            }
        });
        assert!(inc.is_cancelled(), "seed {seed}");
        assert!(inc.is_preempted(), "seed {seed}");
        assert!(inc.should_stop(), "seed {seed}");
    }
}

// ---------------------------------------------------------------------------
// serve: exactly one terminal per job under a signal storm
// ---------------------------------------------------------------------------

/// Drain a job channel until it disconnects (the service drops every
/// sender clone once the job is finished and pruned) or goes quiet,
/// returning all terminals received. The quiet window only matters in
/// the disconnect-less tail; 2 s is far beyond any in-process delivery.
fn drain_terminals(rx: &mpsc::Receiver<ServeEvent>) -> Vec<Terminal> {
    let mut terminals = Vec::new();
    while let Ok(ev) = rx.recv_timeout(Duration::from_secs(2)) {
        if let ServeEvent::Terminal { outcome, .. } = ev {
            terminals.push(outcome);
        }
    }
    terminals
}

/// Submit a burst of fast jobs, then blast every job with a
/// seed-derived mix of Cancel / Preempt / TightenBound signals from
/// multiple threads while workers are completing them — every delivery
/// path (solved, cancelled, preempted, shutdown-drain) races the same
/// `finish` CAS. The contract: each channel sees exactly one terminal,
/// no matter who wins.
#[test]
fn serve_delivers_exactly_one_terminal_under_signal_storm() {
    let n_seeds = seed_count(10);
    for seed in 0..n_seeds {
        let mut rng = Rng(0x5EEDED ^ seed);
        let jobs = if cfg!(miri) { 2 } else { 6 + rng.below(6) as usize };
        let svc = Arc::new(SolverService::start(ServeConfig {
            workers: 2,
            queue_cap: 256,
            cache_cap: 0, // every job must take the full solve path
            ..Default::default()
        }));
        let graph = Arc::new(chain());

        let mut rxs = Vec::with_capacity(jobs);
        let mut ids = Vec::with_capacity(jobs);
        for _ in 0..jobs {
            let (tx, rx) = mpsc::channel();
            let req = ServeRequest {
                deadline: Duration::from_secs(30),
                ..ServeRequest::new(Arc::clone(&graph), 10)
            };
            ids.push(svc.submit(req, tx));
            rxs.push(rx);
        }

        // signal storm: 3 threads, each walking the job list in a
        // seed-derived order firing a seed-derived signal per job
        std::thread::scope(|s| {
            for t in 0..3u64 {
                let svc = Arc::clone(&svc);
                let ids = ids.clone();
                let mut trng = Rng(seed.wrapping_mul(31).wrapping_add(t));
                s.spawn(move || {
                    let mut order: Vec<usize> = (0..ids.len()).collect();
                    // Fisher-Yates from the seed stream
                    for i in (1..order.len()).rev() {
                        order.swap(i, trng.below(i as u64 + 1) as usize);
                    }
                    for &j in &order {
                        match trng.below(4) {
                            0 => {
                                svc.control(ids[j], ControlSignal::Cancel);
                            }
                            1 => {
                                svc.control(ids[j], ControlSignal::Preempt);
                            }
                            2 => {
                                svc.control(ids[j], ControlSignal::TightenBound(7));
                            }
                            _ => {} // let this job race the workers untouched
                        }
                    }
                });
            }
        });

        // shutdown drains whatever is still queued (Failed terminals) —
        // one more contender for the same CAS
        svc.shutdown();

        for (j, rx) in rxs.iter().enumerate() {
            let terminals = drain_terminals(rx);
            assert_eq!(
                terminals.len(),
                1,
                "job {j} (id {}) received {} terminals, want exactly 1 (seed {seed}): {:?}",
                ids[j],
                terminals.len(),
                terminals.iter().map(|t| t.name()).collect::<Vec<_>>()
            );
            // a solved terminal must still be the known optimum — the
            // storm may stop work early but must never corrupt it
            if let Terminal::Solved(resp) = &terminals[0] {
                if let Some(sol) = &resp.solution {
                    assert_eq!(sol.eval.duration, 6, "seed {seed} job {j}");
                    assert!(sol.eval.peak_mem <= 10, "seed {seed} job {j}");
                }
            }
        }
    }
}

/// The storm test again, but with `workers: 1` and a queue deep enough
/// that most jobs are still queued when the signals land — exercising
/// the queued-side arbitration (sweeper + control path + shutdown
/// drain) rather than the in-session side.
#[test]
fn serve_queued_jobs_also_get_exactly_one_terminal() {
    for seed in 0..seed_count(10) {
        let mut rng = Rng(0xABBA ^ seed);
        let jobs = if cfg!(miri) { 3 } else { 8 };
        let svc = Arc::new(SolverService::start(ServeConfig {
            workers: 1,
            queue_cap: 256,
            cache_cap: 0,
            ..Default::default()
        }));
        let graph = Arc::new(chain());

        let mut rxs = Vec::with_capacity(jobs);
        let mut ids = Vec::with_capacity(jobs);
        for _ in 0..jobs {
            let (tx, rx) = mpsc::channel();
            let req = ServeRequest {
                deadline: Duration::from_secs(30),
                ..ServeRequest::new(Arc::clone(&graph), 10)
            };
            ids.push(svc.submit(req, tx));
            rxs.push(rx);
        }

        // cancel a seed-chosen half of the backlog from two racing
        // threads (both threads target the SAME jobs — double-cancel
        // must be as safe as one), then shut down under the rest
        let victims: Vec<u64> =
            ids.iter().copied().filter(|_| rng.below(2) == 0).collect();
        std::thread::scope(|s| {
            for _ in 0..2 {
                let svc = Arc::clone(&svc);
                let victims = victims.clone();
                s.spawn(move || {
                    for id in victims {
                        svc.control(id, ControlSignal::Cancel);
                    }
                });
            }
        });
        svc.shutdown();

        for (j, rx) in rxs.iter().enumerate() {
            let terminals = drain_terminals(rx);
            assert_eq!(
                terminals.len(),
                1,
                "job {j} (id {}) received {} terminals, want exactly 1 (seed {seed}): {:?}",
                ids[j],
                terminals.len(),
                terminals.iter().map(|t| t.name()).collect::<Vec<_>>()
            );
        }
        // cancelled victims must be answered as Cancelled or have lost
        // the race to a worker that already finished them — but the
        // stats ledger must balance either way
        let s = svc.stats();
        let answered = s.solved + s.cancelled + s.preempted + s.expired + s.failed + s.shed;
        assert_eq!(
            answered, jobs as u64,
            "terminal ledger must balance: {s:?} (seed {seed})"
        );
    }
}
