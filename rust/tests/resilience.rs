//! Fault-injection integration tests for the resilient solve pipeline:
//! failpoints (see `util::failpoint`), the watchdog, the
//! graceful-degradation ladder and `solve_many`'s retry-once policy.
//!
//! Requires `--features failpoints` (the whole file compiles away
//! otherwise): the failpoint registry is process-global, so these
//! tests serialize themselves behind a file-local mutex and restore
//! the `MOCCASIN_FAILPOINTS` env baseline after each test — the CI
//! fault-injection job runs this suite under several env matrix
//! entries, and per-test arming must compose with (not clobber) them.
//! Assertions that depend on exact fire counts are gated on the env
//! being empty.
#![cfg(feature = "failpoints")]

use moccasin::coordinator::{Coordinator, SolveRequest};
use moccasin::generators::random_layered;
use moccasin::graph::{topological_order, Graph};
use moccasin::moccasin::{MoccasinSolver, Rung};
use moccasin::serve::{ServeConfig, ServeEvent, ServeRequest, SolverService, Terminal};
use moccasin::util::failpoint::{self, FailAction};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Serializes the tests in this binary: the failpoint registry and the
/// resilience event counters are process-global.
static GATE: Mutex<()> = Mutex::new(());

fn serial() -> std::sync::MutexGuard<'static, ()> {
    GATE.lock().unwrap_or_else(|p| p.into_inner())
}

/// Whether no env-level failpoints are armed (strict count assertions
/// only hold then; the CI matrix arms extra recoverable sites).
fn env_clear() -> bool {
    std::env::var("MOCCASIN_FAILPOINTS").map(|v| v.trim().is_empty()).unwrap_or(true)
}

/// Tiny chain with a known optimum (duration 6 at budget 10).
fn chain() -> Graph {
    Graph::from_edges(
        "c",
        5,
        &[(0, 1), (1, 2), (2, 3), (3, 4), (0, 4)],
        vec![1; 5],
        vec![5, 4, 4, 4, 1],
    )
    .unwrap()
}

/// A graph above the exact threshold (so the improvement phase is
/// LNS-driven) plus a feasible budget for it.
fn lns_instance(seed: u64) -> (Graph, u64) {
    let g = random_layered("res", 40, 95, seed);
    let order = topological_order(&g).unwrap();
    let peak = g.peak_mem_no_remat(&order).unwrap();
    let budget = (peak as f64 * 0.9) as u64;
    (g, budget)
}

#[test]
fn solve_many_retries_once_after_member_panic() {
    let _g = serial();
    failpoint::reset();
    // one injected panic: the first solve attempt that reaches the
    // coordinator.solve site dies; its job must be retried once and the
    // retry (failpoint exhausted) must succeed
    failpoint::arm("coordinator.solve", FailAction::Panic, Some(1));
    let g = chain();
    let mut coord = Coordinator::new();
    let mk = |budget: u64| SolveRequest {
        budget,
        time_limit: Duration::from_secs(10),
        ..Default::default()
    };
    let responses = coord.solve_many(&[(&g, mk(10)), (&g, mk(13))]);
    assert_eq!(responses.len(), 2);
    for r in &responses {
        assert!(
            r.solution.is_some(),
            "every request must be answered despite the injected panic: {:?}",
            r.error
        );
    }
    if env_clear() {
        let total_retries: u32 = responses
            .iter()
            .filter_map(|r| r.degradation.as_ref())
            .map(|d| d.retries)
            .sum();
        assert_eq!(total_retries, 1, "exactly one job panicked and was retried");
        let retried = responses
            .iter()
            .filter_map(|r| r.degradation.as_ref())
            .find(|d| d.retries == 1)
            .expect("one response carries the retry provenance");
        assert!(
            retried.failures.iter().any(|f| f.contains("failpoint 'coordinator.solve'")),
            "provenance must name the failpoint: {:?}",
            retried.failures
        );
    }
    // no poisoned state left behind: the same coordinator keeps working
    let again = coord.solve(&g, &mk(10));
    assert!(again.solution.is_some());
    failpoint::reset();
}

#[test]
fn persistent_panic_degrades_to_member_failure_with_failpoint_name() {
    let _g = serial();
    failpoint::reset();
    // unlimited panics: the first attempt and the retry both die; the
    // serial path's catch_unwind must turn that into a structured
    // member-failure response whose diagnostic names the failpoint
    failpoint::arm("coordinator.solve", FailAction::Panic, None);
    let g = chain();
    let mut coord = Coordinator::new();
    let req =
        SolveRequest { budget: 10, time_limit: Duration::from_secs(10), ..Default::default() };
    let resp = coord.solve(&g, &req);
    assert!(resp.solution.is_none());
    let err = resp.error.as_deref().unwrap_or("");
    assert!(err.contains("member failed"), "unexpected error: {err}");
    assert!(
        err.contains("failpoint 'coordinator.solve'"),
        "diagnostic must carry the failpoint name: {err}"
    );
    // panic responses are not cached and the locks are not poisoned:
    // disarming and re-solving the same request must succeed
    failpoint::disarm("coordinator.solve");
    let resp2 = coord.solve(&g, &req);
    assert_eq!(
        resp2.solution.expect("re-solve succeeds after disarm").eval.duration,
        6
    );
    failpoint::reset();
}

#[test]
fn watchdog_kills_solve_wedged_past_its_budget_slice() {
    let _g = serial();
    failpoint::reset();
    // a 2.5s injected sleep inside the first LNS window, against a
    // 400ms wall budget and a 100ms stall threshold: the watchdog must
    // cancel the solve (the sleeping thread notices on wake), and the
    // response must still be valid with the kill in its provenance
    failpoint::arm("lns.window", FailAction::Delay(2_500), Some(1));
    let (g, budget) = lns_instance(7);
    let mut coord = Coordinator::new();
    let req = SolveRequest {
        budget,
        time_limit: Duration::from_millis(400),
        stall_ms: Some(100),
        ..Default::default()
    };
    let t0 = Instant::now();
    let resp = coord.solve(&g, &req);
    let wall = t0.elapsed();
    assert!(
        wall < Duration::from_secs(30),
        "solve must not hang past the watchdog slice (took {wall:?})"
    );
    if let Some(sol) = &resp.solution {
        assert!(sol.eval.peak_mem <= budget, "degraded answer must still be feasible");
    }
    assert!(
        resp.stats.watchdog_kills >= 1,
        "the kill must surface in the response stats"
    );
    let deg = resp.degradation.expect("moccasin backend reports provenance");
    assert!(
        deg.failures.iter().any(|f| f.contains("watchdog")),
        "provenance must record the watchdog kill: {:?}",
        deg.failures
    );
    failpoint::reset();
}

#[test]
fn lns_window_errors_still_yield_a_valid_response() {
    let _g = serial();
    failpoint::reset();
    // every LNS window reports an injected error ("no improvement"):
    // the solve must still return the greedy-floor schedule, feasibly
    failpoint::arm("lns.window", FailAction::Error, None);
    let (g, budget) = lns_instance(11);
    let mut coord = Coordinator::new();
    let resp = coord.solve(
        &g,
        &SolveRequest {
            budget,
            time_limit: Duration::from_millis(800),
            ..Default::default()
        },
    );
    let sol = resp.solution.expect("greedy floor must survive window errors");
    assert!(sol.eval.peak_mem <= budget);
    assert!(resp.degradation.is_some());
    failpoint::reset();
}

#[test]
fn ladder_floor_is_never_worse_than_plain_greedy() {
    let _g = serial();
    failpoint::reset();
    // with every engine fixpoint panicking, all improvement attempts
    // die and the ladder must answer from the greedy-only floor —
    // which a clean solve must then never be worse than
    failpoint::arm("engine.propagate", FailAction::Panic, None);
    let (g, budget) = lns_instance(3);
    let solver =
        MoccasinSolver { time_limit: Duration::from_secs(5), ..Default::default() };
    let degraded = solver.solve(&g, budget, None);
    assert_eq!(
        degraded.degradation.rung,
        Rung::GreedyOnly,
        "all-attempts-dead must land on the greedy-only rung: {:?}",
        degraded.degradation.failures
    );
    if env_clear() {
        assert!(
            degraded.stats.member_panics >= 1,
            "the absorbed panics must be counted"
        );
        assert!(
            degraded.degradation.failures.iter().any(|f| f.contains("engine.propagate")),
            "provenance must name the failpoint: {:?}",
            degraded.degradation.failures
        );
    }
    failpoint::reset();
    let clean = solver.solve(&g, budget, None);
    if let (Some(d), Some(c)) = (&degraded.best, &clean.best) {
        assert!(d.eval.peak_mem <= budget);
        assert!(c.eval.peak_mem <= budget);
        assert!(
            c.eval.duration <= d.eval.duration,
            "ladder must never return worse than the greedy floor \
             (clean {} > degraded {})",
            c.eval.duration,
            d.eval.duration
        );
    } else {
        // greedy found nothing: then the degraded run must not have
        // conjured a solution either
        assert!(degraded.best.is_none());
    }
}

// ---------------------------------------------------------------------------
// Serving-tier fault matrix: the `serve.worker` / `serve.session`
// failpoints against the admission queue, the worker pool's
// retry-once-and-respawn policy, and the exactly-one-terminal
// invariant.
// ---------------------------------------------------------------------------

fn serve_request(deadline: Duration) -> ServeRequest {
    ServeRequest { deadline, ..ServeRequest::new(Arc::new(chain()), 10) }
}

/// Drain one job's channel to its terminal (progress events returned
/// too); panics — rather than hangs — if no terminal arrives.
fn terminal_of(rx: &mpsc::Receiver<ServeEvent>) -> (Vec<ServeEvent>, Terminal) {
    let mut progress = Vec::new();
    loop {
        let ev = rx
            .recv_timeout(Duration::from_secs(60))
            .expect("every submitted request must receive a terminal");
        match ev {
            ServeEvent::Terminal { outcome, .. } => return (progress, outcome),
            other => progress.push(other),
        }
    }
}

#[test]
fn serve_worker_panic_retries_once_on_fresh_worker_with_provenance() {
    let _g = serial();
    failpoint::reset();
    // the first session to reach the serve.worker site dies; the job
    // must be retried exactly once on a respawned worker and succeed,
    // with the first attempt's death in its degradation provenance
    failpoint::arm("serve.worker", FailAction::Panic, Some(1));
    let svc = SolverService::start(ServeConfig { workers: 1, ..Default::default() });
    let (tx, rx) = mpsc::channel();
    svc.submit(serve_request(Duration::from_secs(30)), tx);
    let (progress, outcome) = terminal_of(&rx);
    let died: Vec<&ServeEvent> = progress
        .iter()
        .filter(|e| matches!(e, ServeEvent::Died { .. }))
        .collect();
    assert_eq!(died.len(), 1, "exactly one worker death event");
    let ServeEvent::Died { attempt, note, will_retry, .. } = died[0] else {
        unreachable!()
    };
    assert_eq!(*attempt, 0);
    assert!(*will_retry);
    assert!(note.contains("failpoint 'serve.worker'"), "note: {note}");
    assert!(
        progress
            .iter()
            .any(|e| matches!(e, ServeEvent::Started { attempt: 1, .. })),
        "the retry must start on a fresh worker"
    );
    let resp = match outcome {
        Terminal::Solved(resp) => resp,
        other => panic!("retry must succeed, got {}", other.name()),
    };
    assert_eq!(resp.solution.as_ref().unwrap().eval.duration, 6);
    let deg = resp.degradation.as_ref().expect("retried response carries provenance");
    assert!(deg.retries >= 1);
    assert!(
        deg.failures.iter().any(|f| f.contains("serve.worker")),
        "provenance must name the failpoint: {:?}",
        deg.failures
    );
    if env_clear() {
        let s = svc.stats();
        assert_eq!(s.worker_deaths, 1);
        assert_eq!(s.retries, 1);
    }
    svc.shutdown();
    failpoint::reset();
}

#[test]
fn serve_persistent_panic_fails_structurally_and_queue_keeps_draining() {
    let _g = serial();
    failpoint::reset();
    // every session dies, forever: each job burns its single retry and
    // must then FAIL structurally — while the queue keeps draining the
    // jobs behind it (each death respawns the worker)
    failpoint::arm("serve.worker", FailAction::Panic, None);
    let svc = SolverService::start(ServeConfig { workers: 1, ..Default::default() });
    let rxs: Vec<mpsc::Receiver<ServeEvent>> = (0..3)
        .map(|_| {
            let (tx, rx) = mpsc::channel();
            svc.submit(serve_request(Duration::from_secs(30)), tx);
            rx
        })
        .collect();
    for rx in &rxs {
        let (_, outcome) = terminal_of(rx);
        let error = match outcome {
            Terminal::Failed { error } => error,
            other => {
                panic!("persistent panic must fail structurally, got {}", other.name())
            }
        };
        assert!(error.contains("no retry left"), "error: {error}");
        assert!(error.contains("failpoint 'serve.worker'"), "error: {error}");
    }
    // disarm: the (respawned) pool must still serve new requests
    failpoint::disarm("serve.worker");
    let (tx, rx) = mpsc::channel();
    svc.submit(serve_request(Duration::from_secs(30)), tx);
    let (_, outcome) = terminal_of(&rx);
    assert!(
        matches!(outcome, Terminal::Solved(_)),
        "pool must recover once the fault clears, got {}",
        outcome.name()
    );
    svc.shutdown();
    failpoint::reset();
}

#[test]
fn serve_watchdog_kills_stalled_session_while_others_keep_solving() {
    let _g = serial();
    failpoint::reset();
    // one session stalls 2.5s without heartbeats against a 100ms stall
    // budget (warmup 4x = 400ms): its watchdog must kill it, the
    // response must carry the kill, and concurrent jobs on the other
    // worker must be unaffected
    failpoint::arm("serve.session", FailAction::Delay(2_500), Some(1));
    let svc = SolverService::start(ServeConfig {
        workers: 2,
        stall_ms: Some(100),
        ..Default::default()
    });
    let rxs: Vec<mpsc::Receiver<ServeEvent>> = (0..4)
        .map(|_| {
            let (tx, rx) = mpsc::channel();
            svc.submit(serve_request(Duration::from_secs(30)), tx);
            rx
        })
        .collect();
    let mut kills = 0u64;
    let mut solved = 0usize;
    for rx in &rxs {
        let (_, outcome) = terminal_of(rx);
        match outcome {
            Terminal::Solved(resp) => {
                solved += 1;
                kills += resp.stats.watchdog_kills;
                if resp.stats.watchdog_kills > 0 {
                    assert!(
                        !resp.proved_optimal,
                        "a killed session cannot claim an optimality proof"
                    );
                    let deg = resp.degradation.as_ref().unwrap();
                    assert!(
                        deg.failures.iter().any(|f| f.contains("watchdog")),
                        "kill must be in provenance: {:?}",
                        deg.failures
                    );
                }
            }
            other => panic!("expected solved terminals, got {}", other.name()),
        }
    }
    assert_eq!(solved, 4, "the stall must not take other requests down");
    assert!(kills >= 1, "the stalled session's watchdog kill must surface");
    svc.shutdown();
    failpoint::reset();
}

#[test]
fn serve_queue_full_shed_is_a_structured_answer_not_a_drop() {
    let _g = serial();
    failpoint::reset();
    // hold the single worker in a 500ms stall so the 1-deep queue
    // fills; the third submit must be answered immediately with a
    // structured Overloaded terminal — never silently dropped
    failpoint::arm("serve.session", FailAction::Delay(500), Some(1));
    let svc = SolverService::start(ServeConfig {
        workers: 1,
        queue_cap: 1,
        ..Default::default()
    });
    let (tx_a, rx_a) = mpsc::channel();
    svc.submit(serve_request(Duration::from_secs(30)), tx_a);
    std::thread::sleep(Duration::from_millis(150)); // A is in-session
    let (tx_b, rx_b) = mpsc::channel();
    svc.submit(serve_request(Duration::from_secs(30)), tx_b);
    let (tx_c, rx_c) = mpsc::channel();
    svc.submit(serve_request(Duration::from_secs(30)), tx_c);
    let t0 = Instant::now();
    let (progress_c, outcome_c) = terminal_of(&rx_c);
    assert!(
        t0.elapsed() < Duration::from_millis(200),
        "a shed must be answered immediately, not after the backlog"
    );
    assert!(progress_c.is_empty(), "a shed request is never queued or started");
    let (queue_len, reason) = match outcome_c {
        Terminal::Overloaded { queue_len, reason, .. } => (queue_len, reason),
        other => panic!("expected overloaded, got {}", other.name()),
    };
    assert_eq!(queue_len, 1);
    assert!(reason.contains("queue full"), "reason: {reason}");
    for rx in [&rx_a, &rx_b] {
        let (_, o) = terminal_of(rx);
        assert!(matches!(o, Terminal::Solved(_)), "admitted jobs still solve");
    }
    assert_eq!(svc.stats().shed, 1);
    svc.shutdown();
    failpoint::reset();
}

/// The PR's acceptance invariant: under injected worker panics AND
/// stalls, with 64 concurrent requests racing a 4-worker pool and a
/// bounded queue, every submitted request receives EXACTLY one terminal
/// response — no hangs, no drops, no duplicates — and the service
/// ledger agrees with the delivered outcomes.
#[test]
fn serve_64_concurrent_requests_each_get_exactly_one_terminal_under_faults() {
    let _g = serial();
    failpoint::reset();
    failpoint::arm("serve.worker", FailAction::Panic, Some(5));
    failpoint::arm("serve.session", FailAction::Delay(150), Some(3));
    let svc = SolverService::start(ServeConfig {
        workers: 4,
        queue_cap: 64,
        ..Default::default()
    });
    const N: usize = 64;
    let rxs: Vec<mpsc::Receiver<ServeEvent>> = (0..N)
        .map(|i| {
            let (tx, rx) = mpsc::channel();
            let req = if i % 4 == 0 {
                // mix in a larger instance so sessions overlap for real
                let g = Arc::new(random_layered("srv64", 40, 95, (i % 8) as u64 + 1));
                let order = topological_order(&g).unwrap();
                let peak = g.peak_mem_no_remat(&order).unwrap();
                ServeRequest {
                    deadline: Duration::from_secs(60),
                    ..ServeRequest::new(g, (peak as f64 * 0.9) as u64)
                }
            } else {
                serve_request(Duration::from_secs(60))
            };
            svc.submit(req, tx);
            rx
        })
        .collect();
    let mut by_class = std::collections::BTreeMap::<&'static str, u64>::new();
    let mut terminals = Vec::with_capacity(N);
    for rx in &rxs {
        let (_, outcome) = terminal_of(rx); // panics on hang
        *by_class.entry(outcome.name()).or_insert(0) += 1;
        terminals.push(outcome);
    }
    // exactly one terminal each: after shutdown every channel must be
    // fully drained with no second terminal behind the first
    svc.shutdown();
    for rx in &rxs {
        while let Ok(ev) = rx.try_recv() {
            assert!(
                !matches!(ev, ServeEvent::Terminal { .. }),
                "duplicate terminal delivered: {ev:?}"
            );
        }
    }
    let s = svc.stats();
    assert_eq!(s.submitted, N as u64);
    assert_eq!(
        s.solved + s.preempted + s.cancelled + s.shed + s.expired + s.failed,
        N as u64,
        "terminal ledger must account for every submission: {s:?}"
    );
    assert_eq!(
        by_class.values().sum::<u64>(),
        N as u64,
        "delivered terminals must match submissions: {by_class:?}"
    );
    // the faults were survivable: the overwhelming majority still solve
    assert!(
        by_class.get("solved").copied().unwrap_or(0) >= (N as u64) - 8,
        "outcomes: {by_class:?}"
    );
    failpoint::reset();
}
