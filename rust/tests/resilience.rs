//! Fault-injection integration tests for the resilient solve pipeline:
//! failpoints (see `util::failpoint`), the watchdog, the
//! graceful-degradation ladder and `solve_many`'s retry-once policy.
//!
//! Requires `--features failpoints` (the whole file compiles away
//! otherwise): the failpoint registry is process-global, so these
//! tests serialize themselves behind a file-local mutex and restore
//! the `MOCCASIN_FAILPOINTS` env baseline after each test — the CI
//! fault-injection job runs this suite under several env matrix
//! entries, and per-test arming must compose with (not clobber) them.
//! Assertions that depend on exact fire counts are gated on the env
//! being empty.
#![cfg(feature = "failpoints")]

use moccasin::coordinator::{Coordinator, SolveRequest};
use moccasin::generators::random_layered;
use moccasin::graph::{topological_order, Graph};
use moccasin::moccasin::{MoccasinSolver, Rung};
use moccasin::util::failpoint::{self, FailAction};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Serializes the tests in this binary: the failpoint registry and the
/// resilience event counters are process-global.
static GATE: Mutex<()> = Mutex::new(());

fn serial() -> std::sync::MutexGuard<'static, ()> {
    GATE.lock().unwrap_or_else(|p| p.into_inner())
}

/// Whether no env-level failpoints are armed (strict count assertions
/// only hold then; the CI matrix arms extra recoverable sites).
fn env_clear() -> bool {
    std::env::var("MOCCASIN_FAILPOINTS").map(|v| v.trim().is_empty()).unwrap_or(true)
}

/// Tiny chain with a known optimum (duration 6 at budget 10).
fn chain() -> Graph {
    Graph::from_edges(
        "c",
        5,
        &[(0, 1), (1, 2), (2, 3), (3, 4), (0, 4)],
        vec![1; 5],
        vec![5, 4, 4, 4, 1],
    )
    .unwrap()
}

/// A graph above the exact threshold (so the improvement phase is
/// LNS-driven) plus a feasible budget for it.
fn lns_instance(seed: u64) -> (Graph, u64) {
    let g = random_layered("res", 40, 95, seed);
    let order = topological_order(&g).unwrap();
    let peak = g.peak_mem_no_remat(&order).unwrap();
    let budget = (peak as f64 * 0.9) as u64;
    (g, budget)
}

#[test]
fn solve_many_retries_once_after_member_panic() {
    let _g = serial();
    failpoint::reset();
    // one injected panic: the first solve attempt that reaches the
    // coordinator.solve site dies; its job must be retried once and the
    // retry (failpoint exhausted) must succeed
    failpoint::arm("coordinator.solve", FailAction::Panic, Some(1));
    let g = chain();
    let mut coord = Coordinator::new();
    let mk = |budget: u64| SolveRequest {
        budget,
        time_limit: Duration::from_secs(10),
        ..Default::default()
    };
    let responses = coord.solve_many(&[(&g, mk(10)), (&g, mk(13))]);
    assert_eq!(responses.len(), 2);
    for r in &responses {
        assert!(
            r.solution.is_some(),
            "every request must be answered despite the injected panic: {:?}",
            r.error
        );
    }
    if env_clear() {
        let total_retries: u32 = responses
            .iter()
            .filter_map(|r| r.degradation.as_ref())
            .map(|d| d.retries)
            .sum();
        assert_eq!(total_retries, 1, "exactly one job panicked and was retried");
        let retried = responses
            .iter()
            .filter_map(|r| r.degradation.as_ref())
            .find(|d| d.retries == 1)
            .expect("one response carries the retry provenance");
        assert!(
            retried.failures.iter().any(|f| f.contains("failpoint 'coordinator.solve'")),
            "provenance must name the failpoint: {:?}",
            retried.failures
        );
    }
    // no poisoned state left behind: the same coordinator keeps working
    let again = coord.solve(&g, &mk(10));
    assert!(again.solution.is_some());
    failpoint::reset();
}

#[test]
fn persistent_panic_degrades_to_member_failure_with_failpoint_name() {
    let _g = serial();
    failpoint::reset();
    // unlimited panics: the first attempt and the retry both die; the
    // serial path's catch_unwind must turn that into a structured
    // member-failure response whose diagnostic names the failpoint
    failpoint::arm("coordinator.solve", FailAction::Panic, None);
    let g = chain();
    let mut coord = Coordinator::new();
    let req =
        SolveRequest { budget: 10, time_limit: Duration::from_secs(10), ..Default::default() };
    let resp = coord.solve(&g, &req);
    assert!(resp.solution.is_none());
    let err = resp.error.as_deref().unwrap_or("");
    assert!(err.contains("member failed"), "unexpected error: {err}");
    assert!(
        err.contains("failpoint 'coordinator.solve'"),
        "diagnostic must carry the failpoint name: {err}"
    );
    // panic responses are not cached and the locks are not poisoned:
    // disarming and re-solving the same request must succeed
    failpoint::disarm("coordinator.solve");
    let resp2 = coord.solve(&g, &req);
    assert_eq!(
        resp2.solution.expect("re-solve succeeds after disarm").eval.duration,
        6
    );
    failpoint::reset();
}

#[test]
fn watchdog_kills_solve_wedged_past_its_budget_slice() {
    let _g = serial();
    failpoint::reset();
    // a 2.5s injected sleep inside the first LNS window, against a
    // 400ms wall budget and a 100ms stall threshold: the watchdog must
    // cancel the solve (the sleeping thread notices on wake), and the
    // response must still be valid with the kill in its provenance
    failpoint::arm("lns.window", FailAction::Delay(2_500), Some(1));
    let (g, budget) = lns_instance(7);
    let mut coord = Coordinator::new();
    let req = SolveRequest {
        budget,
        time_limit: Duration::from_millis(400),
        stall_ms: Some(100),
        ..Default::default()
    };
    let t0 = Instant::now();
    let resp = coord.solve(&g, &req);
    let wall = t0.elapsed();
    assert!(
        wall < Duration::from_secs(30),
        "solve must not hang past the watchdog slice (took {wall:?})"
    );
    if let Some(sol) = &resp.solution {
        assert!(sol.eval.peak_mem <= budget, "degraded answer must still be feasible");
    }
    assert!(
        resp.stats.watchdog_kills >= 1,
        "the kill must surface in the response stats"
    );
    let deg = resp.degradation.expect("moccasin backend reports provenance");
    assert!(
        deg.failures.iter().any(|f| f.contains("watchdog")),
        "provenance must record the watchdog kill: {:?}",
        deg.failures
    );
    failpoint::reset();
}

#[test]
fn lns_window_errors_still_yield_a_valid_response() {
    let _g = serial();
    failpoint::reset();
    // every LNS window reports an injected error ("no improvement"):
    // the solve must still return the greedy-floor schedule, feasibly
    failpoint::arm("lns.window", FailAction::Error, None);
    let (g, budget) = lns_instance(11);
    let mut coord = Coordinator::new();
    let resp = coord.solve(
        &g,
        &SolveRequest {
            budget,
            time_limit: Duration::from_millis(800),
            ..Default::default()
        },
    );
    let sol = resp.solution.expect("greedy floor must survive window errors");
    assert!(sol.eval.peak_mem <= budget);
    assert!(resp.degradation.is_some());
    failpoint::reset();
}

#[test]
fn ladder_floor_is_never_worse_than_plain_greedy() {
    let _g = serial();
    failpoint::reset();
    // with every engine fixpoint panicking, all improvement attempts
    // die and the ladder must answer from the greedy-only floor —
    // which a clean solve must then never be worse than
    failpoint::arm("engine.propagate", FailAction::Panic, None);
    let (g, budget) = lns_instance(3);
    let solver =
        MoccasinSolver { time_limit: Duration::from_secs(5), ..Default::default() };
    let degraded = solver.solve(&g, budget, None);
    assert_eq!(
        degraded.degradation.rung,
        Rung::GreedyOnly,
        "all-attempts-dead must land on the greedy-only rung: {:?}",
        degraded.degradation.failures
    );
    if env_clear() {
        assert!(
            degraded.stats.member_panics >= 1,
            "the absorbed panics must be counted"
        );
        assert!(
            degraded.degradation.failures.iter().any(|f| f.contains("engine.propagate")),
            "provenance must name the failpoint: {:?}",
            degraded.degradation.failures
        );
    }
    failpoint::reset();
    let clean = solver.solve(&g, budget, None);
    if let (Some(d), Some(c)) = (&degraded.best, &clean.best) {
        assert!(d.eval.peak_mem <= budget);
        assert!(c.eval.peak_mem <= budget);
        assert!(
            c.eval.duration <= d.eval.duration,
            "ladder must never return worse than the greedy floor \
             (clean {} > degraded {})",
            c.eval.duration,
            d.eval.duration
        );
    } else {
        // greedy found nothing: then the degraded run must not have
        // conjured a solution either
        assert!(degraded.best.is_none());
    }
}
