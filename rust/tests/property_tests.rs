//! Property-based tests over randomized graphs (in-tree generator-driven
//! sweeps; the offline build carries no proptest dependency, so these
//! are seeded exhaustive-ish sweeps with shrinking-by-construction:
//! every case is reproducible from its printed seed).
//!
//! Invariants checked:
//! 1. Every solver solution is a valid sequence and within budget.
//! 2. eval peak is monotone: adding budget never increases best duration.
//! 3. Appendix-A.3 eval agrees with a brute-force liveness simulation.
//! 4. Canonicalization preserves duration and validity.
//! 5. working_set_floor is a true lower bound on any solver result.
//! 6. The event-driven propagation engine returns the same status and
//!    optimum as the naive re-enqueue-everything reference on random
//!    layered and cm-style staged (and unstaged) models across seeds.
//! 7. The root presolve (structural elimination, cover compaction,
//!    liveness bounds, dominance fixing) returns the same status and
//!    optimum as the raw formulation on random layered and cm-style
//!    staged (and unstaged) models across seeds, while constructing
//!    strictly fewer propagators over strictly smaller domains.
//! 8. The conflict-driven learned search (explained propagation, 1UIP
//!    no-good learning, activity branching, Luby restarts) returns the
//!    same status and optimum as the chronological baseline on the
//!    same instance families — learning is purely pruning.
//! 9. The segment-tree timetable profile is *query-value identical* to
//!    the linear diff-map profile: under the chronological strategy the
//!    two modes must walk the exact same tree (same status, optimum,
//!    nodes, conflicts, solutions and propagations), on small exhausted
//!    instances and on an n ≥ 1000 node-capped smoke.
//! 10. Timetable edge-finding (`--filtering edge-finding`) returns the
//!    same status and optimum as the default timetable filtering, and
//!    under the chronological strategy never grows the tree — the
//!    extra energy reasoning is purely pruning.
//! 11. The disjunctive propagator emitted by heavy-clique presolve
//!    detection preserves status and optimum when toggled, and when no
//!    clique was detected the toggle leaves the tree bit-identical.
//! 12. The solve-context arena is pure mechanism: a solve on a reused
//!    (dirty) `SolveCtx` walks the *identical* tree — same status,
//!    optimum, nodes and conflicts — as a solve on a fresh context, for
//!    chronological and learned search on staged and unstaged models,
//!    even when the context was last used by a different-sized model.
//!
//! Every randomized sweep multiplies its case count by the
//! `MOCCASIN_PROP_CASES` env var (default 1; the nightly deep-test CI
//! job sets 10) and stamps the generator seed into its graph names and
//! assertion messages, so a CI failure reproduces as a one-liner.

use moccasin::cp::{FilteringMode, ProfileMode, SearchStrategy, SolveCtx, Solver, Status};
use moccasin::generators::{cm_style, paper_graph, random_layered, real_world_like};
use moccasin::graph::{eval_sequence, topological_order, Graph, NodeId};
use moccasin::moccasin::lns::canonicalize;
use moccasin::moccasin::{MoccasinSolver, StagedModel};
use moccasin::presolve::{Presolve, PresolveConfig};
use std::time::Duration;

/// Case-count multiplier for the randomized sweeps, read from
/// `MOCCASIN_PROP_CASES` (default 1; the nightly deep-test CI job sets
/// 10). Extra cases reuse the same generators with fresh seeds while
/// instance *sizes* stay bounded (`seed % base` in the size formulas),
/// so deep runs widen coverage without changing the exhaustion budget
/// per case. Any failure reproduces from the seed in the message.
fn prop_case_scale() -> u64 {
    std::env::var("MOCCASIN_PROP_CASES")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .filter(|&s| s >= 1)
        .unwrap_or(1)
}

/// Brute-force Appendix-A.3 oracle: O(L² · m) recomputation of the
/// memory profile from first principles.
fn brute_force_peak(g: &Graph, seq: &[NodeId]) -> u64 {
    let mut peak = 0u64;
    for i in 0..seq.len() {
        // ors_{i-1}: nodes computed in seq[..i] whose latest instance
        // has a consumer occurrence later in the sequence with no
        // recompute in between
        let mut mem = g.mem[seq[i] as usize];
        for v in 0..g.n() as NodeId {
            let Some(p) = seq[..i].iter().rposition(|&x| x == v) else { continue };
            // does any successor consume this instance at position >= i?
            let consumed_later = g.succs[v as usize].iter().any(|&z| {
                (i..seq.len()).any(|q| {
                    seq[q] == z && !seq[p + 1..q].contains(&v)
                })
            });
            if consumed_later {
                mem += g.mem[v as usize];
            }
        }
        peak = peak.max(mem);
    }
    peak
}

fn graphs() -> Vec<Graph> {
    let mut gs = Vec::new();
    for seed in 0..6 * prop_case_scale() {
        let size = (seed % 6) as usize;
        let (n, m) = (40 + 10 * size, 100 + 20 * size);
        gs.push(random_layered(&format!("rl{seed}"), n, m, seed));
    }
    gs.push(cm_style("cm", 21, 45, 3, 256));
    gs.push(real_world_like("rw", 48, 120, 9));
    gs
}

#[test]
fn prop_eval_matches_brute_force() {
    for g in &graphs() {
        let order = topological_order(g).unwrap();
        let ev = eval_sequence(g, &order).unwrap();
        assert_eq!(ev.peak_mem, brute_force_peak(g, &order), "graph {} no-remat", g.name);
        // and with a remat sequence from the solver
        let peak = ev.peak_mem;
        let solver = MoccasinSolver { time_limit: Duration::from_secs(2), ..Default::default() };
        if let Some(best) = solver.solve(g, (peak as f64 * 0.85) as u64, None).best {
            assert_eq!(
                best.eval.peak_mem,
                brute_force_peak(g, &best.seq),
                "graph {} remat seq",
                g.name
            );
        }
    }
}

#[test]
fn prop_solutions_valid_and_within_budget() {
    for g in &graphs() {
        let order = topological_order(g).unwrap();
        let peak = g.peak_mem_no_remat(&order).unwrap();
        for frac in [0.95, 0.85] {
            let budget = (peak as f64 * frac) as u64;
            let solver =
                MoccasinSolver { time_limit: Duration::from_secs(2), ..Default::default() };
            if let Some(best) = solver.solve(g, budget, None).best {
                let ev = eval_sequence(g, &best.seq).expect("valid sequence");
                assert!(ev.peak_mem <= budget, "graph {} frac {frac}", g.name);
                assert_eq!(ev.duration, best.eval.duration, "graph {} self-consistent", g.name);
            }
        }
    }
}

#[test]
fn prop_duration_monotone_in_budget() {
    for g in &graphs() {
        let order = topological_order(g).unwrap();
        let peak = g.peak_mem_no_remat(&order).unwrap();
        let mut last: Option<u64> = None;
        // increasing budgets → non-increasing optimal-ish durations
        for frac in [0.85, 0.9, 0.95, 1.0] {
            let solver =
                MoccasinSolver { time_limit: Duration::from_secs(2), ..Default::default() };
            let d = solver
                .solve(g, (peak as f64 * frac) as u64, None)
                .best
                .map(|b| b.eval.duration);
            if let (Some(prev), Some(cur)) = (last, d) {
                // heuristic solver: allow tiny non-monotonicity (2%)
                assert!(
                    cur as f64 <= prev as f64 * 1.02,
                    "graph {}: duration rose {prev} -> {cur} as budget loosened",
                    g.name
                );
            }
            if d.is_some() {
                last = d;
            }
        }
        // at full budget there must be no remat
        assert_eq!(last, Some(g.total_duration()), "graph {} full budget", g.name);
    }
}

#[test]
fn prop_canonicalize_preserves_duration() {
    for g in &graphs() {
        let order = topological_order(g).unwrap();
        let peak = g.peak_mem_no_remat(&order).unwrap();
        let solver = MoccasinSolver { time_limit: Duration::from_secs(2), ..Default::default() };
        if let Some(best) = solver.solve(g, (peak as f64 * 0.9) as u64, Some(order.clone())).best
        {
            if let Some(c) = canonicalize(g, &order, &best.seq) {
                assert!(c.eval.duration <= best.eval.duration, "graph {}", g.name);
                assert!(eval_sequence(g, &c.seq).is_ok(), "graph {} canonical valid", g.name);
            }
        }
    }
}

/// Solve one staged (or unstaged) CP model with the given engine mode;
/// returns (status, best objective value).
fn cp_solve(
    g: &Graph,
    budget: u64,
    staged: bool,
    naive: bool,
    node_limit: u64,
) -> (Status, Option<i64>) {
    let order = topological_order(g).unwrap();
    let c_v = vec![2usize; g.n()];
    let sm = if staged {
        StagedModel::build(g, &order, budget, &c_v)
    } else {
        StagedModel::build_unstaged(g, &order, budget, &c_v)
    };
    let (bo, guards) = sm.branch_order();
    let solver = Solver { node_limit, guards: Some(guards), naive, ..Default::default() };
    let r = solver.solve(&sm.model, &sm.objective, &bo, |_, _| {});
    (r.status, r.best.map(|(_, o)| o))
}

#[test]
fn prop_engine_matches_naive_reference() {
    // Small instances solved to exhaustion: the event-driven engine and
    // the naive reference must agree on status AND optimum. Bounds
    // propagation is confluent, so any divergence is an engine bug
    // (missed wakeup, stale cumulative profile, bad backtrack resync).
    let mut graphs: Vec<Graph> = Vec::new();
    for seed in 0..4 * prop_case_scale() {
        let n = 10 + 2 * (seed % 4) as usize;
        graphs.push(random_layered(&format!("eq-rl{seed}"), n, 2 * n + 4, seed));
    }
    graphs.push(cm_style("eq-cm", 11, 22, 3, 64));
    for g in &graphs {
        let order = topological_order(g).unwrap();
        let peak = g.peak_mem_no_remat(&order).unwrap();
        for frac in [0.85, 0.95] {
            let budget = (peak as f64 * frac) as u64;
            let (s_ev, o_ev) = cp_solve(g, budget, true, false, 200_000);
            let (s_na, o_na) = cp_solve(g, budget, true, true, 200_000);
            assert_eq!(s_ev, s_na, "graph {} frac {frac}: status diverged", g.name);
            assert_eq!(o_ev, o_na, "graph {} frac {frac}: optimum diverged", g.name);
        }
    }
    // unstaged model (exercises AllDifferent) on a tiny instance
    let g = random_layered("eq-un", 7, 12, 99);
    let order = topological_order(&g).unwrap();
    let peak = g.peak_mem_no_remat(&order).unwrap();
    let (s_ev, o_ev) = cp_solve(&g, peak, false, false, 200_000);
    let (s_na, o_na) = cp_solve(&g, peak, false, true, 200_000);
    assert_eq!(s_ev, s_na, "unstaged: status diverged");
    assert_eq!(o_ev, o_na, "unstaged: optimum diverged");
}

/// Solve one staged (or unstaged) CP model with the given search
/// strategy; returns (status, best objective value, kernel stats).
fn cp_solve_strategy(
    g: &Graph,
    budget: u64,
    staged: bool,
    strategy: SearchStrategy,
    node_limit: u64,
) -> (Status, Option<i64>, moccasin::cp::SearchStats) {
    let order = topological_order(g).unwrap();
    let c_v = vec![2usize; g.n()];
    let sm = if staged {
        StagedModel::build(g, &order, budget, &c_v)
    } else {
        StagedModel::build_unstaged(g, &order, budget, &c_v)
    };
    let (bo, guards) = sm.branch_order();
    let solver = Solver { node_limit, guards: Some(guards), strategy, ..Default::default() };
    let r = solver.solve(&sm.model, &sm.objective, &bo, |_, _| {});
    (r.status, r.best.map(|(_, o)| o), r.stats)
}

#[test]
fn prop_learned_matches_chronological() {
    // Small instances solved to exhaustion: the conflict-driven learned
    // search and the chronological baseline must agree on status AND
    // optimum — learning must be purely pruning, never dropping
    // solutions. Any divergence is a learning bug (an unsound
    // explanation, a bad 1UIP cut, a wrong no-good assertion, a branch
    // heap that lost a position and declared a premature leaf).
    let mut graphs: Vec<Graph> = Vec::new();
    for seed in 0..4 * prop_case_scale() {
        let n = 10 + 2 * (seed % 4) as usize;
        graphs.push(random_layered(&format!("lr-rl{seed}"), n, 2 * n + 4, seed));
    }
    graphs.push(cm_style("lr-cm", 11, 22, 3, 64));
    for g in &graphs {
        let order = topological_order(g).unwrap();
        let peak = g.peak_mem_no_remat(&order).unwrap();
        for frac in [0.85, 0.95] {
            let budget = (peak as f64 * frac) as u64;
            let (s_ch, o_ch, st_ch) =
                cp_solve_strategy(g, budget, true, SearchStrategy::chronological(), 400_000);
            let (s_ln, o_ln, st_ln) =
                cp_solve_strategy(g, budget, true, SearchStrategy::learned(), 400_000);
            assert_eq!(s_ch, s_ln, "graph {} frac {frac}: status diverged", g.name);
            assert_eq!(o_ch, o_ln, "graph {} frac {frac}: optimum diverged", g.name);
            // chronological must not pay any learning overhead …
            assert_eq!(st_ch.nogoods_learned, 0);
            // … and the learned run must actually have learned whenever
            // it saw a conflict at a decision level
            assert!(
                st_ln.conflicts == 0 || st_ln.nogoods_learned > 0,
                "graph {} frac {frac}: conflicts without learning",
                g.name
            );
        }
    }
    // unstaged model (exercises AllDifferent) on tiny instances
    for seed in [99u64, 123] {
        let g = random_layered(&format!("lr-un{seed}"), 7, 12, seed);
        let order = topological_order(&g).unwrap();
        let peak = g.peak_mem_no_remat(&order).unwrap();
        let (s_ch, o_ch, _) =
            cp_solve_strategy(&g, peak, false, SearchStrategy::chronological(), 400_000);
        let (s_ln, o_ln, _) =
            cp_solve_strategy(&g, peak, false, SearchStrategy::learned(), 400_000);
        assert_eq!(s_ch, s_ln, "unstaged seed {seed}: status diverged");
        assert_eq!(o_ch, o_ln, "unstaged seed {seed}: optimum diverged");
    }
}

/// Solve one staged (or unstaged) CP model built raw or through the
/// root presolve; returns (status, best objective value, #propagators,
/// summed domain size).
fn cp_solve_presolve(
    g: &Graph,
    budget: u64,
    staged: bool,
    presolve: bool,
    node_limit: u64,
) -> (Status, Option<i64>, usize, u64) {
    let order = topological_order(g).unwrap();
    let c_v = vec![2usize; g.n()];
    let pre = if presolve {
        Presolve::new(g, PresolveConfig::default())
    } else {
        Presolve::off()
    };
    let sm = if staged {
        StagedModel::build_with(g, &order, budget, &c_v, &pre, None)
    } else {
        StagedModel::build_unstaged_with(g, &order, budget, &c_v, &pre)
    };
    let (bo, guards) = sm.branch_order();
    let solver = Solver { node_limit, guards: Some(guards), ..Default::default() };
    let r = solver.solve(&sm.model, &sm.objective, &bo, |_, _| {});
    (
        r.status,
        r.best.map(|(_, o)| o),
        sm.model.num_constraints(),
        sm.model.domain_size_sum(),
    )
}

#[test]
fn prop_presolve_preserves_optimum() {
    // Small instances solved to exhaustion: the presolved (compacted)
    // model and the raw formulation must agree on status AND optimum —
    // the presolve's default level is exactness-preserving by
    // construction, and any divergence is a reduction bug (an over-eager
    // domain cap, a dominance rule that kills a needed copy, a dropped
    // constraint that was not implied). Mirrors the PR 2
    // engine-vs-naive harness.
    let mut graphs: Vec<Graph> = Vec::new();
    for seed in 0..4 * prop_case_scale() {
        let n = 10 + 2 * (seed % 4) as usize;
        graphs.push(random_layered(&format!("pre-rl{seed}"), n, 2 * n + 4, seed));
    }
    graphs.push(cm_style("pre-cm", 11, 22, 3, 64));
    for g in &graphs {
        let order = topological_order(g).unwrap();
        let peak = g.peak_mem_no_remat(&order).unwrap();
        for frac in [0.85, 0.95] {
            let budget = (peak as f64 * frac) as u64;
            let (s_pre, o_pre, props_pre, dom_pre) =
                cp_solve_presolve(g, budget, true, true, 400_000);
            let (s_raw, o_raw, props_raw, dom_raw) =
                cp_solve_presolve(g, budget, true, false, 400_000);
            assert_eq!(s_pre, s_raw, "graph {} frac {frac}: status diverged", g.name);
            assert_eq!(o_pre, o_raw, "graph {} frac {frac}: optimum diverged", g.name);
            assert!(
                props_pre < props_raw,
                "graph {} frac {frac}: presolve must construct fewer propagators",
                g.name
            );
            assert!(
                dom_pre < dom_raw,
                "graph {} frac {frac}: presolve must shrink summed domain size",
                g.name
            );
        }
    }
    // unstaged models (exercise AllDifferent + depth bounds) on tiny
    // instances
    for seed in [99u64, 123] {
        let g = random_layered(&format!("pre-un{seed}"), 7, 12, seed);
        let order = topological_order(&g).unwrap();
        let peak = g.peak_mem_no_remat(&order).unwrap();
        let (s_pre, o_pre, _, _) = cp_solve_presolve(&g, peak, false, true, 400_000);
        let (s_raw, o_raw, _, _) = cp_solve_presolve(&g, peak, false, false, 400_000);
        assert_eq!(s_pre, s_raw, "unstaged seed {seed}: status diverged");
        assert_eq!(o_pre, o_raw, "unstaged seed {seed}: optimum diverged");
    }
}

/// Solve one staged (or unstaged) CP model under a timetable-profile
/// mode; returns (status, best objective, kernel stats).
fn cp_solve_profile(
    g: &Graph,
    budget: u64,
    staged: bool,
    profile: ProfileMode,
    strategy: SearchStrategy,
    node_limit: u64,
) -> (Status, Option<i64>, moccasin::cp::SearchStats) {
    let order = topological_order(g).unwrap();
    let c_v = vec![2usize; g.n()];
    let sm = if staged {
        StagedModel::build(g, &order, budget, &c_v)
    } else {
        StagedModel::build_unstaged(g, &order, budget, &c_v)
    };
    let (bo, guards) = sm.branch_order();
    let solver = Solver {
        node_limit,
        guards: Some(guards),
        strategy: strategy.with_profile(profile),
        ..Default::default()
    };
    let r = solver.solve(&sm.model, &sm.objective, &bo, |_, _| {});
    (r.status, r.best.map(|(_, o)| o), r.stats)
}

#[test]
fn prop_segtree_profile_matches_linear() {
    // The segment tree must answer every filter query with the same
    // *value* as the linear step profile (point loads, overload checks,
    // first-overload witnesses). Under the deterministic chronological
    // strategy that means the two modes walk the *identical* tree: not
    // just the same status/optimum, but the same node, conflict,
    // solution and propagation counts — the strongest cheap proxy for
    // "identical prunings". Any divergence is a tree bug (bad lazy
    // recompute, wrong gap handling, off-by-one range clamp).
    let mut graphs: Vec<Graph> = Vec::new();
    for seed in 0..5 * prop_case_scale() {
        let n = 10 + 2 * (seed % 5) as usize;
        graphs.push(random_layered(&format!("sp-rl{seed}"), n, 2 * n + 4, seed));
    }
    graphs.push(cm_style("sp-cm", 11, 22, 3, 64));
    graphs.push(real_world_like("sp-rw", 16, 40, 5));
    for g in &graphs {
        let order = topological_order(g).unwrap();
        let peak = g.peak_mem_no_remat(&order).unwrap();
        for frac in [0.85, 0.95] {
            let budget = (peak as f64 * frac) as u64;
            let chron = SearchStrategy::chronological();
            let (s_l, o_l, st_l) =
                cp_solve_profile(g, budget, true, ProfileMode::Linear, chron, 400_000);
            let (s_t, o_t, st_t) =
                cp_solve_profile(g, budget, true, ProfileMode::SegTree, chron, 400_000);
            assert_eq!(s_l, s_t, "graph {} frac {frac}: status diverged", g.name);
            assert_eq!(o_l, o_t, "graph {} frac {frac}: optimum diverged", g.name);
            assert_eq!(
                (st_l.nodes, st_l.conflicts, st_l.solutions, st_l.propagations),
                (st_t.nodes, st_t.conflicts, st_t.solutions, st_t.propagations),
                "graph {} frac {frac}: the two profile modes walked different trees",
                g.name
            );
            assert_eq!(st_t.cum_rebuilds, 0, "segtree mode never re-flattens");
            // learned strategy: explanations are also value-identical,
            // but assert only the exactness contract here (restart
            // timing makes full trace equality brittle)
            let (s_ll, o_ll, _) = cp_solve_profile(
                g,
                budget,
                true,
                ProfileMode::Linear,
                SearchStrategy::learned(),
                400_000,
            );
            let (s_lt, o_lt, _) = cp_solve_profile(
                g,
                budget,
                true,
                ProfileMode::SegTree,
                SearchStrategy::learned(),
                400_000,
            );
            assert_eq!(s_ll, s_lt, "graph {} frac {frac}: learned status diverged", g.name);
            assert_eq!(o_ll, o_lt, "graph {} frac {frac}: learned optimum diverged", g.name);
        }
    }
    // unstaged model (exercises AllDifferent alongside Cumulative)
    let g = random_layered("sp-un", 7, 12, 99);
    let order = topological_order(&g).unwrap();
    let peak = g.peak_mem_no_remat(&order).unwrap();
    let chron = SearchStrategy::chronological();
    let (s_l, o_l, st_l) =
        cp_solve_profile(&g, peak, false, ProfileMode::Linear, chron, 400_000);
    let (s_t, o_t, st_t) =
        cp_solve_profile(&g, peak, false, ProfileMode::SegTree, chron, 400_000);
    assert_eq!((s_l, o_l, st_l.nodes), (s_t, o_t, st_t.nodes), "unstaged diverged");
}

#[test]
fn prop_segtree_matches_linear_on_large_instance_smoke() {
    // n ≥ 1000 smoke (the tier the segment tree exists for): the same
    // node-capped chronological B&B over the presolved L1 staged model
    // must visit the identical tree under both profile modes.
    let g = paper_graph("L1").unwrap();
    let order = topological_order(&g).unwrap();
    let peak = g.peak_mem_no_remat(&order).unwrap();
    let budget = (peak as f64 * 0.9) as u64;
    let pre = Presolve::new(&g, PresolveConfig::default());
    let sm = StagedModel::build_with(&g, &order, budget, &vec![2; g.n()], &pre, None);
    let (bo, guards) = sm.branch_order();
    let run = |profile: ProfileMode| {
        let solver = Solver {
            node_limit: 1_500,
            guards: Some(guards.clone()),
            strategy: SearchStrategy::chronological().with_profile(profile),
            ..Default::default()
        };
        let r = solver.solve(&sm.model, &sm.objective, &bo, |_, _| {});
        (r.status, r.best.map(|(_, o)| o), r.stats.nodes, r.stats.propagations)
    };
    let linear = run(ProfileMode::Linear);
    let segtree = run(ProfileMode::SegTree);
    assert_eq!(linear, segtree, "L1 node-capped runs diverged between profile modes");
}

/// Solve one *presolved* staged (or unstaged) CP model with the given
/// search strategy; returns (status, best objective, kernel stats).
/// The presolved builders are the ones that run heavy-clique detection
/// and emit the redundant disjunctive constraint, so this is the
/// harness for the `--disjunctive` knob.
fn cp_solve_presolved_strategy(
    g: &Graph,
    budget: u64,
    staged: bool,
    strategy: SearchStrategy,
    node_limit: u64,
) -> (Status, Option<i64>, moccasin::cp::SearchStats) {
    let order = topological_order(g).unwrap();
    let c_v = vec![2usize; g.n()];
    let pre = Presolve::new(g, PresolveConfig::default());
    let sm = if staged {
        StagedModel::build_with(g, &order, budget, &c_v, &pre, None)
    } else {
        StagedModel::build_unstaged_with(g, &order, budget, &c_v, &pre)
    };
    let (bo, guards) = sm.branch_order();
    let solver = Solver { node_limit, guards: Some(guards), strategy, ..Default::default() };
    let r = solver.solve(&sm.model, &sm.objective, &bo, |_, _| {});
    (r.status, r.best.map(|(_, o)| o), r.stats)
}

/// A tiny fan-out graph whose first tensor dwarfs the rest: under any
/// budget near the no-remat peak, more than half the memory capacity
/// is taken by each copy of node 0, so heavy-clique detection is
/// *guaranteed* to fire and emit a disjunctive constraint over node
/// 0's interval copies. Keeps the disjunctive on/off sweep from
/// silently degenerating into the no-clique case on every instance.
fn dominant_tensor_graph() -> Graph {
    let edges: Vec<(NodeId, NodeId)> =
        vec![(0, 1), (0, 2), (0, 3), (1, 4), (2, 4), (3, 4)];
    Graph::from_edges(
        "dj-dominant",
        5,
        &edges,
        vec![3, 1, 1, 1, 2],
        vec![100, 6, 6, 6, 10],
    )
    .expect("dominant-tensor graph is a DAG")
}

#[test]
fn prop_edge_finding_preserves_optimum() {
    // Edge-finding is a *strengthening* of the timetable filter: it may
    // only remove values that cannot appear in any solution, so both
    // filtering modes must agree on status AND optimum everywhere.
    // Under the deterministic chronological strategy the stronger
    // filter can also never grow the tree. (Learned-search node counts
    // are deliberately not compared: VSIDS activities and restart
    // timing make them non-monotone in filtering strength.)
    let scale = prop_case_scale();
    for seed in 0..4 * scale {
        let n = 10 + 2 * (seed % 4) as usize;
        let g = random_layered(&format!("ef-rl{seed}"), n, 2 * n + 4, seed);
        let order = topological_order(&g).unwrap();
        let peak = g.peak_mem_no_remat(&order).unwrap();
        for frac in [0.85, 0.95] {
            let budget = (peak as f64 * frac) as u64;
            for strat in [SearchStrategy::chronological(), SearchStrategy::learned()] {
                let (s_tt, o_tt, st_tt) = cp_solve_strategy(
                    &g,
                    budget,
                    true,
                    strat.with_filtering(FilteringMode::Timetable),
                    400_000,
                );
                let (s_ef, o_ef, st_ef) = cp_solve_strategy(
                    &g,
                    budget,
                    true,
                    strat.with_filtering(FilteringMode::EdgeFinding),
                    400_000,
                );
                assert_eq!(
                    s_tt, s_ef,
                    "graph {} frac {frac} {strat:?}: status diverged",
                    g.name
                );
                assert_eq!(
                    o_tt, o_ef,
                    "graph {} frac {frac} {strat:?}: optimum diverged",
                    g.name
                );
                if strat == SearchStrategy::chronological() {
                    assert!(
                        st_ef.nodes <= st_tt.nodes,
                        "graph {} frac {frac}: edge-finding grew the chronological \
                         tree ({} vs {} nodes)",
                        g.name,
                        st_ef.nodes,
                        st_tt.nodes
                    );
                }
            }
        }
    }
    // unstaged model (AllDifferent + Cumulative) on a tiny instance
    let g = random_layered("ef-un", 7, 12, 99);
    let order = topological_order(&g).unwrap();
    let peak = g.peak_mem_no_remat(&order).unwrap();
    for strat in [SearchStrategy::chronological(), SearchStrategy::learned()] {
        let (s_tt, o_tt, _) = cp_solve_strategy(
            &g,
            peak,
            false,
            strat.with_filtering(FilteringMode::Timetable),
            400_000,
        );
        let (s_ef, o_ef, _) = cp_solve_strategy(
            &g,
            peak,
            false,
            strat.with_filtering(FilteringMode::EdgeFinding),
            400_000,
        );
        assert_eq!(s_tt, s_ef, "unstaged {strat:?}: status diverged");
        assert_eq!(o_tt, o_ef, "unstaged {strat:?}: optimum diverged");
    }
}

#[test]
fn prop_disjunctive_preserves_optimum() {
    // The disjunctive constraint emitted by heavy-clique detection is
    // redundant (implied by the cumulative it was extracted from), so
    // toggling its propagation must never change status or optimum.
    // When no clique was detected the model carries no disjunctive
    // propagator at all and the toggle must leave the tree
    // bit-identical — any node-count difference is a gating bug.
    let scale = prop_case_scale();
    let mut graphs: Vec<Graph> = vec![dominant_tensor_graph()];
    for seed in 0..4 * scale {
        let n = 10 + 2 * (seed % 4) as usize;
        graphs.push(random_layered(&format!("dj-rl{seed}"), n, 2 * n + 4, seed));
    }
    let mut pairs_seen = 0u64;
    for g in &graphs {
        let order = topological_order(g).unwrap();
        let peak = g.peak_mem_no_remat(&order).unwrap();
        for frac in [0.85, 0.95] {
            let budget = (peak as f64 * frac) as u64;
            for strat in [SearchStrategy::chronological(), SearchStrategy::learned()] {
                let (s_on, o_on, st_on) = cp_solve_presolved_strategy(
                    g,
                    budget,
                    true,
                    strat.with_disjunctive(true),
                    400_000,
                );
                let (s_off, o_off, st_off) = cp_solve_presolved_strategy(
                    g,
                    budget,
                    true,
                    strat.with_disjunctive(false),
                    400_000,
                );
                assert_eq!(
                    s_on, s_off,
                    "graph {} frac {frac} {strat:?}: status diverged",
                    g.name
                );
                assert_eq!(
                    o_on, o_off,
                    "graph {} frac {frac} {strat:?}: optimum diverged",
                    g.name
                );
                // detection happens at model build time, so both runs
                // see the same pair count regardless of the knob
                assert_eq!(
                    st_on.disj_pairs_detected, st_off.disj_pairs_detected,
                    "graph {} frac {frac}: detection depends on the knob",
                    g.name
                );
                pairs_seen += st_on.disj_pairs_detected;
                if st_on.disj_pairs_detected == 0 {
                    // no disjunctive propagator exists → the knob is
                    // inert and both runs must walk the same tree
                    assert_eq!(
                        st_on.nodes, st_off.nodes,
                        "graph {} frac {frac} {strat:?}: knob changed the tree \
                         with no disjunctive constraint in the model",
                        g.name
                    );
                    assert_eq!(st_on.disj_prunes, 0, "prunes without a propagator");
                }
            }
        }
    }
    // the hand-built dominant-tensor instance guarantees at least one
    // detected clique across the sweep — the on/off A/B above is never
    // vacuously exercising only the no-clique branch
    assert!(pairs_seen > 0, "no instance produced a heavy clique");
}

#[test]
fn prop_solve_ctx_reuse_matches_fresh() {
    // The solve-context arena (pooled kernel scratch stolen by each
    // engine and returned on recycle) must be behavior-invisible: a
    // solve on a context dirtied by *previous, differently-sized*
    // models must walk the identical tree as a solve on a fresh one.
    // Exact equality on (status, optimum, nodes, conflicts) — not just
    // the optimum — so a buffer that leaks state across solves (a
    // missed clear, a stale watch list, a no-good surviving its model)
    // shows up as a trace divergence even when it happens to keep the
    // answer right.
    let scale = prop_case_scale();
    let mut staged_graphs: Vec<Graph> = Vec::new();
    for seed in 0..4 * scale {
        let n = 10 + 2 * (seed % 4) as usize;
        staged_graphs.push(random_layered(&format!("ctx-rl{seed}"), n, 2 * n + 4, seed));
    }
    staged_graphs.push(cm_style("ctx-cm", 11, 22, 3, 64));
    // unstaged models (AllDifferent) stay tiny so they still exhaust
    let unstaged_graphs: Vec<Graph> = [99u64, 123]
        .iter()
        .map(|&seed| random_layered(&format!("ctx-un{seed}"), 7, 12, seed))
        .collect();
    for strat in [SearchStrategy::chronological(), SearchStrategy::learned()] {
        // ONE context per strategy sweep, reused across every graph and
        // both model shapes — maximally dirty by the end
        let mut ctx = SolveCtx::default();
        for staged in [true, false] {
            let graphs = if staged { &staged_graphs } else { &unstaged_graphs };
            for g in graphs {
                let order = topological_order(g).unwrap();
                let peak = g.peak_mem_no_remat(&order).unwrap();
                let budget = (peak as f64 * 0.9) as u64;
                let c_v = vec![2usize; g.n()];
                let sm = if staged {
                    StagedModel::build(g, &order, budget, &c_v)
                } else {
                    StagedModel::build_unstaged(g, &order, budget, &c_v)
                };
                let (bo, guards) = sm.branch_order();
                let solver = Solver {
                    node_limit: 400_000,
                    guards: Some(guards),
                    strategy: strat,
                    ..Default::default()
                };
                // fresh context (the compat path constructs its own)
                let fresh = solver.solve(&sm.model, &sm.objective, &bo, |_, _| {});
                // reused, dirty context
                let reused =
                    solver.solve_with_ctx(&sm.model, &sm.objective, &bo, |_, _| {}, &mut ctx);
                assert_eq!(
                    fresh.status, reused.status,
                    "graph {} {strat:?} staged={staged}: status diverged on reused ctx",
                    g.name
                );
                assert_eq!(
                    fresh.best.as_ref().map(|(_, o)| *o),
                    reused.best.as_ref().map(|(_, o)| *o),
                    "graph {} {strat:?} staged={staged}: optimum diverged on reused ctx",
                    g.name
                );
                assert_eq!(
                    (fresh.stats.nodes, fresh.stats.conflicts),
                    (reused.stats.nodes, reused.stats.conflicts),
                    "graph {} {strat:?} staged={staged}: reused ctx walked a different tree",
                    g.name
                );
                // close the pool loop the way the moccasin layer does
                if let Some((v, _)) = reused.best {
                    ctx.recycle_solution(v);
                }
            }
        }
    }
}

#[test]
fn prop_floor_is_lower_bound() {
    for g in &graphs() {
        let floor = g.working_set_floor();
        let order = topological_order(g).unwrap();
        let peak = g.peak_mem_no_remat(&order).unwrap();
        assert!(floor <= peak, "graph {}", g.name);
        // any solver result respects the floor
        let solver = MoccasinSolver { time_limit: Duration::from_secs(1), ..Default::default() };
        if let Some(best) = solver.solve(g, (peak as f64 * 0.85) as u64, None).best {
            assert!(best.eval.peak_mem >= floor, "graph {}", g.name);
        }
    }
}
