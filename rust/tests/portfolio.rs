//! Integration tests for the parallel portfolio coordinator and the
//! batched `solve_many` API.

use moccasin::coordinator::{
    solve_portfolio, Backend, Coordinator, PortfolioConfig, SolveRequest,
};
use moccasin::generators::random_layered;
use moccasin::graph::{topological_order, Graph};
use std::time::Duration;

/// Chain + long skip with heavy source. The topological order is
/// forced (it is a chain), so every portfolio member races on the same
/// staged model and the exact optimum — one remat of node 0, duration
/// 6 at budget 10 — is deterministic.
fn chain() -> Graph {
    Graph::from_edges(
        "c",
        5,
        &[(0, 1), (1, 2), (2, 3), (3, 4), (0, 4)],
        vec![1; 5],
        vec![5, 4, 4, 4, 1],
    )
    .unwrap()
}

#[test]
fn two_thread_portfolio_matches_serial_exact_optimum() {
    let g = chain();

    // serial exact solve through the coordinator
    let mut coord = Coordinator::new();
    let serial = coord.solve(
        &g,
        &SolveRequest { budget: 10, time_limit: Duration::from_secs(20), ..Default::default() },
    );
    let serial_sol = serial.solution.expect("serial solve feasible");
    assert!(serial.proved_optimal, "5-node graph must be proved optimal");

    // deterministic 2-thread race on the same request
    let cfg = PortfolioConfig {
        threads: 2,
        time_limit: Duration::from_secs(20),
        ..Default::default()
    };
    let race = solve_portfolio(&g, 10, None, &cfg);
    let race_sol = race.solution.expect("portfolio feasible");

    assert_eq!(
        race_sol.eval.duration, serial_sol.eval.duration,
        "portfolio must return the same optimum as the serial exact solve"
    );
    assert!(race_sol.eval.peak_mem <= 10);
    assert!(race.proved_optimal, "the exact member's proof must surface");
    // kernel statistics must aggregate across members (and the serial
    // solve must report its own)
    assert!(serial.stats.propagations > 0, "serial response missing kernel stats");
    assert!(race.stats.propagations > 0, "portfolio response missing kernel stats");
}

#[test]
fn portfolio_backend_through_coordinator_is_cached() {
    let g = chain();
    let mut coord = Coordinator::new();
    coord.threads = 2;
    let req = SolveRequest {
        budget: 10,
        time_limit: Duration::from_secs(20),
        backend: Backend::Portfolio,
        ..Default::default()
    };
    let a = coord.solve(&g, &req);
    assert!(!a.from_cache);
    assert_eq!(a.solution.as_ref().unwrap().eval.duration, 6);
    let b = coord.solve(&g, &req);
    assert!(b.from_cache, "portfolio responses are cached like serial ones");
    assert_eq!(b.solution.unwrap().eval.duration, 6);
}

#[test]
fn portfolio_feasible_on_medium_graph() {
    // rl-class graph above the exact threshold: the race is LNS-driven;
    // the result must be feasible and the merged trace monotone.
    let g = random_layered("t", 60, 150, 3);
    let order = topological_order(&g).unwrap();
    let peak = g.peak_mem_no_remat(&order).unwrap();
    let budget = (peak as f64 * 0.85) as u64;
    let cfg = PortfolioConfig {
        threads: 2,
        time_limit: Duration::from_secs(4),
        include_checkmate: false,
        ..Default::default()
    };
    let resp = solve_portfolio(&g, budget, None, &cfg);
    let sol = resp.solution.expect("feasible at 85%");
    assert!(sol.eval.peak_mem <= budget);
    let durs: Vec<u64> = resp.trace.iter().map(|&(_, d)| d).collect();
    assert!(
        durs.windows(2).all(|w| w[1] < w[0]),
        "merged trace must be strictly improving: {durs:?}"
    );
    assert_eq!(
        durs.last().copied(),
        Some(sol.eval.duration),
        "trace must end at the returned solution"
    );
}

#[test]
fn solve_many_dedups_within_and_across_batches() {
    let g = chain();
    let g2 = random_layered("t2", 30, 70, 1);
    let order = topological_order(&g2).unwrap();
    let peak2 = g2.peak_mem_no_remat(&order).unwrap();
    let mut coord = Coordinator::new();
    let mk = |budget: u64| SolveRequest {
        budget,
        time_limit: Duration::from_secs(5),
        ..Default::default()
    };

    // batch: 6 requests over two graphs, 3 unique keys
    let batch = vec![
        (&g, mk(10)),
        (&g, mk(13)),
        (&g, mk(10)),
        (&g2, mk(peak2)),
        (&g2, mk(peak2)),
        (&g, mk(13)),
    ];
    let responses = coord.solve_many(&batch);
    assert_eq!(responses.len(), 6);
    assert_eq!(coord.misses, 3, "3 unique keys → 3 solves");
    assert_eq!(coord.hits, 3, "3 duplicates answered from the batch dedup");
    assert!(responses[2].from_cache && responses[4].from_cache && responses[5].from_cache);
    // duplicates agree with their originals
    assert_eq!(
        responses[0].solution.as_ref().unwrap().eval.duration,
        responses[2].solution.as_ref().unwrap().eval.duration
    );
    assert_eq!(
        responses[1].solution.as_ref().unwrap().eval.duration,
        responses[5].solution.as_ref().unwrap().eval.duration
    );

    // a second batch over the same keys is served entirely from cache
    let again = coord.solve_many(&batch);
    assert!(again.iter().all(|r| r.from_cache));
    assert_eq!(coord.misses, 3, "no new solves");
}

#[test]
fn solve_many_budget_sweep_matches_serial_results() {
    // the sweep shape the CLI uses: one graph, several budgets — the
    // parallel path must return exactly what serial solves return
    // (durations are deterministic on a proved-optimal-size graph)
    let g = chain();
    let budgets = [10u64, 11, 12, 13];
    let requests: Vec<(&Graph, SolveRequest)> = budgets
        .iter()
        .map(|&b| {
            let req = SolveRequest {
                budget: b,
                time_limit: Duration::from_secs(10),
                ..Default::default()
            };
            (&g, req)
        })
        .collect();
    let mut par = Coordinator::new();
    let parallel = par.solve_many(&requests);

    let mut ser = Coordinator::new();
    for (i, (graph, req)) in requests.iter().enumerate() {
        let s = ser.solve(graph, req);
        assert_eq!(
            s.solution.map(|x| x.eval.duration),
            parallel[i].solution.as_ref().map(|x| x.eval.duration),
            "budget {} disagrees",
            budgets[i]
        );
    }
}
