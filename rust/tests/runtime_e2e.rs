//! Integration: PJRT runtime + executor against real artifacts.
//! Skipped (pass trivially) when `artifacts/` has not been built.

use moccasin::executor::{train_with_remat, TrainConfig};
use moccasin::runtime::{HostTensor, Runtime};

fn have_artifacts() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

#[test]
fn block_fwd_runs_and_is_finite() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut rt = Runtime::new("artifacts").unwrap();
    let (b, s, d, dff) = (8usize, 64usize, 128usize, 512usize);
    let x = HostTensor::zeros_f32(&[b, s, d]);
    let mk = |sh: &[usize]| HostTensor::F32 {
        shape: sh.to_vec(),
        data: (0..sh.iter().product::<usize>()).map(|i| ((i % 17) as f32 - 8.0) * 1e-2).collect(),
    };
    let (wqkv, wo, w1, w2) = (mk(&[d, 3 * d]), mk(&[d, d]), mk(&[d, dff]), mk(&[dff, d]));
    let exe = rt.load("block_fwd").unwrap();
    let out = exe.run(&[&x, &wqkv, &wo, &w1, &w2]).unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].num_elements(), b * s * d);
    assert!(out[0].as_f32().iter().all(|v| v.is_finite()));
}

#[test]
fn short_training_run_respects_budget_and_learns() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let cfg = TrainConfig { blocks: 4, steps: 30, lr: 0.05, budget_frac: 0.6, seed: 1 };
    let r = train_with_remat("artifacts", 256, 128, 512, 64, 8, &cfg).unwrap();
    assert!(r.peak_pool_bytes <= r.budget_bytes);
    assert!(r.remat_count >= 1, "0.6x budget must force remat");
    let first = r.losses[0];
    let last = *r.losses.last().unwrap();
    assert!(last < first, "loss should decrease: {first} -> {last}");
}
