//! `bench compare`: the CI perf ratchet.
//!
//! Diffs two bench JSON files (the previous run's uploaded artifact vs
//! the file the current build just emitted) record by record and fails
//! on kernel-throughput regressions, so a change that silently costs
//! >10% of `nodes_per_sec` or `propagations_per_sec` turns the build
//! red instead of accumulating unnoticed. Records are matched by a
//! composite identity key (instance / profile / filtering / search
//! strategy / serve mode+concurrency — whichever fields the file
//! carries), so the solver, large-graph and serve benches all compare
//! through the same code path.
//!
//! Design points:
//!
//! * **Versioned envelope.** Every `BENCH_*.json` is
//!   `{"schema_version": N, "records": [...]}`; the comparator refuses
//!   (exit 2, explicit message) to diff files with a missing or
//!   mismatched version — including the pre-envelope top-level-array
//!   format — instead of producing a silently wrong comparison.
//! * **Noise floor.** Throughput ratios over tiny workloads are
//!   meaningless: a metric is reported as `noise` (never a failure)
//!   unless both sides cleared a minimum event count *and* wall time.
//!   A quick CI smoke therefore ratchets only what it measured
//!   credibly; skipped metrics are listed, never silently dropped.
//! * **`--warn-only`.** Demotes every failure to a loud warning with
//!   exit 0 — the smoke-test mode. The nightly deep bench runs strict.
//!
//! Exit codes: 0 = no credible regression (or `--warn-only`),
//! 1 = regression beyond the threshold, 2 = not comparable (missing
//! file, parse error, schema mismatch).

use crate::serve::json::{parse, Json};
use std::fmt::Write as _;
use std::path::Path;

/// Version stamped into every `BENCH_*.json` envelope by
/// [`super::bench_envelope`]. Bump when a record field the comparator
/// reads changes meaning.
pub(crate) const SCHEMA_VERSION: u64 = 1;

/// Ratcheted metrics: `(field, gating count field, minimum count)`.
/// A comparison is credible only when both sides report at least the
/// minimum count — a handful of nodes in a 50ms solve says nothing
/// about kernel throughput.
const METRICS: [(&str, &str, f64); 3] = [
    ("nodes_per_sec", "nodes", 1_000.0),
    ("propagations_per_sec", "propagations", 20_000.0),
    ("throughput_rps", "requests", 16.0),
];

/// Wall-time noise floor: below this, per-second rates are dominated by
/// startup effects regardless of the event counts.
const MIN_WALL_S: f64 = 0.2;

/// Outcome of one (record, metric) comparison.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Verdict {
    /// Within the threshold band either way.
    Ok,
    /// Faster than baseline by more than the threshold.
    Improved,
    /// Slower than baseline by more than the threshold.
    Regression,
    /// Workload too small on at least one side — skipped, reported.
    Noise,
}

impl Verdict {
    fn name(self) -> &'static str {
        match self {
            Verdict::Ok => "ok",
            Verdict::Improved => "improved",
            Verdict::Regression => "REGRESSION",
            Verdict::Noise => "noise (skipped)",
        }
    }
}

/// One compared metric of one matched record pair.
pub(crate) struct MetricDelta {
    /// Composite record identity (`instance=G1,search=learned`, ...).
    pub key: String,
    /// Metric field name.
    pub metric: &'static str,
    /// Baseline value.
    pub baseline: f64,
    /// Current value.
    pub current: f64,
    /// Comparison outcome under the configured threshold.
    pub verdict: Verdict,
}

/// Composite identity of a bench record: every identity-bearing field
/// the three emitters use, in a fixed order. Metrics fields never
/// appear here, so a perf change can never unmatch a record.
fn record_key(r: &Json) -> String {
    let mut parts: Vec<String> = Vec::new();
    for k in ["instance", "mode", "profile", "filtering"] {
        if let Some(s) = r.get(k).and_then(Json::as_str) {
            parts.push(format!("{k}={s}"));
        }
    }
    // solver-json nests the strategy ({"search": {"strategy": ...}}),
    // large-json carries it flat ({"search": "chronological"})
    match r.get("search") {
        Some(Json::Str(s)) => parts.push(format!("search={s}")),
        Some(obj @ Json::Obj(_)) => {
            if let Some(s) = obj.get("strategy").and_then(Json::as_str) {
                parts.push(format!("search={s}"));
            }
        }
        _ => {}
    }
    if let Some(c) = r.get("concurrency").and_then(Json::as_u64) {
        parts.push(format!("concurrency={c}"));
    }
    parts.join(",")
}

/// Unwrap the versioned envelope, rejecting anything the comparator
/// cannot interpret *by name* — a wrong-but-parsing comparison is worse
/// than a refused one.
fn envelope_records(doc: &Json, label: &str) -> Result<&[Json], String> {
    match doc {
        Json::Arr(_) => Err(format!(
            "{label}: top-level array with no schema_version envelope — this file \
             predates the versioned bench format; regenerate it with the current \
             binary (first CI run after the format change: delete the stale \
             baseline artifact or pass --warn-only)"
        )),
        Json::Obj(_) => {
            let ver = doc
                .get("schema_version")
                .and_then(Json::as_u64)
                .ok_or_else(|| format!("{label}: missing/non-integer schema_version"))?;
            if ver != SCHEMA_VERSION {
                return Err(format!(
                    "{label}: schema_version {ver}, but this binary compares version \
                     {SCHEMA_VERSION} — regenerate the older side"
                ));
            }
            match doc.get("records") {
                Some(Json::Arr(rs)) => Ok(rs),
                _ => Err(format!("{label}: missing \"records\" array")),
            }
        }
        _ => Err(format!("{label}: expected a JSON object envelope")),
    }
}

/// Compare two parsed bench documents. Current records with no
/// baseline counterpart (new instance, renamed variant) are skipped —
/// a ratchet can only hold ground it has already measured.
pub(crate) fn compare_docs(
    base: &Json,
    cur: &Json,
    threshold_pct: f64,
) -> Result<Vec<MetricDelta>, String> {
    let base_rs = envelope_records(base, "baseline")?;
    let cur_rs = envelope_records(cur, "current")?;
    let lo = 1.0 - threshold_pct / 100.0;
    let hi = 1.0 + threshold_pct / 100.0;
    let mut out = Vec::new();
    for cr in cur_rs {
        let key = record_key(cr);
        let Some(br) = base_rs.iter().find(|r| record_key(r) == key) else {
            continue;
        };
        for (metric, count_field, min_count) in METRICS {
            let (Some(b), Some(c)) = (
                br.get(metric).and_then(Json::as_f64),
                cr.get(metric).and_then(Json::as_f64),
            ) else {
                continue;
            };
            let credible = |r: &Json| {
                r.get(count_field).and_then(Json::as_f64).is_some_and(|n| n >= min_count)
                    && r.get("wall_s").and_then(Json::as_f64).map_or(true, |w| w >= MIN_WALL_S)
            };
            let verdict = if !credible(br) || !credible(cr) {
                Verdict::Noise
            } else if b > 0.0 && c < b * lo {
                Verdict::Regression
            } else if b > 0.0 && c > b * hi {
                Verdict::Improved
            } else {
                Verdict::Ok
            };
            out.push(MetricDelta { key: key.clone(), metric, baseline: b, current: c, verdict });
        }
    }
    Ok(out)
}

/// Render the comparison report (printed to stdout and uploaded as a CI
/// artifact).
fn render_report(
    baseline: &Path,
    current: &Path,
    threshold_pct: f64,
    deltas: &[MetricDelta],
) -> String {
    let mut s = String::new();
    let _ = writeln!(
        s,
        "perf ratchet: {} vs {} (threshold {threshold_pct:.0}%)",
        baseline.display(),
        current.display()
    );
    if deltas.is_empty() {
        let _ = writeln!(s, "  no matching records — nothing to ratchet");
    }
    for d in deltas {
        let ratio = if d.baseline > 0.0 { d.current / d.baseline } else { f64::NAN };
        let _ = writeln!(
            s,
            "  [{}] {} {}: {:.1} -> {:.1} ({:.2}x)",
            d.verdict.name(),
            d.key,
            d.metric,
            d.baseline,
            d.current,
            ratio
        );
    }
    let regressions = deltas.iter().filter(|d| d.verdict == Verdict::Regression).count();
    let noise = deltas.iter().filter(|d| d.verdict == Verdict::Noise).count();
    let _ = writeln!(
        s,
        "  summary: {} compared, {regressions} regression(s), {noise} below the noise floor",
        deltas.len()
    );
    s
}

/// The `bench compare` entry point: load, compare, report, and return
/// the process exit code (0 ok / 1 regression / 2 not comparable;
/// `warn_only` demotes both failures to warnings with exit 0). The
/// report is also written to `report_path` so CI can upload it.
pub fn bench_compare(
    baseline: &Path,
    current: &Path,
    threshold_pct: f64,
    warn_only: bool,
    report_path: &Path,
) -> i32 {
    let fail = |msg: String| -> i32 {
        if warn_only {
            println!("WARNING (--warn-only, not failing the build): {msg}");
            0
        } else {
            eprintln!("bench compare: {msg}");
            2
        }
    };
    let load = |p: &Path, label: &str| -> Result<Json, String> {
        let text = std::fs::read_to_string(p)
            .map_err(|e| format!("{label} {p:?} unreadable: {e}"))?;
        parse(&text).map_err(|e| format!("{label} {p:?}: {e}"))
    };
    let (base, cur) = match (load(baseline, "baseline"), load(current, "current")) {
        (Ok(b), Ok(c)) => (b, c),
        (Err(e), _) | (_, Err(e)) => return fail(e),
    };
    let deltas = match compare_docs(&base, &cur, threshold_pct) {
        Ok(d) => d,
        Err(e) => return fail(e),
    };
    let report = render_report(baseline, current, threshold_pct, &deltas);
    print!("{report}");
    if let Err(e) = std::fs::write(report_path, &report) {
        eprintln!("warning: could not write {report_path:?}: {e}");
    } else {
        println!("  [report] {}", report_path.display());
    }
    let regressions = deltas.iter().filter(|d| d.verdict == Verdict::Regression).count();
    if regressions == 0 {
        0
    } else if warn_only {
        println!(
            "WARNING (--warn-only, not failing the build): {regressions} throughput \
             regression(s) beyond {threshold_pct:.0}%"
        );
        0
    } else {
        eprintln!(
            "bench compare: {regressions} throughput regression(s) beyond {threshold_pct:.0}%"
        );
        1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn doc(records: &str) -> Json {
        parse(&format!("{{\"schema_version\": 1, \"records\": [{records}]}}")).unwrap()
    }

    fn solver_record(nodes: u64, nps: f64, props: u64, pps: f64) -> String {
        format!(
            "{{\"instance\": \"G1\", \"wall_s\": 2.0, \"nodes\": {nodes}, \
             \"propagations\": {props}, \"nodes_per_sec\": {nps:.1}, \
             \"propagations_per_sec\": {pps:.1}, \
             \"search\": {{\"strategy\": \"learned\"}}}}"
        )
    }

    #[test]
    fn regression_beyond_threshold_is_flagged() {
        let base = doc(&solver_record(100_000, 50_000.0, 1_000_000, 500_000.0));
        // nodes/sec down 20%, props/sec flat
        let cur = doc(&solver_record(100_000, 40_000.0, 1_000_000, 500_000.0));
        let d = compare_docs(&base, &cur, 10.0).unwrap();
        assert_eq!(d.len(), 2);
        assert_eq!(d[0].metric, "nodes_per_sec");
        assert_eq!(d[0].verdict, Verdict::Regression);
        assert_eq!(d[1].verdict, Verdict::Ok);
    }

    #[test]
    fn small_changes_and_improvements_pass() {
        let base = doc(&solver_record(100_000, 50_000.0, 1_000_000, 500_000.0));
        // 5% dip is inside the band; 30% gain reports as improved
        let cur = doc(&solver_record(100_000, 47_500.0, 1_000_000, 650_000.0));
        let d = compare_docs(&base, &cur, 10.0).unwrap();
        assert_eq!(d[0].verdict, Verdict::Ok);
        assert_eq!(d[1].verdict, Verdict::Improved);
        assert!(d.iter().all(|x| x.verdict != Verdict::Regression));
    }

    #[test]
    fn tiny_workloads_fall_below_the_noise_floor() {
        // a 50-node run can halve its nodes/sec without meaning anything
        let base = doc(&solver_record(50, 50_000.0, 500, 500_000.0));
        let cur = doc(&solver_record(50, 25_000.0, 500, 100_000.0));
        let d = compare_docs(&base, &cur, 10.0).unwrap();
        assert!(d.iter().all(|x| x.verdict == Verdict::Noise), "all skipped as noise");
    }

    #[test]
    fn records_match_by_identity_not_position() {
        let base = doc(&format!(
            "{},{}",
            solver_record(100_000, 50_000.0, 1_000_000, 500_000.0),
            "{\"instance\": \"G2\", \"wall_s\": 2.0, \"nodes\": 100000, \
             \"propagations\": 1000000, \"nodes_per_sec\": 10000.0, \
             \"propagations_per_sec\": 100000.0, \
             \"search\": {\"strategy\": \"learned\"}}"
        ));
        // current lists G2 first; G2 regressed, G1 did not
        let cur = doc(&format!(
            "{},{}",
            "{\"instance\": \"G2\", \"wall_s\": 2.0, \"nodes\": 100000, \
             \"propagations\": 1000000, \"nodes_per_sec\": 5000.0, \
             \"propagations_per_sec\": 100000.0, \
             \"search\": {\"strategy\": \"learned\"}}",
            solver_record(100_000, 50_000.0, 1_000_000, 500_000.0)
        ));
        let d = compare_docs(&base, &cur, 10.0).unwrap();
        let g2 = d.iter().find(|x| x.key.contains("G2") && x.metric == "nodes_per_sec");
        let g1 = d.iter().find(|x| x.key.contains("G1") && x.metric == "nodes_per_sec");
        assert_eq!(g2.unwrap().verdict, Verdict::Regression);
        assert_eq!(g1.unwrap().verdict, Verdict::Ok);
    }

    #[test]
    fn schema_mismatch_and_legacy_format_are_refused() {
        let good = doc(&solver_record(100_000, 1.0, 1_000_000, 1.0));
        let old_array = parse("[{\"instance\": \"G1\"}]").unwrap();
        let e = compare_docs(&old_array, &good, 10.0).unwrap_err();
        assert!(e.contains("schema_version"), "unhelpful error: {e}");
        let future = parse("{\"schema_version\": 99, \"records\": []}").unwrap();
        let e = compare_docs(&good, &future, 10.0).unwrap_err();
        assert!(e.contains("99"), "should name the offending version: {e}");
        let missing = parse("{\"records\": []}").unwrap();
        assert!(compare_docs(&missing, &good, 10.0).is_err());
    }

    #[test]
    fn new_instances_have_nothing_to_ratchet() {
        let base = doc(&solver_record(100_000, 50_000.0, 1_000_000, 500_000.0));
        let cur = doc(
            "{\"instance\": \"G9\", \"wall_s\": 2.0, \"nodes\": 100000, \
             \"propagations\": 1000000, \"nodes_per_sec\": 1.0, \
             \"propagations_per_sec\": 1.0, \"search\": {\"strategy\": \"learned\"}}",
        );
        assert!(compare_docs(&base, &cur, 10.0).unwrap().is_empty());
    }

    #[test]
    fn large_bench_variants_keep_distinct_keys() {
        let rec = |profile: &str, nps: f64| {
            format!(
                "{{\"instance\": \"L1\", \"profile\": \"{profile}\", \
                 \"filtering\": \"timetable\", \"search\": \"chronological\", \
                 \"wall_s\": 5.0, \"nodes\": 200000, \"propagations\": 5000000, \
                 \"nodes_per_sec\": {nps:.1}, \"propagations_per_sec\": 1000000.0}}"
            )
        };
        let base = doc(&format!("{},{}", rec("segtree", 40_000.0), rec("linear", 10_000.0)));
        let cur = doc(&format!("{},{}", rec("segtree", 40_000.0), rec("linear", 2_000.0)));
        let d = compare_docs(&base, &cur, 10.0).unwrap();
        let lin = d
            .iter()
            .find(|x| x.key.contains("profile=linear") && x.metric == "nodes_per_sec")
            .unwrap();
        let seg = d
            .iter()
            .find(|x| x.key.contains("profile=segtree") && x.metric == "nodes_per_sec")
            .unwrap();
        assert_eq!(lin.verdict, Verdict::Regression);
        assert_eq!(seg.verdict, Verdict::Ok);
    }

    #[test]
    fn end_to_end_exit_codes() {
        let dir = std::env::temp_dir().join(format!("bench_cmp_{}", std::process::id()));
        let _ = std::fs::create_dir_all(&dir);
        let base_p = dir.join("base.json");
        let cur_p = dir.join("cur.json");
        let rep_p = dir.join("report.txt");
        let envelope = |r: &str| format!("{{\"schema_version\": 1, \"records\": [{r}]}}");
        std::fs::write(
            &base_p,
            envelope(&solver_record(100_000, 50_000.0, 1_000_000, 500_000.0)),
        )
        .unwrap();
        // regression fixture: nonzero strict, zero with --warn-only
        std::fs::write(
            &cur_p,
            envelope(&solver_record(100_000, 30_000.0, 1_000_000, 500_000.0)),
        )
        .unwrap();
        assert_eq!(bench_compare(&base_p, &cur_p, 10.0, false, &rep_p), 1);
        assert_eq!(bench_compare(&base_p, &cur_p, 10.0, true, &rep_p), 0);
        let report = std::fs::read_to_string(&rep_p).unwrap();
        assert!(report.contains("REGRESSION"), "{report}");
        // noise fixture: inside the band, exit 0
        std::fs::write(
            &cur_p,
            envelope(&solver_record(100_000, 48_000.0, 1_000_000, 510_000.0)),
        )
        .unwrap();
        assert_eq!(bench_compare(&base_p, &cur_p, 10.0, false, &rep_p), 0);
        // missing baseline: not comparable
        assert_eq!(bench_compare(&dir.join("nope.json"), &cur_p, 10.0, false, &rep_p), 2);
        assert_eq!(bench_compare(&dir.join("nope.json"), &cur_p, 10.0, true, &rep_p), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn emitted_envelope_parses_and_compares_clean_against_itself() {
        let records =
            vec![solver_record(100_000, 50_000.0, 1_000_000, 500_000.0)];
        let text = super::super::bench_envelope(&records);
        let v = parse(&text).unwrap();
        let d = compare_docs(&v, &v, 10.0).unwrap();
        assert_eq!(d.len(), 2);
        assert!(d.iter().all(|x| x.verdict == Verdict::Ok));
    }
}
