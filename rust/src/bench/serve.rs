//! `bench serve-json`: a load generator for the serving tier, emitting
//! `BENCH_serve.json`.
//!
//! Sweeps client concurrency against a solver service and reports, per
//! level: p50/p99/max end-to-end latency of served requests, delivered
//! throughput, shed/expired/failed/retry counts, cache hits, and —
//! load-bearing for the robustness claim — that **every submitted
//! request received exactly one terminal** (the bench hangs, and CI
//! with it, if one doesn't; it errors if counts disagree). Runs either
//! in-process (default: starts its own [`SolverService`]) or against a
//! live daemon over its Unix socket (`--socket PATH`), exercising the
//! full NDJSON wire path. Arm `MOCCASIN_FAILPOINTS` (e.g.
//! `serve.worker=panic*3;serve.session=delay(150)*2`) to measure the
//! same sweep under injected worker deaths and stalls — the CI smoke
//! does exactly that.

use crate::serve::{ServeConfig, ServeEvent, ServeRequest, SolverService, Terminal};
use crate::util::Context as _;
use std::fmt::Write as _;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// Per-request observation: outcome class, end-to-end latency, cache.
struct Obs {
    outcome: &'static str,
    latency: Duration,
    from_cache: bool,
}

fn percentile(sorted_ms: &[f64], p: f64) -> f64 {
    if sorted_ms.is_empty() {
        return 0.0;
    }
    let idx = ((sorted_ms.len() as f64 - 1.0) * p).round() as usize;
    sorted_ms[idx.min(sorted_ms.len() - 1)]
}

/// The request mix: small random-layered instances, `distinct` unique
/// seeds cycled across the batch so repeats exercise the shared cache.
fn request_mix(total: usize, distinct: usize, deadline: Duration) -> Vec<ServeRequest> {
    let graphs: Vec<Arc<crate::graph::Graph>> = (0..distinct)
        .map(|s| {
            Arc::new(crate::generators::random_layered(
                &format!("serve-{s}"),
                40,
                90,
                s as u64 + 1,
            ))
        })
        .collect();
    (0..total)
        .map(|i| {
            let g = Arc::clone(&graphs[i % distinct]);
            let order = crate::graph::topological_order(&g).unwrap();
            let peak = g.peak_mem_no_remat(&order).unwrap();
            ServeRequest {
                deadline,
                ..ServeRequest::new(g, (peak as f64 * 0.85) as u64)
            }
        })
        .collect()
}

/// Drive one concurrency level against an in-process service. Returns
/// one observation per submitted request — the exactly-one-terminal
/// invariant made measurable.
fn run_level_inprocess(
    svc: &SolverService,
    requests: Vec<ServeRequest>,
) -> crate::util::Result<Vec<Obs>> {
    let mut waiters = Vec::with_capacity(requests.len());
    for req in requests {
        let (tx, rx) = mpsc::channel::<ServeEvent>();
        let t0 = Instant::now();
        svc.submit(req, tx);
        waiters.push((t0, rx));
    }
    let mut obs = Vec::with_capacity(waiters.len());
    for (t0, rx) in waiters {
        // a terminal MUST arrive for every submit; a hang here is a
        // service bug and the bench (deliberately) fails with it
        let outcome = loop {
            let ev = rx
                .recv_timeout(Duration::from_secs(120))
                .ok()
                .context("request hung: no terminal within 120s — invariant broken")?;
            if let ServeEvent::Terminal { outcome, .. } = ev {
                break outcome;
            }
        };
        let from_cache = match &outcome {
            Terminal::Solved(r) => r.from_cache,
            _ => false,
        };
        obs.push(Obs { outcome: outcome.name(), latency: t0.elapsed(), from_cache });
    }
    Ok(obs)
}

/// Drive one concurrency level against a live daemon: one connection
/// per request, full NDJSON round trip.
#[cfg(unix)]
fn run_level_socket(
    socket: &std::path::Path,
    n_requests: usize,
    distinct: usize,
    deadline: Duration,
) -> crate::util::Result<Vec<Obs>> {
    use crate::serve::json::Json;
    use std::io::{BufRead, BufReader, Write as _};
    use std::os::unix::net::UnixStream;
    let mut joins = Vec::new();
    for i in 0..n_requests {
        let socket = socket.to_path_buf();
        let deadline_ms = deadline.as_millis() as u64;
        joins.push(std::thread::spawn(move || -> Result<Obs, String> {
            let mut stream =
                UnixStream::connect(&socket).map_err(|e| format!("connect {socket:?}: {e}"))?;
            stream
                .set_read_timeout(Some(Duration::from_secs(120)))
                .map_err(|e| e.to_string())?;
            let line = format!(
                "{{\"graph\":\"rl:40:90:{}\",\"budget_frac\":0.85,\
                 \"deadline_ms\":{deadline_ms},\"tag\":\"r{i}\"}}\n",
                i % distinct + 1
            );
            let t0 = Instant::now();
            stream.write_all(line.as_bytes()).map_err(|e| e.to_string())?;
            let reader = BufReader::new(stream);
            for line in reader.lines() {
                let line = line.map_err(|e| format!("read: {e} (no terminal — hang?)"))?;
                let v = crate::serve::json::parse(&line)?;
                if v.get("event").and_then(Json::as_str) == Some("terminal") {
                    let outcome = match v.get("outcome").and_then(Json::as_str) {
                        Some("solved") => "solved",
                        Some("preempted") => "preempted",
                        Some("cancelled") => "cancelled",
                        Some("overloaded") => "overloaded",
                        Some("expired") => "expired",
                        _ => "failed",
                    };
                    let from_cache =
                        v.get("from_cache").and_then(Json::as_bool).unwrap_or(false);
                    return Ok(Obs { outcome, latency: t0.elapsed(), from_cache });
                }
            }
            Err("connection closed before terminal".to_string())
        }));
    }
    let mut obs = Vec::new();
    for j in joins {
        let o = j
            .join()
            .map_err(|_| crate::util::Error::msg("socket client panicked"))?
            .map_err(crate::util::Error::msg)?;
        obs.push(o);
    }
    Ok(obs)
}

#[cfg(not(unix))]
fn run_level_socket(
    _socket: &std::path::Path,
    _n_requests: usize,
    _distinct: usize,
    _deadline: Duration,
) -> crate::util::Result<Vec<Obs>> {
    Err(crate::util::Error::msg("--socket requires a unix platform"))
}

/// The `bench serve-json` entry point. `socket` switches from the
/// in-process service to a live daemon.
pub fn bench_serve_json(
    quick: bool,
    socket: Option<&std::path::Path>,
) -> crate::util::Result<()> {
    // re-arm from MOCCASIN_FAILPOINTS (fault injection only exists
    // under its gate; the bench runs clean without it)
    #[cfg(any(test, feature = "failpoints"))]
    crate::util::failpoint::reset();
    let levels: &[usize] = if quick { &[4, 16] } else { &[4, 16, 64] };
    let deadline = Duration::from_secs(if quick { 10 } else { 20 });
    let workers = 2;
    let queue_cap = 16;
    println!(
        "== serving-tier load sweep (BENCH_serve.json, {} mode, workers={workers}, \
         queue_cap={queue_cap}) ==",
        if socket.is_some() { "socket" } else { "in-process" }
    );
    let mut records = Vec::new();
    for &level in levels {
        let distinct = (level / 2).max(2);
        let t_level = Instant::now();
        let (obs, retries, deaths) = match socket {
            Some(path) => {
                let obs = run_level_socket(path, level, distinct, deadline)?;
                // daemon-side counters are not visible over the wire
                (obs, None, None)
            }
            None => {
                let svc = SolverService::start(ServeConfig {
                    workers,
                    queue_cap,
                    ..Default::default()
                });
                let obs = run_level_inprocess(&svc, request_mix(level, distinct, deadline))?;
                let s = svc.stats();
                if s.submitted
                    != s.solved + s.preempted + s.cancelled + s.shed + s.expired + s.failed
                {
                    return Err(crate::util::Error::msg(format!(
                        "terminal ledger disagrees with submissions: {s:?}"
                    )));
                }
                svc.shutdown();
                (obs, Some(s.retries), Some(s.worker_deaths))
            }
        };
        let wall = t_level.elapsed().as_secs_f64();
        let mut by_class: std::collections::BTreeMap<&str, usize> = Default::default();
        for o in &obs {
            *by_class.entry(o.outcome).or_insert(0) += 1;
        }
        let solved = by_class.get("solved").copied().unwrap_or(0);
        let shed = by_class.get("overloaded").copied().unwrap_or(0);
        let cache_hits = obs.iter().filter(|o| o.from_cache).count();
        let mut served_ms: Vec<f64> = obs
            .iter()
            .filter(|o| o.outcome == "solved" || o.outcome == "preempted")
            .map(|o| o.latency.as_secs_f64() * 1000.0)
            .collect();
        served_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let (p50, p99) = (percentile(&served_ms, 0.50), percentile(&served_ms, 0.99));
        let max_ms = served_ms.last().copied().unwrap_or(0.0);
        let throughput = solved as f64 / wall.max(1e-9);
        let shed_rate = shed as f64 / obs.len().max(1) as f64;
        println!(
            "  level {level:>3}: {solved} solved ({cache_hits} cached), {shed} shed, \
             p50 {p50:.1}ms p99 {p99:.1}ms, {throughput:.1} solved/s, \
             retries {} deaths {}",
            retries.map(|r| r.to_string()).unwrap_or_else(|| "n/a".into()),
            deaths.map(|d| d.to_string()).unwrap_or_else(|| "n/a".into()),
        );
        let mut classes = String::new();
        for (k, v) in &by_class {
            let _ = write!(classes, "{}\"{k}\": {v}", if classes.is_empty() { "" } else { ", " });
        }
        records.push(format!(
            "  {{\n    \"mode\": \"{}\",\n    \"concurrency\": {level},\n    \
             \"requests\": {},\n    \"workers\": {workers},\n    \
             \"queue_cap\": {queue_cap},\n    \"deadline_ms\": {},\n    \
             \"outcomes\": {{{classes}}},\n    \"cache_hits\": {cache_hits},\n    \
             \"p50_ms\": {p50:.2},\n    \"p99_ms\": {p99:.2},\n    \
             \"max_ms\": {max_ms:.2},\n    \"throughput_rps\": {throughput:.2},\n    \
             \"shed_rate\": {shed_rate:.4},\n    \"retries\": {},\n    \
             \"worker_deaths\": {},\n    \"wall_s\": {wall:.3}\n  }}",
            if socket.is_some() { "socket" } else { "in-process" },
            obs.len(),
            deadline.as_millis(),
            retries.map(|r| r.to_string()).unwrap_or_else(|| "null".into()),
            deaths.map(|d| d.to_string()).unwrap_or_else(|| "null".into()),
        ));
    }
    let json = super::bench_envelope(&records);
    let path = std::path::Path::new("BENCH_serve.json");
    std::fs::write(path, &json).with_context(|| format!("could not write {path:?}"))?;
    println!("  [json] {}", path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_are_order_statistics() {
        let ms = [1.0, 2.0, 3.0, 4.0, 100.0];
        assert_eq!(percentile(&ms, 0.5), 3.0);
        assert_eq!(percentile(&ms, 0.99), 100.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
    }

    #[test]
    fn request_mix_cycles_distinct_graphs() {
        let reqs = request_mix(6, 2, Duration::from_secs(5));
        assert_eq!(reqs.len(), 6);
        // repeats share the same Arc'd graph (cache-hit fodder)
        assert!(Arc::ptr_eq(&reqs[0].graph, &reqs[2].graph));
        assert!(!Arc::ptr_eq(&reqs[0].graph, &reqs[1].graph));
        assert!(reqs[0].budget > 0);
    }
}
