//! Benchmark harness: regenerates every table and figure of the paper's
//! evaluation (§3). Each function prints the series/rows the paper
//! reports and writes a CSV under `results/`. Absolute solve times are
//! not comparable to the paper's 16-core workstation runs (our CP
//! substrate is in-tree, not CP-SAT/Gurobi — see DESIGN.md
//! "Substitutions"); the *shape* — who wins, who times out, where
//! feasibility breaks — is the reproduction target.

mod compare;
mod serve;

pub use compare::bench_compare;
pub use serve::bench_serve_json;

use crate::coordinator::{Backend, Coordinator, SolveRequest};
use crate::cp::{FilteringMode, ProfileMode, SearchStrategy, Solver};
use crate::generators::{paper_graph, random_layered, rw2, LARGE_GRAPHS, PAPER_GRAPHS};
use crate::graph::{random_topological_order, topological_order, Graph};
use crate::moccasin::{MoccasinSolver, StagedModel};
use crate::presolve::{Presolve, PresolveConfig, PresolveStats};
use crate::util::{Context as _, Deadline, Rng};
use std::fmt::Write as _;
use std::time::{Duration, Instant};

/// Look up a named paper/large-tier instance, reporting an unknown name
/// as a `util::error` instead of a process abort (every bench target
/// resolves graphs through this).
pub(crate) fn graph(name: &str) -> crate::util::Result<Graph> {
    paper_graph(name).with_context(|| {
        format!("unknown graph {name:?} (known: {PAPER_GRAPHS:?} and {LARGE_GRAPHS:?})")
    })
}

fn results_dir() -> std::path::PathBuf {
    let d = std::path::PathBuf::from("results");
    let _ = std::fs::create_dir_all(&d);
    d
}

fn write_csv(name: &str, contents: &str) {
    let path = results_dir().join(name);
    if let Err(e) = std::fs::write(&path, contents) {
        eprintln!("warning: could not write {path:?}: {e}");
    } else {
        println!("  [csv] {}", path.display());
    }
}

/// Wrap bench records in the versioned envelope every `BENCH_*.json`
/// emitter shares: `{"schema_version": N, "records": [...]}`. The
/// `bench compare` ratchet validates the version on both sides and
/// refuses (exit 2, clear message) to diff files whose versions
/// disagree — bump [`compare::SCHEMA_VERSION`] whenever a record field
/// the comparator reads changes meaning.
pub(crate) fn bench_envelope(records: &[String]) -> String {
    format!(
        "{{\n\"schema_version\": {},\n\"records\": [\n{}\n]\n}}\n",
        compare::SCHEMA_VERSION,
        records.join(",\n")
    )
}

fn budget_at(g: &Graph, frac: f64) -> u64 {
    let order = topological_order(g).unwrap();
    let peak = g.peak_mem_no_remat(&order).unwrap();
    ((peak as f64) * frac) as u64
}

/// Figure 1: solve-progress (TDI % vs time) on the RW2-class graph
/// (n=442, m=1247) at an 80% budget, MOCCASIN vs CHECKMATE.
pub fn fig1(time_limit: Duration) -> crate::util::Result<()> {
    println!("== Figure 1: solve progress, RW2 (442, 1247), M = 80% ==");
    let g = rw2();
    let budget = budget_at(&g, 0.8);
    let base = g.total_duration() as f64;
    let mut csv = String::from("method,elapsed_s,tdi_percent\n");
    let mut coord = Coordinator::new();
    for (name, backend) in
        [("moccasin", Backend::Moccasin), ("checkmate", Backend::CheckmateMilp)]
    {
        let resp = coord.solve(
            &g,
            &SolveRequest { budget, time_limit, backend, ..Default::default() },
        );
        println!("-- {name}: {} improving solutions", resp.trace.len());
        for (t, dur) in &resp.trace {
            let tdi = 100.0 * (*dur as f64 - base) / base;
            println!("   t={:>8.2}s  TDI={tdi:.2}%", t.as_secs_f64());
            let _ = writeln!(csv, "{name},{:.3},{tdi:.4}", t.as_secs_f64());
        }
        if resp.trace.is_empty() {
            println!("   (no solution within {time_limit:?} — {:?})", resp.error);
            let _ = writeln!(csv, "{name},,");
        }
    }
    write_csv("fig1.csv", &csv);
    Ok(())
}

/// Figure 5: progress curves for RL G1–G4 under several budgets. The
/// whole (graph × budget × method) grid is dispatched as one batch
/// through [`Coordinator::solve_many`], so rows solve in parallel
/// across the worker pool.
pub fn fig5(time_limit: Duration, quick: bool) -> crate::util::Result<()> {
    println!("== Figure 5: solve progress, random layered G1..G4 ==");
    let names: &[&str] = if quick { &["G1", "G2"] } else { &["G1", "G2", "G3", "G4"] };
    let fracs: &[f64] = if quick { &[0.9, 0.8] } else { &[0.95, 0.9, 0.85, 0.8] };
    let graphs: Vec<Graph> =
        names.iter().map(|n| graph(n)).collect::<crate::util::Result<_>>()?;
    let mut requests: Vec<(&Graph, SolveRequest)> = Vec::new();
    let mut meta: Vec<(usize, f64, &str)> = Vec::new();
    for (gi, g) in graphs.iter().enumerate() {
        for &frac in fracs {
            let budget = budget_at(g, frac);
            for (mname, backend) in
                [("moccasin", Backend::Moccasin), ("checkmate", Backend::CheckmateMilp)]
            {
                requests
                    .push((g, SolveRequest { budget, time_limit, backend, ..Default::default() }));
                meta.push((gi, frac, mname));
            }
        }
    }
    let mut coord = Coordinator::new();
    let responses = coord.solve_many(&requests);
    let mut csv = String::from("graph,budget_frac,method,elapsed_s,tdi_percent\n");
    for (k, resp) in responses.iter().enumerate() {
        let (gi, frac, mname) = meta[k];
        let name = names[gi];
        let base = graphs[gi].total_duration() as f64;
        let last = resp
            .trace
            .last()
            .map(|(t, d)| {
                format!(
                    "TDI {:.2}% @ {:.2}s",
                    100.0 * (*d as f64 - base) / base,
                    t.as_secs_f64()
                )
            })
            .unwrap_or_else(|| "no solution".into());
        println!("  {name} M={frac:.2} {mname:9}: {last}");
        for (t, d) in &resp.trace {
            let _ = writeln!(
                csv,
                "{name},{frac},{mname},{:.3},{:.4}",
                t.as_secs_f64(),
                100.0 * (*d as f64 - base) / base
            );
        }
    }
    write_csv("fig5.csv", &csv);
    Ok(())
}

/// Parallel budget sweep through [`Coordinator::solve_many`]: eight
/// budgets per graph dispatched across the worker pool at once —
/// the batched path the `sweep` CLI subcommand uses. Reports wall-clock
/// against a serial estimate (per-request solve times summed).
pub fn sweep_parallel(time_limit: Duration, quick: bool) -> crate::util::Result<()> {
    println!("== Parallel budget sweep (Coordinator::solve_many) ==");
    let names: &[&str] = if quick { &["G1"] } else { &["G1", "RW1", "CM2"] };
    let fracs = [0.95, 0.9, 0.85, 0.8, 0.75, 0.7, 0.65, 0.6];
    let mut csv =
        String::from("graph,budget_frac,tdi_percent,remats,proved_optimal,feasible\n");
    for &name in names {
        let g = graph(name)?;
        let base = g.total_duration() as f64;
        let requests: Vec<(&Graph, SolveRequest)> = fracs
            .iter()
            .map(|&f| {
                (
                    &g,
                    SolveRequest {
                        budget: budget_at(&g, f),
                        time_limit,
                        ..Default::default()
                    },
                )
            })
            .collect();
        let mut coord = Coordinator::new();
        let t0 = Instant::now();
        let responses = coord.solve_many(&requests);
        let wall = t0.elapsed().as_secs_f64();
        // serial estimate: proved-optimal solves end at their last
        // improvement; anytime solves run the full limit
        let serial_est: f64 = responses
            .iter()
            .map(|r| {
                if r.proved_optimal {
                    r.trace.last().map(|(t, _)| t.as_secs_f64()).unwrap_or(0.1)
                } else {
                    time_limit.as_secs_f64()
                }
            })
            .sum();
        for (i, resp) in responses.iter().enumerate() {
            match &resp.solution {
                Some(sol) => {
                    let tdi = 100.0 * (sol.eval.duration as f64 - base) / base;
                    println!(
                        "  {name} M={:.2}: TDI {tdi:6.2}%  ({} remats, optimal={})",
                        fracs[i], sol.eval.remat_count, resp.proved_optimal
                    );
                    let _ = writeln!(
                        csv,
                        "{name},{},{tdi:.4},{},{},1",
                        fracs[i],
                        sol.eval.remat_count,
                        u8::from(resp.proved_optimal)
                    );
                }
                None => {
                    println!("  {name} M={:.2}: no solution", fracs[i]);
                    let _ = writeln!(csv, "{name},{},,,{},0", fracs[i], 0);
                }
            }
        }
        println!(
            "  {name}: {} budgets in {wall:.2}s wall (serial estimate {serial_est:.2}s, \
             {:.1}x)",
            fracs.len(),
            serial_est / wall.max(1e-9)
        );
    }
    write_csv("sweep.csv", &csv);
    Ok(())
}

/// Figure 6: time-to-best-solution vs n (log-log), M = 90%.
pub fn fig6(time_limit: Duration, quick: bool) -> crate::util::Result<()> {
    println!("== Figure 6: time to best solution vs n (M = 90%) ==");
    let sizes: &[(usize, usize)] = if quick {
        &[(25, 55), (50, 115), (100, 236), (175, 600)]
    } else {
        &[(25, 55), (50, 115), (100, 236), (175, 600), (250, 944), (500, 2461), (1000, 5875)]
    };
    let mut csv = String::from("n,m,method,time_to_best_s,tdi_percent,found\n");
    let mut coord = Coordinator::new();
    for &(n, m) in sizes {
        let g = random_layered(&format!("rl{n}"), n, m, n as u64);
        let base = g.total_duration() as f64;
        let budget = budget_at(&g, 0.9);
        for (mname, backend) in
            [("moccasin", Backend::Moccasin), ("checkmate", Backend::CheckmateMilp)]
        {
            let resp = coord.solve(
                &g,
                &SolveRequest { budget, time_limit, backend, ..Default::default() },
            );
            match resp.trace.last() {
                Some((t, d)) => {
                    let tdi = 100.0 * (*d as f64 - base) / base;
                    println!(
                        "  n={n:5} {mname:9}: best at {:.2}s (TDI {tdi:.2}%)",
                        t.as_secs_f64()
                    );
                    let _ = writeln!(csv, "{n},{m},{mname},{:.3},{tdi:.4},1", t.as_secs_f64());
                }
                None => {
                    println!("  n={n:5} {mname:9}: no solution within {time_limit:?}");
                    let _ = writeln!(csv, "{n},{m},{mname},,,0");
                }
            }
        }
    }
    write_csv("fig6.csv", &csv);
    Ok(())
}

/// Table 1: formulation complexity — actual variable/constraint counts
/// from both model builders across n.
pub fn table1() {
    println!("== Table 1: formulation sizes (measured, C = 2) ==");
    println!(
        "{:>6} {:>8} | {:>12} {:>12} {:>12} | {:>14} {:>14}",
        "n", "m", "mocc #bool", "mocc #int", "mocc #cons", "cm #bool", "cm #cons"
    );
    let mut csv =
        String::from(
            "n,m,moccasin_bools,moccasin_ints,moccasin_cons,checkmate_bools,checkmate_cons\n",
        );
    for &(n, m) in &[(25usize, 55usize), (50, 115), (100, 236), (250, 944), (500, 2461)] {
        let g = random_layered(&format!("rl{n}"), n, m, n as u64);
        let order = topological_order(&g).unwrap();
        let budget = budget_at(&g, 0.9);
        let sm = StagedModel::build(&g, &order, budget, &vec![2; g.n()]);
        let (mb, mi, mc) = sm.complexity();
        let (cb, cc) = crate::checkmate::formulation_size(&g, &order, budget);
        println!(
            "{n:>6} {m:>8} | {mb:>12} {mi:>12} {mc:>12} | {cb:>14} {cc:>14}"
        );
        let _ = writeln!(csv, "{n},{m},{mb},{mi},{mc},{cb},{cc}");
    }
    write_csv("table1.csv", &csv);
}

/// Table 2/3: TDI %, peak memory and time-to-best for the three methods
/// on the paper's instances at 80% and 90% budgets.
pub fn table2(time_limit: Duration, quick: bool) -> crate::util::Result<()> {
    println!("== Table 2/3: all methods on all paper instances ==");
    let names: &[&str] = if quick {
        &["G1", "G2", "RW1", "CM1"]
    } else {
        &["G1", "G2", "G3", "G4", "RW1", "RW2", "RW3", "RW4", "CM1", "CM2"]
    };
    println!(
        "{:<5} {:>11} | {:>8} {:>11} {:>8} | {:>8} {:>11} {:>8} | {:>8} {:>11} {:>8}",
        "graph", "M", "cmTDI%", "cmPeak", "cmT(s)", "lpTDI%", "lpPeak", "lpT(s)", "moTDI%",
        "moPeak", "moT(s)"
    );
    let mut csv = String::from(
        "graph,n,m,budget,method,tdi_percent,peak_mem,time_s,feasible\n",
    );
    let mut coord = Coordinator::new();
    for &name in names {
        let g = graph(name)?;
        let base = g.total_duration() as f64;
        for frac in [0.9, 0.8] {
            let budget = budget_at(&g, frac);
            let mut cells: Vec<String> = Vec::new();
            for (mname, backend) in [
                ("checkmate_milp", Backend::CheckmateMilp),
                ("lp_rounding", Backend::CheckmateLpRounding),
                ("moccasin", Backend::Moccasin),
            ] {
                let resp = coord.solve(
                    &g,
                    &SolveRequest { budget, time_limit, backend, ..Default::default() },
                );
                match (&resp.solution, resp.trace.last()) {
                    (Some(sol), last) => {
                        let t = last.map(|(t, _)| t.as_secs_f64()).unwrap_or(0.0);
                        let tdi = 100.0 * (sol.eval.duration as f64 - base) / base;
                        let feas = sol.eval.peak_mem <= budget;
                        cells.push(format!(
                            "{tdi:>8.1} {:>11} {t:>8.1}",
                            crate::util::fmt_u64(sol.eval.peak_mem)
                        ));
                        let _ = writeln!(
                            csv,
                            "{name},{},{},{budget},{mname},{tdi:.3},{},{t:.2},{}",
                            g.n(),
                            g.m(),
                            sol.eval.peak_mem,
                            u8::from(feas)
                        );
                    }
                    _ => {
                        cells.push(format!("{:>8} {:>11} {:>8}", "-", "-", "-"));
                        let _ = writeln!(
                            csv,
                            "{name},{},{},{budget},{mname},,,,0",
                            g.n(),
                            g.m()
                        );
                    }
                }
            }
            println!(
                "{name:<5} {:>11} | {} | {} | {}",
                crate::util::fmt_u64(budget),
                cells[0],
                cells[1],
                cells[2]
            );
        }
    }
    write_csv("table2.csv", &csv);
    Ok(())
}

/// C_v ablation (§3 / contribution 2): solution quality vs C.
pub fn ablation_c(time_limit: Duration) -> crate::util::Result<()> {
    println!("== Ablation: max rematerializations per node C ==");
    let g = graph("G1")?;
    let base = g.total_duration() as f64;
    let budget = budget_at(&g, 0.8);
    // Note: C binds the CP model (exact / window re-solves). The
    // Phase-1 planner and removal polish are C-oblivious, so we report
    // the *achieved* max per-node interval count alongside — the paper's
    // finding (C=2 suffices) shows as achieved-C rarely exceeding 2.
    let mut csv = String::from("c,tdi_percent,remats,achieved_max_c,feasible\n");
    for c in 1..=4usize {
        let solver = MoccasinSolver { c, time_limit, ..Default::default() };
        let out = solver.solve(&g, budget, None);
        match out.best {
            Some(sol) => {
                let tdi = 100.0 * (sol.eval.duration as f64 - base) / base;
                let achieved = crate::moccasin::solution::intervals_per_node(&g, &sol.seq)
                    .into_iter()
                    .max()
                    .unwrap_or(0);
                println!(
                    "  C={c}: TDI {tdi:.2}%  ({} remats, achieved max C = {achieved})",
                    sol.eval.remat_count
                );
                let _ = writeln!(csv, "{c},{tdi:.4},{},{achieved},1", sol.eval.remat_count);
            }
            None => {
                println!("  C={c}: infeasible");
                let _ = writeln!(csv, "{c},,,,0");
            }
        }
    }
    write_csv("ablation_c.csv", &csv);
    Ok(())
}

/// Input-topological-order ablation (§1.1): peak-memory variability
/// across 50 random topological orders per graph.
pub fn ablation_topo() -> crate::util::Result<()> {
    println!("== Ablation: peak memory across 50 random topological orders ==");
    let mut csv = String::from("graph,min_peak,median_peak,max_peak,spread_percent\n");
    for name in ["G1", "G2", "RW1", "CM1"] {
        let g = graph(name)?;
        let mut rng = Rng::seed_from_u64(7);
        let mut peaks: Vec<u64> = (0..50)
            .map(|_| {
                let o = random_topological_order(&g, &mut rng);
                g.peak_mem_no_remat(&o).unwrap()
            })
            .collect();
        peaks.sort_unstable();
        let (mn, md, mx) = (peaks[0], peaks[25], peaks[49]);
        let spread = 100.0 * (mx as f64 - mn as f64) / mn as f64;
        println!("  {name}: min {mn}, median {md}, max {mx}  (spread {spread:.1}%)");
        let _ = writeln!(csv, "{name},{mn},{md},{mx},{spread:.2}");
    }
    write_csv("ablation_topo.csv", &csv);
    Ok(())
}

/// Per-instance presolve effect, measured statically: build the raw and
/// the presolved staged model side by side and compare formulation
/// sizes. Returns the presolved model's counters (the raw build is only
/// used to cross-check them).
fn presolve_effect(g: &Graph, budget: u64) -> PresolveStats {
    let order = topological_order(g).unwrap();
    let c_v = vec![2usize; g.n()];
    let raw = StagedModel::build(g, &order, budget, &c_v);
    let pre = StagedModel::build_with(
        g,
        &order,
        budget,
        &c_v,
        &Presolve::new(g, PresolveConfig::default()),
        None,
    );
    debug_assert_eq!(pre.presolve.props_before, raw.model.num_constraints() as u64);
    pre.presolve
}

/// Machine-readable kernel benchmark: solve the Figure-5-style
/// instances (random layered G1..G4 at a 90% budget) with the full
/// MOCCASIN stack under the given search strategy and emit
/// `BENCH_solver.json` — one record per instance with wall time,
/// nodes/sec, propagations/sec, the engine's event counters, the
/// search-strategy counter block (restarts, no-goods learned/pruned,
/// database reductions), the presolve counter block (raw vs
/// compacted formulation sizes) and the degradation/resilience block
/// (ladder rung, absorbed failures, per-phase wall spend, watchdog and
/// retry counters — see `docs/BENCHMARKS.md`) — so the kernel's perf
/// trajectory can
/// be tracked across commits and the two strategies A/B-compared (the
/// CI smoke-bench step runs the quick variant once per strategy on
/// every push and uploads both files).
pub fn bench_solver_json(
    time_limit: Duration,
    quick: bool,
    search: SearchStrategy,
) -> crate::util::Result<()> {
    println!("== solver kernel bench (BENCH_solver.json, search={}) ==", search.name());
    let names: &[&str] = if quick { &["G1", "G2"] } else { &["G1", "G2", "G3", "G4"] };
    let mut records: Vec<String> = Vec::new();
    for &name in names {
        let g = graph(name)?;
        let budget = budget_at(&g, 0.9);
        let pe = presolve_effect(&g, budget);
        let solver = MoccasinSolver { time_limit, search, ..Default::default() };
        let t0 = Instant::now();
        let out = solver.solve(&g, budget, None);
        let wall = t0.elapsed().as_secs_f64();
        let st = out.stats;
        let nodes_per_sec = st.nodes as f64 / wall.max(1e-9);
        let props_per_sec = st.propagations as f64 / wall.max(1e-9);
        println!(
            "  {name}: {:.2}s wall, {} nodes ({:.0}/s), {} propagations ({:.0}/s), \
             {} events, {} wakeups skipped, {} cum resyncs",
            wall,
            st.nodes,
            nodes_per_sec,
            st.propagations,
            props_per_sec,
            st.events_posted,
            st.wakeups_skipped,
            st.cum_resyncs
        );
        println!(
            "  {name} search[{}]: {} conflicts, {} restarts, {} nogoods learned, \
             {} nogood prunings, {} db reductions",
            search.name(),
            st.conflicts,
            st.restarts,
            st.nogoods_learned,
            st.nogoods_pruned,
            st.db_reductions
        );
        println!(
            "  {name} presolve: propagators {} -> {} ({:.1}% fewer), domains {} -> {} \
             ({:.1}% smaller), {} copies deactivated, {} vars fixed, {} redundant edges",
            pe.props_before,
            pe.props_after,
            pe.props_reduction_pct(),
            pe.domain_before,
            pe.domain_after,
            pe.domain_shrink_pct(),
            pe.copies_deactivated,
            pe.vars_fixed,
            pe.edges_redundant
        );
        records.push(format!(
            "  {{\n    \"instance\": \"{name}\",\n    \"n\": {},\n    \"m\": {},\n    \
             \"budget_frac\": 0.9,\n    \"wall_s\": {wall:.4},\n    \"nodes\": {},\n    \
             \"propagations\": {},\n    \"events_posted\": {},\n    \
             \"wakeups_skipped\": {},\n    \"cum_resyncs\": {},\n    \
             \"cum_rebuilds\": {},\n    \"nodes_per_sec\": {nodes_per_sec:.1},\n    \
             \"propagations_per_sec\": {props_per_sec:.1},\n    \
             \"best_duration\": {},\n    \"proved_optimal\": {},\n    \
             \"degradation\": {},\n    \
             \"resilience\": {{\"lock_recoveries\": {}, \"watchdog_kills\": {}, \
             \"member_panics\": {}, \"member_retries\": {}}},\n    \
             \"search\": {{\n      \"strategy\": \"{}\",\n      \"conflicts\": {},\n      \
             \"restarts\": {},\n      \"nogoods_learned\": {},\n      \
             \"nogoods_pruned\": {},\n      \"db_reductions\": {}\n    }},\n    \
             \"presolve\": {{\n      \"props_before\": {},\n      \"props_after\": {},\n      \
             \"props_reduction_pct\": {:.2},\n      \"domain_before\": {},\n      \
             \"domain_after\": {},\n      \"domain_shrink_pct\": {:.2},\n      \
             \"copies_deactivated\": {},\n      \"vars_fixed\": {},\n      \
             \"edges_redundant\": {},\n      \"edges_removed\": {}\n    }}\n  }}",
            g.n(),
            g.m(),
            st.nodes,
            st.propagations,
            st.events_posted,
            st.wakeups_skipped,
            st.cum_resyncs,
            st.cum_rebuilds,
            out.best.as_ref().map(|b| b.eval.duration as i64).unwrap_or(-1),
            out.proved_optimal,
            out.degradation.to_json(),
            st.lock_recoveries,
            st.watchdog_kills,
            st.member_panics,
            st.member_retries,
            search.name(),
            st.conflicts,
            st.restarts,
            st.nogoods_learned,
            st.nogoods_pruned,
            st.db_reductions,
            pe.props_before,
            pe.props_after,
            pe.props_reduction_pct(),
            pe.domain_before,
            pe.domain_after,
            pe.domain_shrink_pct(),
            pe.copies_deactivated,
            pe.vars_fixed,
            pe.edges_redundant,
            pe.edges_removed
        ));
    }
    let json = bench_envelope(&records);
    let path = std::path::Path::new("BENCH_solver.json");
    if let Err(e) = std::fs::write(path, &json) {
        eprintln!("warning: could not write {path:?}: {e}");
    } else {
        println!("  [json] {}", path.display());
    }
    Ok(())
}

/// Large-graph kernel bench (`bench large-json`): time-bounded,
/// node-capped staged B&B on the `L1..L4` tier (n ∈ {1000, 2500, 5000,
/// 10000} — the "especially for large-scale graphs" regime of the
/// paper's headline claim), run once per cumulative timetable-profile
/// mode, emitting `BENCH_large.json`.
///
/// Unlike `bench solver-json` (which drives the anytime stack), this
/// bench runs a *fixed workload*: the presolved staged model is built
/// once per instance and the same node-capped chronological B&B runs
/// under each variant of the engine-knob grid —
/// `(segtree, timetable)`, `(segtree, edge-finding)` and
/// `(linear, timetable)` — so `propagations_per_sec` is a clean
/// segtree-vs-linear A/B (those two walk the identical tree — the
/// property suite proves query-value equivalence) and `nodes` is the
/// filtering nodes-to-proof A/B (edge-finding may walk a *smaller*
/// tree; the property suite proves the optimum is unchanged). The
/// strategy is *always* chronological: under learned search the
/// variants need not walk comparable trees (different overload
/// witnesses can yield different no-goods), which would silently
/// invalidate both ratios — so unlike the other bench targets,
/// `--search` does not apply here. Each record carries nodes/sec,
/// propagations/sec, the engine event and filtering counters, peak RSS
/// (`VmHWM`, 0 where procfs is unavailable), the profile mode and the
/// filtering mode. `quick` runs L1 only (the CI smoke configuration);
/// `xl` adds L4 to the default L1–L3 grid.
pub fn bench_large_json(
    time_limit: Duration,
    quick: bool,
    xl: bool,
) -> crate::util::Result<()> {
    let search = SearchStrategy::chronological();
    println!(
        "== large-graph kernel bench (BENCH_large.json, search={}, {:?} per mode) ==",
        search.name(),
        time_limit,
    );
    let names: &[&str] = if quick {
        &LARGE_GRAPHS[..1]
    } else if xl {
        &LARGE_GRAPHS[..]
    } else {
        &LARGE_GRAPHS[..3]
    };
    const NODE_CAP: u64 = 200_000;
    let mut records: Vec<String> = Vec::new();
    for &name in names {
        let g = graph(name)?;
        let order = topological_order(&g).context("large-tier instance must be a DAG")?;
        let peak = g
            .peak_mem_no_remat(&order)
            .context("canonical order must evaluate")?;
        let budget = (peak as f64 * 0.9) as u64; // the paper's 90% ratio
        let pre = Presolve::new(&g, PresolveConfig::default());
        let t_build = Instant::now();
        let sm = StagedModel::build_with(&g, &order, budget, &vec![2; g.n()], &pre, None);
        let build_s = t_build.elapsed().as_secs_f64();
        let (bo, guards) = sm.branch_order();
        println!(
            "  {name}: n={} m={} budget={} — model built in {build_s:.2}s \
             ({} vars, {} propagators)",
            g.n(),
            g.m(),
            crate::util::fmt_u64(budget),
            sm.model.num_vars(),
            sm.model.num_constraints()
        );
        // variant 0 vs 2: segtree/linear throughput A/B (identical tree)
        // variant 0 vs 1: timetable/edge-finding nodes-to-proof A/B
        const VARIANTS: [(ProfileMode, FilteringMode); 3] = [
            (ProfileMode::SegTree, FilteringMode::Timetable),
            (ProfileMode::SegTree, FilteringMode::EdgeFinding),
            (ProfileMode::Linear, FilteringMode::Timetable),
        ];
        let mut props_per_sec_of = [0.0f64; VARIANTS.len()];
        let mut mode_runs: Vec<(
            ProfileMode,
            FilteringMode,
            f64,
            crate::cp::SearchStats,
            Option<i64>,
            String,
        )> = Vec::new();
        for (mi, (mode, filtering)) in VARIANTS.into_iter().enumerate() {
            let solver = Solver {
                deadline: Deadline::after(time_limit),
                node_limit: NODE_CAP,
                guards: Some(guards.clone()),
                strategy: search.with_profile(mode).with_filtering(filtering),
                ..Default::default()
            };
            let t0 = Instant::now();
            let r = solver.solve(&sm.model, &sm.objective, &bo, |_, _| {});
            let wall = t0.elapsed().as_secs_f64();
            let st = r.stats;
            let nodes_per_sec = st.nodes as f64 / wall.max(1e-9);
            let props_per_sec = st.propagations as f64 / wall.max(1e-9);
            props_per_sec_of[mi] = props_per_sec;
            println!(
                "  {name} [{:7}/{:12}]: {wall:6.2}s wall, {} nodes ({nodes_per_sec:.0}/s), \
                 {} propagations ({props_per_sec:.0}/s), {} resyncs, {} rebuilds, \
                 {} ef-prunes, {} disj-prunes",
                mode.name(),
                filtering.name(),
                st.nodes,
                st.propagations,
                st.cum_resyncs,
                st.cum_rebuilds,
                st.ef_prunes,
                st.disj_prunes,
            );
            mode_runs.push((
                mode,
                filtering,
                wall,
                st,
                r.best.as_ref().map(|&(_, o)| o),
                format!("{:?}", r.status),
            ));
        }
        // VmHWM is a process-lifetime high-water mark (monotone), so it
        // is sampled ONCE per instance after both mode runs and shared
        // by both records: instances run in ascending size, which keeps
        // per-instance scaling meaningful — it is deliberately NOT a
        // per-mode memory A/B (both modes share the same model anyway)
        let rss = crate::util::peak_rss_kb().unwrap_or(0);
        for (mode, filtering, wall, st, best, status) in &mode_runs {
            let nodes_per_sec = st.nodes as f64 / wall.max(1e-9);
            let props_per_sec = st.propagations as f64 / wall.max(1e-9);
            records.push(format!(
                "  {{\n    \"instance\": \"{name}\",\n    \"n\": {},\n    \"m\": {},\n    \
                 \"budget\": {budget},\n    \"budget_frac\": 0.9,\n    \
                 \"profile\": \"{}\",\n    \"filtering\": \"{}\",\n    \
                 \"search\": \"{}\",\n    \
                 \"build_s\": {build_s:.4},\n    \"wall_s\": {wall:.4},\n    \
                 \"node_cap\": {NODE_CAP},\n    \"nodes\": {},\n    \
                 \"propagations\": {},\n    \"conflicts\": {},\n    \
                 \"events_posted\": {},\n    \"wakeups_skipped\": {},\n    \
                 \"cum_resyncs\": {},\n    \"cum_rebuilds\": {},\n    \
                 \"ef_prunes\": {},\n    \"disj_prunes\": {},\n    \
                 \"disj_pairs_detected\": {},\n    \
                 \"nodes_per_sec\": {nodes_per_sec:.1},\n    \
                 \"propagations_per_sec\": {props_per_sec:.1},\n    \
                 \"best_objective\": {},\n    \"status\": \"{status}\",\n    \
                 \"peak_rss_kb\": {rss}\n  }}",
                g.n(),
                g.m(),
                mode.name(),
                filtering.name(),
                search.name(),
                st.nodes,
                st.propagations,
                st.conflicts,
                st.events_posted,
                st.wakeups_skipped,
                st.cum_resyncs,
                st.cum_rebuilds,
                st.ef_prunes,
                st.disj_prunes,
                st.disj_pairs_detected,
                best.unwrap_or(-1),
            ));
        }
        if props_per_sec_of[2] > 0.0 {
            println!(
                "  {name}: segtree/linear propagation throughput = {:.2}x \
                 (instance peak RSS {} kB)",
                props_per_sec_of[0] / props_per_sec_of[2],
                crate::util::fmt_u64(rss)
            );
        }
        // nodes-to-proof A/B: how much smaller is the edge-finding tree
        // on the same fixed workload? (valid whether or not either side
        // finished — both run under the same node cap and deadline)
        let (tt_nodes, ef_nodes) = (mode_runs[0].3.nodes, mode_runs[1].3.nodes);
        if ef_nodes > 0 {
            println!(
                "  {name}: timetable/edge-finding nodes-to-proof = {:.2}x \
                 ({tt_nodes} vs {ef_nodes} nodes, {} ef-prunes)",
                tt_nodes as f64 / ef_nodes as f64,
                mode_runs[1].3.ef_prunes
            );
        }
    }
    let json = bench_envelope(&records);
    let path = std::path::Path::new("BENCH_large.json");
    std::fs::write(path, &json).with_context(|| format!("could not write {path:?}"))?;
    println!("  [json] {}", path.display());
    Ok(())
}

/// Run everything (the `bench all` CLI path); `search` selects the
/// kernel strategy for the solver-json record. The large tier is not
/// part of `all` — it has its own time budget (`bench large-json`).
pub fn run_all(
    time_limit: Duration,
    quick: bool,
    search: SearchStrategy,
) -> crate::util::Result<()> {
    table1();
    ablation_topo()?;
    fig1(time_limit)?;
    fig5(time_limit, quick)?;
    fig6(time_limit, quick)?;
    table2(time_limit, quick)?;
    sweep_parallel(time_limit, true)?;
    ablation_c(time_limit)?;
    bench_solver_json(time_limit, quick, search)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_at_fraction() {
        let g = random_layered("t", 50, 115, 1);
        let b9 = budget_at(&g, 0.9);
        let b8 = budget_at(&g, 0.8);
        assert!(b8 < b9);
    }

    #[test]
    fn table1_runs() {
        // smoke: no panics, csv written
        table1();
    }

    #[test]
    fn ablation_topo_runs() {
        ablation_topo().unwrap();
    }

    #[test]
    fn unknown_graph_name_is_a_reported_error_not_a_panic() {
        let e = graph("nope").unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("nope") && msg.contains("L4"), "unhelpful error: {msg}");
    }

    #[test]
    fn presolve_effect_meets_acceptance_on_quick_instances() {
        // the Figure-5 acceptance bar: ≥ 20% fewer propagators and a
        // strictly smaller summed domain size, per instance (G3/G4 are
        // covered by the same arithmetic — every reduction scales with
        // n and m — and by the full `bench solver-json` run)
        for name in ["G1", "G2"] {
            let g = paper_graph(name).unwrap();
            let pe = presolve_effect(&g, budget_at(&g, 0.9));
            assert!(
                pe.props_after as f64 <= 0.8 * pe.props_before as f64,
                "{name}: propagator reduction below 20% ({} -> {})",
                pe.props_before,
                pe.props_after
            );
            assert!(
                pe.domain_after < pe.domain_before,
                "{name}: domains did not shrink"
            );
        }
    }
}
