//! Degradation provenance: what failed during a solve and which
//! fallback produced the answer.
//!
//! The solve pipeline never lets a contained failure (panic, spurious
//! timeout, watchdog kill, window error) take down the caller — it
//! falls back down a ladder of cheaper strategies (learned →
//! chronological → LNS-from-greedy → greedy-only) and returns the best
//! incumbent it has. That is only acceptable if degraded answers are
//! *visibly* degraded: a [`Degradation`] value travels with every
//! [`SolveOutcome`](super::SolveOutcome) / `SolveResponse`, recording
//! the ladder rung that answered, every failure absorbed along the way,
//! retry counts, and wall-clock spend per pipeline phase, and is
//! surfaced by `solve --verbose` and the bench JSONs.

use std::time::Duration;

/// The ladder rung (strategy tier) that produced the final answer.
/// Rungs are ordered strongest-first; a solve that absorbs a failure
/// falls to the next rung down.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rung {
    /// Conflict-driven learned search ran the improvement phase.
    Learned,
    /// Chronological DFS ran the improvement phase (either as
    /// configured, or as the fallback after a learned-search failure).
    Chronological,
    /// Exact search was skipped or failed; only LNS polish from the
    /// greedy warm start ran.
    LnsGreedy,
    /// Every improvement attempt failed; the answer is the greedy
    /// Phase-1 sequence (plus deterministic removal polish).
    GreedyOnly,
}

impl Rung {
    /// Stable lower-case name (CLI / JSON).
    pub fn as_str(&self) -> &'static str {
        match self {
            Rung::Learned => "learned",
            Rung::Chronological => "chronological",
            Rung::LnsGreedy => "lns-greedy",
            Rung::GreedyOnly => "greedy-only",
        }
    }
}

/// Wall-clock actually consumed per pipeline phase, in milliseconds.
/// Phases follow the solve structure: presolve + Phase-1 greedy, the
/// exact/portfolio search, and the LNS polish loop.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PhaseSpend {
    /// Presolve + Phase-1 greedy feasibility.
    pub presolve_ms: u64,
    /// Exact branch & bound (or portfolio member search).
    pub search_ms: u64,
    /// LNS polish loop.
    pub polish_ms: u64,
}

/// Per-phase wall-clock budget split of a solve's total time limit.
/// The exact search phase is capped at its slice (so a pathological
/// proof attempt cannot starve the anytime LNS polish); presolve and
/// polish run within whatever remains of the request deadline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PhaseBudgets {
    /// Slice for presolve + Phase-1 greedy.
    pub presolve: Duration,
    /// Slice for the exact search phase.
    pub search: Duration,
    /// Slice for the LNS polish phase.
    pub polish: Duration,
}

impl PhaseBudgets {
    /// Default partition of a total wall budget: 15% presolve, 60%
    /// exact search, 25% LNS polish.
    pub fn split(total: Duration) -> Self {
        PhaseBudgets {
            presolve: total.mul_f64(0.15),
            search: total.mul_f64(0.60),
            polish: total.mul_f64(0.25),
        }
    }
}

/// Provenance of how an answer was produced when parts of the pipeline
/// failed — and proof that nothing failed when it didn't.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Degradation {
    /// Ladder rung that produced the final answer.
    pub rung: Rung,
    /// Failures absorbed on the way (one human-readable entry each:
    /// `"panic at rung learned: failpoint 'engine.propagate': ..."`,
    /// `"watchdog: heartbeat stall"`, ...). Empty on a clean solve.
    pub failures: Vec<String>,
    /// Transient member failures retried by `solve_many` for this
    /// request.
    pub retries: u32,
    /// Wall-clock consumed per pipeline phase.
    pub spend: PhaseSpend,
}

impl Degradation {
    /// A clean (so-far failure-free) provenance answered by `rung`.
    pub fn clean(rung: Rung) -> Self {
        Degradation { rung, failures: Vec::new(), retries: 0, spend: PhaseSpend::default() }
    }

    /// True when nothing failed and nothing was retried — the answer is
    /// indistinguishable from a fault-free run.
    pub fn is_clean(&self) -> bool {
        self.failures.is_empty() && self.retries == 0
    }

    /// Record an absorbed failure.
    pub fn note_failure(&mut self, why: impl Into<String>) {
        self.failures.push(why.into());
    }

    /// Compact JSON object (used verbatim by the bench JSON writers and
    /// anything else that reports degradation per solve).
    pub fn to_json(&self) -> String {
        let fails: Vec<String> =
            self.failures.iter().map(|f| format!("\"{}\"", json_escape(f))).collect();
        format!(
            "{{\"rung\":\"{}\",\"clean\":{},\"failures\":[{}],\"retries\":{},\
             \"spend_ms\":{{\"presolve\":{},\"search\":{},\"polish\":{}}}}}",
            self.rung.as_str(),
            self.is_clean(),
            fails.join(","),
            self.retries,
            self.spend.presolve_ms,
            self.spend.search_ms,
            self.spend.polish_ms,
        )
    }
}

/// Minimal JSON string escaping (quotes, backslashes, control chars).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push(' '),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clean_roundtrip() {
        let d = Degradation::clean(Rung::Learned);
        assert!(d.is_clean());
        let j = d.to_json();
        assert!(j.contains("\"rung\":\"learned\""), "{j}");
        assert!(j.contains("\"clean\":true"), "{j}");
        assert!(j.contains("\"failures\":[]"), "{j}");
    }

    #[test]
    fn failures_escape_and_mark_dirty() {
        let mut d = Degradation::clean(Rung::Chronological);
        d.note_failure("panic: said \"boom\"\nat line 3");
        assert!(!d.is_clean());
        let j = d.to_json();
        assert!(j.contains("\\\"boom\\\""), "{j}");
        assert!(!j.contains('\n'), "control chars must be stripped: {j}");
    }

    #[test]
    fn budget_split_covers_total() {
        let b = PhaseBudgets::split(Duration::from_secs(10));
        let sum = b.presolve + b.search + b.polish;
        assert!(sum <= Duration::from_secs(10));
        assert!(sum >= Duration::from_millis(9_900));
        assert!(b.search > b.presolve && b.search > b.polish);
    }

    #[test]
    fn rungs_order_strongest_first() {
        assert!(Rung::Learned < Rung::Chronological);
        assert!(Rung::Chronological < Rung::LnsGreedy);
        assert!(Rung::LnsGreedy < Rung::GreedyOnly);
    }
}
