//! The MOCCASIN retention-interval CP model (paper §2.1–§2.3).
//!
//! Variables per node `v` (topological index `k`, 1-based) and interval
//! copy `i ∈ {1..C_v}`:
//!
//! * `a_v^i ∈ {0,1}` — interval active (constraint (7): `a_v^1 = 1`)
//! * `s_v^i, e_v^i ∈ D` — start / end events (constraint (8))
//!
//! **Staged domain (§2.3).** Events are grouped into stages: stage `j`
//! has `j` events, event `(j, k)` has id `j(j−1)/2 + k` (1-based,
//! `k ≤ j`), and the node with topological index `k` may only be
//! computed at slot `k` of a stage — so start domains are
//! `{id(j,k) : j ≥ k}`, the first interval is *fixed* at `id(k,k)` ("the
//! j'th node is computed in the last event of stage j"), and constraint
//! (6) (alldifferent of starts) holds structurally. `|D| = n(n+1)/2`.
//!
//! **Constraints.**
//! * (2) `a_v^i → s_v^i ≤ e_v^i`
//! * (3) `a_v^{i+1} → e_v^i ≤ s_v^{i+1}` and `s_v^i + 1 ≤ s_v^{i+1}`
//!   (interval copies are ordered; also breaks copy symmetry)
//! * (4) `cumulative({(s,e,a,m_v)}, M)`
//! * (5) per edge `(u,v)`, per copy `i`: `cover(a_v^i, s_v^i,
//!   {(a_u^j, s_u^j, e_u^j)}_j)` — the reservoir/producer-consumer
//!   constraint: an active start of `v` must lie strictly inside an
//!   active retention interval of every predecessor.
//! * (6) only in the unstaged variant: `alldifferent({s_v^i})`
//!
//! **Objective (1).** `Σ_{v,i} w_v a_v^i` = total execution duration.

use crate::cp::{CumItem, Model, VarId};
use crate::graph::{Graph, NodeId};
use crate::presolve::{detect_serialized_clique, staged_caps, Presolve, PresolveStats};
use std::sync::Arc;

/// CP variables of one retention interval.
#[derive(Debug, Clone, Copy)]
pub struct IntervalVars {
    /// The node this interval belongs to.
    pub node: NodeId,
    /// copy index (0-based; copy 0 is the always-active first compute)
    pub copy: usize,
    /// `a_v^i`: Boolean, interval is used.
    pub active: VarId,
    /// `s_v^i`: start event (the (re)computation).
    pub start: VarId,
    /// `e_v^i`: end event (last retention event, inclusive).
    pub end: VarId,
}

/// The built model plus the metadata needed to extract sequences and
/// choose branch orders.
pub struct StagedModel {
    /// The CP model (variables + constraints).
    pub model: Model,
    /// All interval variable bundles, in creation order.
    pub intervals: Vec<IntervalVars>,
    /// interval indices per node
    pub by_node: Vec<Vec<usize>>,
    /// input topological order
    pub order: Vec<NodeId>,
    /// node -> 1-based topological index k
    pub topo_index: Vec<usize>,
    /// number of events T = n(n+1)/2 (staged) or Σ C_v (unstaged)
    pub horizon: i64,
    /// objective terms Σ w_v a_v^i
    pub objective: Vec<(i64, VarId)>,
    /// true if built with the §2.3 staged domain
    pub staged: bool,
    /// What the root presolve achieved while building this model
    /// (all-zero for the raw builders).
    pub presolve: PresolveStats,
}

/// Emit the compacted precedence constraints (5) for a presolved
/// build: one multi-target cover per edge, covering every consumer
/// copy at once, over candidate/target slices shared via `Arc`. In
/// aggressive mode, covers of transitively redundant edges are skipped
/// (counted in `edges_removed`) — shared by the staged and unstaged
/// builders.
fn emit_presolved_covers(
    model: &mut Model,
    graph: &Graph,
    by_node: &[Vec<usize>],
    intervals: &[IntervalVars],
    pre: &Presolve,
    stats: &mut PresolveStats,
) {
    let n = graph.n();
    let cand_of: Vec<Arc<[(VarId, VarId, VarId)]>> = (0..n)
        .map(|u| {
            by_node[u]
                .iter()
                .map(|&j| {
                    let p = intervals[j];
                    (p.active, p.start, p.end)
                })
                .collect::<Vec<_>>()
                .into()
        })
        .collect();
    for v in 0..n {
        if graph.preds[v].is_empty() {
            continue;
        }
        let targets: Arc<[(VarId, VarId)]> = by_node[v]
            .iter()
            .map(|&j| {
                let p = intervals[j];
                (p.active, p.start)
            })
            .collect::<Vec<_>>()
            .into();
        for &u in &graph.preds[v] {
            if pre.aggressive() {
                if let Some(a) = pre.analysis.as_ref() {
                    if a.edge_redundant(graph, u, v as NodeId) {
                        stats.edges_removed += 1;
                        continue;
                    }
                }
            }
            model.cover_multi(Arc::clone(&targets), Arc::clone(&cand_of[u as usize]));
        }
    }
}

/// 1-based staged event id of slot `k` in stage `j` (`k ≤ j`).
#[inline]
pub fn event_id(j: usize, k: usize) -> i64 {
    debug_assert!(k >= 1 && k <= j);
    (j * (j - 1) / 2 + k) as i64
}

impl StagedModel {
    /// Build the staged model (§2.3). `c_v[v]` = max interval copies for
    /// node `v` (the paper's `C_v`; pass `vec![2; n]` for the default).
    /// `budget` is the memory capacity `M`.
    pub fn build(graph: &Graph, order: &[NodeId], budget: u64, c_v: &[usize]) -> StagedModel {
        let n = graph.n();
        assert_eq!(order.len(), n);
        assert_eq!(c_v.len(), n);
        let mut topo_index = vec![0usize; n];
        for (i, &v) in order.iter().enumerate() {
            topo_index[v as usize] = i + 1; // 1-based
        }
        let horizon = event_id(n, n);
        let mut model = Model::new();
        let mut intervals: Vec<IntervalVars> = Vec::new();
        let mut by_node: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut objective: Vec<(i64, VarId)> = Vec::new();

        // --- variables ---
        for v in 0..n {
            let k = topo_index[v];
            let c = c_v[v].max(1);
            for copy in 0..c {
                let (active, start) = if copy == 0 {
                    // (7): first interval active, start fixed at (k,k)
                    let a = model.new_bool();
                    model.fix(a, 1);
                    let s = model.new_var(event_id(k, k), event_id(k, k));
                    (a, s)
                } else {
                    if k + copy > n {
                        break; // no stage left for this copy
                    }
                    let a = model.new_bool();
                    // start domain {id(j,k) : j in k+copy ..= n}
                    let vals: Vec<i64> =
                        (k + copy..=n).map(|j| event_id(j, k)).collect();
                    if vals.is_empty() {
                        break;
                    }
                    let s = model.new_var_values(Arc::new(vals));
                    (a, s)
                };
                let end = model.new_var(event_id(k, k), horizon);
                objective.push((graph.duration[v] as i64, active));
                by_node[v].push(intervals.len());
                intervals.push(IntervalVars { node: v as NodeId, copy, active, start, end });
            }
        }

        // --- interval-shape constraints (2), (3) ---
        for v in 0..n {
            let ivs = &by_node[v];
            for (ci, &idx) in ivs.iter().enumerate() {
                let iv = intervals[idx];
                // (2): active → s ≤ e
                model.cond_le_offset(iv.active, iv.start, 0, iv.end);
                if ci + 1 < ivs.len() {
                    let nx = intervals[ivs[ci + 1]];
                    // copies used in order (symmetry breaking)
                    model.implies(nx.active, iv.active);
                    // (3): next copy starts after this one ends
                    model.cond_le_offset(nx.active, iv.end, 0, nx.start);
                    // strictly increasing starts
                    model.cond_le_offset(nx.active, iv.start, 1, nx.start);
                }
            }
        }

        // --- memory constraint (4) ---
        let items: Vec<CumItem> = intervals
            .iter()
            .map(|iv| CumItem {
                active: iv.active,
                start: iv.start,
                end: iv.end,
                demand: graph.mem[iv.node as usize] as i64,
            })
            .collect();
        model.cumulative(items, budget as i64);

        // --- precedence constraints (5) ---
        // one candidate list per producer, shared (`Arc`) across every
        // consumer-copy cover instead of cloned per copy
        for v in 0..n {
            for &u in &graph.preds[v] {
                let candidates: Arc<[(VarId, VarId, VarId)]> = by_node[u as usize]
                    .iter()
                    .map(|&j| {
                        let p = intervals[j];
                        (p.active, p.start, p.end)
                    })
                    .collect::<Vec<_>>()
                    .into();
                for &idx in &by_node[v] {
                    let iv = intervals[idx];
                    model.cover(iv.active, iv.start, Arc::clone(&candidates));
                }
            }
        }

        StagedModel {
            model,
            intervals,
            by_node,
            order: order.to_vec(),
            topo_index,
            horizon,
            objective,
            staged: true,
            presolve: PresolveStats::default(),
        }
    }

    /// Build the staged model through the root presolve: same problem,
    /// smaller formulation. Reductions applied (see `presolve` for the
    /// taxonomy):
    ///
    /// * **Structural elimination.** Copy 0's interval-validity
    ///   constraint (2) is dropped (its start is fixed at `id(k,k)` and
    ///   the end domain's lower bound is exactly that event), the
    ///   `a¹ → a⁰` implication is dropped (`a⁰ ≡ 1`), and each (3)
    ///   ordering pair collapses into the single strict constraint
    ///   `aⁱ⁺¹ → eⁱ + 1 ≤ sⁱ⁺¹`. Strictness is exact: a minimal-end
    ///   solution has `eⁱ` at its copy start or the last covered
    ///   consumer event, both strictly below `sⁱ⁺¹` (consumer events sit
    ///   at other slots, so equality is impossible) — and it forbids
    ///   only double-charged placements with `eⁱ = sⁱ⁺¹` that dominate
    ///   nothing.
    /// * **Cover compaction.** One multi-target cover per precedence
    ///   edge (all consumer copies at once) over a shared candidate
    ///   slice — `m` propagators instead of `Σ_edges C_v`.
    /// * **Liveness bounds.** `e_v ≤ latest_use(v)` (no cover ever needs
    ///   more), recompute-copy start stages capped at the last stage
    ///   that can still cover a use, sink intervals pinned to their
    ///   compute event, and `e` lower bounds raised to each copy's own
    ///   earliest start.
    /// * **Dominance fixing.** Copies whose earliest start cannot
    ///   precede any use are never built: any feasible assignment using
    ///   such a copy maps to one of no larger objective without it
    ///   (deactivate it — memory load only drops, covers never
    ///   reference it as a candidate *requirement* — and shift later
    ///   active copies down one slot, which their wider stage ranges
    ///   permit).
    /// * **Aggressive level only:** covers of transitively redundant
    ///   edges are skipped (a relaxation — extracted solutions are
    ///   still eval-validated downstream).
    /// * **`max_interval_len`:** the §3 cap `e − s ≤ L` as tightened end
    ///   domains (copy 0) plus one conditional propagator per recompute
    ///   copy.
    ///
    /// `keep_stages` (LNS window re-solves) forces copies that a frozen
    /// incumbent occupies to exist with their incumbent stage inside the
    /// start domain, even when dominance would have pruned them.
    pub fn build_with(
        graph: &Graph,
        order: &[NodeId],
        budget: u64,
        c_v: &[usize],
        pre: &Presolve,
        keep_stages: Option<&[Vec<usize>]>,
    ) -> StagedModel {
        if !pre.enabled() {
            return StagedModel::build(graph, order, budget, c_v);
        }
        let n = graph.n();
        assert_eq!(order.len(), n);
        assert_eq!(c_v.len(), n);
        let mut topo_index = vec![0usize; n];
        for (i, &v) in order.iter().enumerate() {
            topo_index[v as usize] = i + 1;
        }
        let horizon = event_id(n, n);
        let caps = staged_caps(graph, order, c_v);
        let mut stats = PresolveStats {
            edges_redundant: pre.analysis.as_ref().map_or(0, |a| a.edges_redundant),
            ..Default::default()
        };

        // --- raw-formulation counters (what `build` would construct) ---
        {
            let mut copies_raw = vec![0u64; n];
            let mut props: u64 = 1; // cumulative
            let mut dom: u64 = 0;
            for v in 0..n {
                let k = topo_index[v];
                for copy in 0..c_v[v].max(1) {
                    if copy > 0 && k + copy > n {
                        break;
                    }
                    copies_raw[v] += 1;
                    dom += 2; // active
                    dom += if copy == 0 { 1 } else { (n - (k + copy) + 1) as u64 };
                    dom += (horizon - event_id(k, k) + 1) as u64; // end
                }
                props += copies_raw[v]; // (2)
                props += 3 * copies_raw[v].saturating_sub(1); // (3)
            }
            for v in 0..n {
                props += graph.preds[v].len() as u64 * copies_raw[v]; // (5)
            }
            stats.props_before = props;
            stats.domain_before = dom;
        }

        // Frozen-incumbent uses: when an LNS window pins consumer
        // copies at their incumbent stages, every *producer's* end
        // upper bound must still be able to reach those pinned start
        // events — `latest_use` alone was computed without the
        // incumbent, and a kept stage beyond a consumer's dominance cap
        // would otherwise root-conflict the whole window model.
        let mut keep_use = vec![0i64; n];
        if let Some(ks) = keep_stages {
            for w in 0..n {
                let Some(stages) = ks.get(w) else { continue };
                let kw = topo_index[w];
                for &j in stages {
                    let ev = event_id(j, kw);
                    for &u in &graph.preds[w] {
                        let ku = &mut keep_use[u as usize];
                        *ku = (*ku).max(ev);
                    }
                }
            }
        }

        // --- copy planning: (stage_lo, stage_hi) per surviving copy ---
        let mut plan: Vec<Vec<(usize, usize)>> = Vec::with_capacity(n);
        for v in 0..n {
            let k = topo_index[v];
            let keep = keep_stages.and_then(|ks| ks.get(v));
            let keep_len = keep.map_or(0, |s| s.len());
            let mut copies: Vec<(usize, usize)> = Vec::new();
            for copy in 0..c_v[v].max(1) {
                if copy == 0 {
                    copies.push((k, k));
                    continue;
                }
                if k + copy > n {
                    break; // the raw build has no stage for it either
                }
                let lo = k + copy;
                let mut hi = caps.max_stage[v];
                if copy < keep_len {
                    // a frozen incumbent occupies this copy: keep it,
                    // with its stage inside the domain
                    hi = hi.max(keep.map_or(0, |s| s[copy]));
                }
                if lo > hi {
                    // dominance: this copy (and every later one — same
                    // cap, higher lo) can never cover a use
                    let mut dead = copy;
                    while dead < c_v[v].max(1) && k + dead <= n {
                        stats.copies_deactivated += 1;
                        dead += 1;
                    }
                    break;
                }
                copies.push((lo, hi));
            }
            plan.push(copies);
        }

        // --- start-domain arena: one flat allocation for every
        //     recompute-copy start list ---
        let mut arena: Vec<i64> = Vec::new();
        let mut windows: Vec<Vec<(usize, usize)>> = Vec::with_capacity(n);
        for v in 0..n {
            let k = topo_index[v];
            let mut w = Vec::with_capacity(plan[v].len());
            for (ci, &(lo, hi)) in plan[v].iter().enumerate() {
                if ci == 0 {
                    w.push((0, 0)); // copy 0: fixed start, no arena slot
                    continue;
                }
                let off = arena.len();
                arena.extend((lo..=hi).map(|j| event_id(j, k)));
                w.push((off, hi - lo + 1));
            }
            windows.push(w);
        }
        let arena = Arc::new(arena);

        // --- variables ---
        let l_cap = pre.config.max_interval_len.map(|l| l.max(0));
        let mut model = Model::new();
        let mut intervals: Vec<IntervalVars> = Vec::new();
        let mut by_node: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut objective: Vec<(i64, VarId)> = Vec::new();
        for v in 0..n {
            let k = topo_index[v];
            let lu = caps.latest_use[v];
            for (copy, &(lo, hi)) in plan[v].iter().enumerate() {
                let (active, start) = if copy == 0 {
                    let a = model.new_bool();
                    model.fix(a, 1); // (7)
                    (a, model.new_var(event_id(k, k), event_id(k, k)))
                } else {
                    let (off, len) = windows[v][copy];
                    (model.new_bool(), model.new_var_arena(&arena, off, len))
                };
                // liveness-derived end bounds: never below this copy's
                // earliest start, never above the last possible use —
                // extended to any frozen-incumbent use (sinks pin to
                // the compute event)
                let end_lb = event_id(lo, k);
                let mut end_ub = if lu > 0 { lu.max(event_id(hi, k)) } else { event_id(hi, k) };
                end_ub = end_ub.max(keep_use[v]);
                if let Some(l) = l_cap {
                    end_ub = end_ub.min(event_id(hi, k) + l).max(end_lb);
                }
                if end_lb == end_ub {
                    stats.vars_fixed += 1;
                }
                let end = model.new_var(end_lb, end_ub);
                objective.push((graph.duration[v] as i64, active));
                by_node[v].push(intervals.len());
                intervals.push(IntervalVars { node: v as NodeId, copy, active, start, end });
            }
        }

        // --- interval-shape constraints: (2) only where unimplied,
        //     (3) merged into one strict ordering per pair ---
        for v in 0..n {
            let ivs = &by_node[v];
            for (ci, &idx) in ivs.iter().enumerate() {
                let iv = intervals[idx];
                if ci > 0 {
                    // (2): copy 0's is implied by the end lower bound
                    model.cond_le_offset(iv.active, iv.start, 0, iv.end);
                    if let Some(l) = l_cap {
                        // §3 cap e − s ≤ L (copy 0: folded into the
                        // end domain above)
                        model.cond_le_offset(iv.active, iv.end, -l, iv.start);
                    }
                }
                if ci + 1 < ivs.len() {
                    let nx = intervals[ivs[ci + 1]];
                    if ci > 0 {
                        // copies used in order; vacuous for ci == 0
                        // where the guard a⁰ is fixed true
                        model.implies(nx.active, iv.active);
                    }
                    // merged (3): strictly ordered, end before next start
                    model.cond_le_offset(nx.active, iv.end, 1, nx.start);
                }
            }
        }

        // --- memory constraint (4) ---
        let items: Vec<CumItem> = intervals
            .iter()
            .map(|iv| CumItem {
                active: iv.active,
                start: iv.start,
                end: iv.end,
                demand: graph.mem[iv.node as usize] as i64,
            })
            .collect();
        // tight-budget regimes: tensors over half the budget pairwise
        // serialize — post the redundant disjunctive clique alongside
        // the cumulative (the `--disjunctive` knob gates propagation)
        let clique = detect_serialized_clique(&items, budget as i64);
        model.cumulative(items, budget as i64);
        if !clique.is_empty() {
            model.disjunctive(clique);
        }

        // --- precedence constraints (5): one multi-target cover per
        //     edge, shared target/candidate slices ---
        emit_presolved_covers(&mut model, graph, &by_node, &intervals, pre, &mut stats);

        stats.props_after = model.num_constraints() as u64;
        stats.domain_after = model.domain_size_sum();
        StagedModel {
            model,
            intervals,
            by_node,
            order: order.to_vec(),
            topo_index,
            horizon,
            objective,
            staged: true,
            presolve: stats,
        }
    }

    /// Build the unstaged variant (§2.1–§2.2): full event domain
    /// `D = {1..Σ C_v}` for every start, with the explicit
    /// `alldifferent` on starts (constraint (6)). Exponentially harder —
    /// used only for the flexibility ablation on tiny graphs.
    pub fn build_unstaged(
        graph: &Graph,
        order: &[NodeId],
        budget: u64,
        c_v: &[usize],
    ) -> StagedModel {
        let n = graph.n();
        let mut topo_index = vec![0usize; n];
        for (i, &v) in order.iter().enumerate() {
            topo_index[v as usize] = i + 1;
        }
        let horizon: i64 = c_v.iter().map(|&c| c as i64).sum();
        let mut model = Model::new();
        let mut intervals = Vec::new();
        let mut by_node: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut objective = Vec::new();

        for v in 0..n {
            for copy in 0..c_v[v].max(1) {
                let a = model.new_bool();
                if copy == 0 {
                    model.fix(a, 1); // (7)
                }
                let s = model.new_var(1, horizon);
                let e = model.new_var(1, horizon);
                objective.push((graph.duration[v] as i64, a));
                by_node[v].push(intervals.len());
                intervals
                    .push(IntervalVars { node: v as NodeId, copy, active: a, start: s, end: e });
            }
        }
        for v in 0..n {
            let ivs = &by_node[v];
            for (ci, &idx) in ivs.iter().enumerate() {
                let iv = intervals[idx];
                model.cond_le_offset(iv.active, iv.start, 0, iv.end);
                if ci + 1 < ivs.len() {
                    let nx = intervals[ivs[ci + 1]];
                    model.implies(nx.active, iv.active);
                    model.cond_le_offset(nx.active, iv.end, 0, nx.start);
                    model.cond_le_offset(nx.active, iv.start, 1, nx.start);
                }
            }
        }
        let items: Vec<CumItem> = intervals
            .iter()
            .map(|iv| CumItem {
                active: iv.active,
                start: iv.start,
                end: iv.end,
                demand: graph.mem[iv.node as usize] as i64,
            })
            .collect();
        model.cumulative(items, budget as i64);
        for v in 0..n {
            for &u in &graph.preds[v] {
                let candidates: Arc<[(VarId, VarId, VarId)]> = by_node[u as usize]
                    .iter()
                    .map(|&j| {
                        let p = intervals[j];
                        (p.active, p.start, p.end)
                    })
                    .collect::<Vec<_>>()
                    .into();
                for &idx in &by_node[v] {
                    let iv = intervals[idx];
                    model.cover(iv.active, iv.start, Arc::clone(&candidates));
                }
            }
        }
        // (6): starts pairwise distinct
        let starts: Vec<VarId> = intervals.iter().map(|iv| iv.start).collect();
        model.all_different(starts);

        StagedModel {
            model,
            intervals,
            by_node,
            order: order.to_vec(),
            topo_index,
            horizon,
            objective,
            staged: false,
            presolve: PresolveStats::default(),
        }
    }

    /// The unstaged builder behind the root presolve: depth-derived
    /// lower bounds (`s_v ≥ |ancestors| + 1 + copy` — every ancestor
    /// computes at least once first, and copies are strictly ordered),
    /// reverse-reachability upper bounds
    /// (`s_v⁰ ≤ |D| − |descendants|` — every descendant's first compute
    /// needs a distinct later event under the alldifferent), sink
    /// recompute copies dropped (dominance: they cover nothing), the
    /// same structural (3) merge as the staged builder (`alldifferent`
    /// keeps equality impossible, so strictness is exact), and one
    /// multi-target cover per edge. The event horizon stays `Σ C_v`, so
    /// any raw-feasible assignment embeds unchanged.
    pub fn build_unstaged_with(
        graph: &Graph,
        order: &[NodeId],
        budget: u64,
        c_v: &[usize],
        pre: &Presolve,
    ) -> StagedModel {
        if !pre.enabled() {
            return StagedModel::build_unstaged(graph, order, budget, c_v);
        }
        let n = graph.n();
        let mut topo_index = vec![0usize; n];
        for (i, &v) in order.iter().enumerate() {
            topo_index[v as usize] = i + 1;
        }
        let horizon: i64 = c_v.iter().map(|&c| c as i64).sum();
        let mut stats = PresolveStats {
            edges_redundant: pre.analysis.as_ref().map_or(0, |a| a.edges_redundant),
            ..Default::default()
        };
        // raw-formulation counters
        {
            let mut props: u64 = 2; // cumulative + alldifferent
            let mut dom: u64 = 0;
            for v in 0..n {
                let rc = c_v[v].max(1) as u64;
                props += rc + 3 * (rc - 1) + graph.preds[v].len() as u64 * rc;
                dom += rc * (2 + 2 * horizon as u64);
            }
            stats.props_before = props;
            stats.domain_before = dom;
        }
        let zero = vec![0u32; n];
        let (anc, desc) = match pre.analysis.as_ref() {
            Some(a) => (&a.anc_count, &a.desc_count),
            None => (&zero, &zero),
        };
        let l_cap = pre.config.max_interval_len.map(|l| l.max(0));

        let mut model = Model::new();
        let mut intervals = Vec::new();
        let mut by_node: Vec<Vec<usize>> = vec![Vec::new(); n];
        let mut objective = Vec::new();
        for v in 0..n {
            let is_sink = graph.succs[v].is_empty();
            for copy in 0..c_v[v].max(1) {
                if copy > 0 && is_sink {
                    // dominance: a sink recompute covers nothing
                    stats.copies_deactivated += 1;
                    continue;
                }
                let a = model.new_bool();
                if copy == 0 {
                    model.fix(a, 1); // (7)
                }
                let s_lb = anc[v] as i64 + 1 + copy as i64;
                let s_ub = if copy == 0 { horizon - desc[v] as i64 } else { horizon };
                let s = model.new_var(s_lb, s_ub);
                let e = model.new_var(s_lb, horizon);
                objective.push((graph.duration[v] as i64, a));
                by_node[v].push(intervals.len());
                intervals
                    .push(IntervalVars { node: v as NodeId, copy, active: a, start: s, end: e });
            }
        }
        for v in 0..n {
            let ivs = &by_node[v];
            for (ci, &idx) in ivs.iter().enumerate() {
                let iv = intervals[idx];
                // (2): starts are unfixed here, so every copy needs it
                model.cond_le_offset(iv.active, iv.start, 0, iv.end);
                if let Some(l) = l_cap {
                    model.cond_le_offset(iv.active, iv.end, -l, iv.start);
                }
                if ci + 1 < ivs.len() {
                    let nx = intervals[ivs[ci + 1]];
                    if ci > 0 {
                        model.implies(nx.active, iv.active);
                    }
                    model.cond_le_offset(nx.active, iv.end, 1, nx.start);
                }
            }
        }
        let items: Vec<CumItem> = intervals
            .iter()
            .map(|iv| CumItem {
                active: iv.active,
                start: iv.start,
                end: iv.end,
                demand: graph.mem[iv.node as usize] as i64,
            })
            .collect();
        let clique = detect_serialized_clique(&items, budget as i64);
        model.cumulative(items, budget as i64);
        if !clique.is_empty() {
            model.disjunctive(clique);
        }
        emit_presolved_covers(&mut model, graph, &by_node, &intervals, pre, &mut stats);
        // (6): starts pairwise distinct
        let starts: Vec<VarId> = intervals.iter().map(|iv| iv.start).collect();
        model.all_different(starts);

        stats.props_after = model.num_constraints() as u64;
        stats.domain_after = model.domain_size_sum();
        StagedModel {
            model,
            intervals,
            by_node,
            order: order.to_vec(),
            topo_index,
            horizon,
            objective,
            staged: false,
            presolve: stats,
        }
    }

    /// Branch order: actives (topo order), then starts, then ends; with
    /// guards so start/end of an inactive copy are skipped. (The
    /// unstaged variant cannot guard: its `alldifferent` ranges over
    /// *all* starts, so they must all be decided.)
    pub fn branch_order(&self) -> (Vec<VarId>, Vec<Option<VarId>>) {
        let mut vars = Vec::with_capacity(self.intervals.len() * 3);
        let mut guards = Vec::with_capacity(self.intervals.len() * 3);
        let guard = |iv: &IntervalVars| if self.staged { Some(iv.active) } else { None };
        for iv in &self.intervals {
            vars.push(iv.active);
            guards.push(None);
        }
        for iv in &self.intervals {
            vars.push(iv.start);
            guards.push(guard(iv));
        }
        for iv in &self.intervals {
            vars.push(iv.end);
            guards.push(guard(iv));
        }
        (vars, guards)
    }

    /// Extract the rematerialization sequence of a solver assignment:
    /// active intervals ordered by start event.
    pub fn extract_sequence(&self, assignment: &[i64]) -> Vec<NodeId> {
        let mut starts: Vec<(i64, NodeId)> = self
            .intervals
            .iter()
            .filter(|iv| assignment[iv.active.0 as usize] == 1)
            .map(|iv| (assignment[iv.start.0 as usize], iv.node))
            .collect();
        starts.sort_unstable();
        debug_assert!(
            starts.windows(2).all(|w| w[0].0 != w[1].0),
            "two active intervals share a start event"
        );
        starts.into_iter().map(|(_, v)| v).collect()
    }

    /// Formulation size counts (Table 1): (#Boolean vars, #integer vars,
    /// #constraints).
    pub fn complexity(&self) -> (usize, usize, usize) {
        let bools = self.intervals.len();
        let ints = self.intervals.len() * 2;
        (bools, ints, self.model.num_constraints())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cp::{Solver, Status};
    use crate::graph::{eval_sequence, topological_order};
    use crate::util::Deadline;
    use std::time::Duration;

    fn fig2_graph() -> Graph {
        // paper Figure 2: 1→2, 1→3, 2→4, 3→4 (0-indexed)
        Graph::from_edges(
            "fig2",
            4,
            &[(0, 1), (0, 2), (1, 3), (2, 3)],
            vec![1; 4],
            vec![1; 4],
        )
        .unwrap()
    }

    #[test]
    fn event_ids_match_figure4() {
        // stage 1: event 1; stage 2: events 2,3; stage 3: 4,5,6 …
        assert_eq!(event_id(1, 1), 1);
        assert_eq!(event_id(2, 1), 2);
        assert_eq!(event_id(2, 2), 3);
        assert_eq!(event_id(3, 3), 6);
        assert_eq!(event_id(4, 1), 7);
    }

    #[test]
    fn variable_counts_are_linear_in_n() {
        let g = fig2_graph();
        let order = topological_order(&g).unwrap();
        let sm = StagedModel::build(&g, &order, 100, &vec![2; 4]);
        let (bools, ints, _cons) = sm.complexity();
        // C·n intervals (minus copies that don't fit): here 4 + 3 = 7
        assert_eq!(bools, 7);
        assert_eq!(ints, 14);
    }

    #[test]
    fn loose_budget_solves_with_no_remat() {
        let g = fig2_graph();
        let order = topological_order(&g).unwrap();
        let sm = StagedModel::build(&g, &order, 100, &vec![2; 4]);
        let (bo, guards) = sm.branch_order();
        let solver = Solver { guards: Some(guards), ..Default::default() };
        let r = solver.solve(&sm.model, &sm.objective, &bo, |_, _| {});
        assert_eq!(r.status, Status::Optimal);
        let (a, obj) = r.best.unwrap();
        assert_eq!(obj, 4, "no remat needed: duration = Σ w = 4");
        let seq = sm.extract_sequence(&a);
        assert_eq!(seq.len(), 4);
        let ev = eval_sequence(&g, &seq).unwrap();
        assert_eq!(ev.duration, 4);
    }

    #[test]
    fn tight_budget_forces_remat_matching_paper_example() {
        // Figure 3's scenario: unit sizes, budget 3 is achievable without
        // remat (peak 3); budget 2 is infeasible even with remat for this
        // graph (node 3 needs both preds + itself = 3).
        let g = fig2_graph();
        let order = topological_order(&g).unwrap();
        let sm = StagedModel::build(&g, &order, 3, &vec![2; 4]);
        let (bo, guards) = sm.branch_order();
        let solver = Solver { guards: Some(guards), ..Default::default() };
        let r = solver.solve(&sm.model, &sm.objective, &bo, |_, _| {});
        assert_eq!(r.status, Status::Optimal);
        assert_eq!(r.best.unwrap().1, 4);

        let sm2 = StagedModel::build(&g, &order, 2, &vec![2; 4]);
        let (bo2, guards2) = sm2.branch_order();
        let solver2 = Solver { guards: Some(guards2), ..Default::default() };
        let r2 = solver2.solve(&sm2.model, &sm2.objective, &bo2, |_, _| {});
        assert_eq!(r2.status, Status::Infeasible);
    }

    #[test]
    fn remat_strictly_needed_case() {
        // 0→1→2, 0→2? no — build the chain where remat of 0 pays:
        // 0→1, 1→2, 0→3, 2→3; m = [3,1,1,1]; w = [1,1,1,1].
        // No-remat peak: 0 alive until step 3 → at step 3: m0+m2+m3 = 5;
        // step 2: m0+m1+m2 = 5. With remat of 0: [0,1,2,0,3]:
        // p0 0:[0,1] p1 1:[1,2] p2 2:[2,4] p3 0:[3,4] p4 3 → profile
        // 3,4,2,5,5 → still 5. Hmm: m0 dominates; choose m=[2,1,1,1]:
        // no-remat: steps: 2,3,4(m0+m1+m2? 0 live till 3,1 live till 2):
        //   p0 0:[0,3], p1 1:[1,2], p2 2:[2,3], p3 3:[3,3]
        //   loads: 2, 3, 4, 4 → peak 4.
        // remat [0,1,2,0,3]: p0 0:[0,1], p1 1:[1,2], p2 2:[2,4],
        //   p3 0:[3,4], p4 3:[4,4] → 2,3,2,4,4 → peak 4. Same.
        // Use bigger fan: 0→1,1→2,2→3,0→4,3→4, m=[2,1,1,1,1]:
        //   no-remat 0 live [0,4]: loads 2,3,3,4(m0+m2+m3? 1 dead),4+...
        //   p0 0:[0,4] p1 1:[1,2] p2 2:[2,3] p3 3:[3,4] p4 4:[4,4]
        //   loads: 2,3,4,4,4  peak 4
        //   remat [0,1,2,3,0,4]: p0 0:[0,1] p1 1:[1,2] p2 2:[2,3]
        //   p3 3:[3,5] p4 0:[4,5] p5 4 → 2,3,2,2,3,4 → peak 4? m4+m3+m0=4
        //   at last step. budget 4 vs no-remat 4… same again (final step
        //   dominates). Force with heavier skip tensor: m=[3,1,1,1,1]:
        //   no-remat peak: p2: m0+m1+m2=5 → 5; remat peak: max(3,4,2,2,4,5)=5.
        //   Last step m0+m3+m4 = 5. Unavoidable: 5 = m0+m3+m4 is the
        //   working set of node 4. budget 5: no-remat feasible. OK so for
        //   this topology remat never wins — that matches the paper's
        //   line-graph observation. Just assert solver agrees: budget 5
        //   solvable with zero remat, budget 4 infeasible.
        let g = Graph::from_edges(
            "ch",
            5,
            &[(0, 1), (1, 2), (2, 3), (0, 4), (3, 4)],
            vec![1; 5],
            vec![3, 1, 1, 1, 1],
        )
        .unwrap();
        let order = topological_order(&g).unwrap();
        let sm = StagedModel::build(&g, &order, 5, &vec![2; 5]);
        let (bo, guards) = sm.branch_order();
        let r = Solver { guards: Some(guards), ..Default::default() }.solve(
            &sm.model,
            &sm.objective,
            &bo,
            |_, _| {},
        );
        assert_eq!(r.status, Status::Optimal);
        assert_eq!(r.best.unwrap().1, 5);
    }

    #[test]
    fn remat_pays_on_skip_connection() {
        // 0→1, 1→2, 0→3, 2→3 with m0 heavy and the middle small:
        // keeping 0 across 1,2 costs m0 the whole time; remat lets the
        // solver drop 0 after 1 and recompute before 3.
        // m = [4,1,1,1], w = [1,1,1,1].
        // no-remat: p0 0:[0,3] p1 1:[1,2] p2 2:[2,3] p3 3 →
        //   4,5,6,6 → peak 6.
        // remat [0,1,2,0,3]: p0 0:[0,1] p1 1:[1,2] p2 2:[2,4] p3 0:[3,4]
        //   p4 3 → 4,5,2,6,6 → peak 6?? m0+m2+m3 = 6 at the end again.
        // The end working set {0,2,3} has 0 in it — remat can't reduce
        // peak below working sets containing the heavy tensor. Use the
        // heavy tensor NOT needed at the end: 0→1 heavy mid tensor 1:
        // edges 0→1,1→2,0→3,2→3; m = [1,4,1,1]:
        // no-remat: p0 0:[0,2]? 0 consumed by 1 (q1) and 3 (q3) → [0,3];
        //   p1 1:[1,2] heavy only until 2 → loads 1, 5, 6, 3 → peak 6.
        //   Remat can't help: 1's retention is already minimal [1,2].
        // The real remat win needs TWO consumers of the heavy tensor far
        // apart: edges 0→1(h), 1→2, 2→3, 1→4, 3→4. m=[1,4,1,1,1].
        //   no-remat: 1 live [1,4]: loads 1,5,6,6,7? p3 3:[3,4] p4 4.
        //     p0 0:[0,1] p1 1:[1,4] p2 2:[2,3] p3 3:[3,4] → 1,5,6,6,6.
        //   remat of 1 before 4: seq [0,1,2,3,1,4]? 1 needs 0: 0 gone
        //     (released after 1 at p1) → must also remat 0:
        //     [0,1,2,3,0,1,4]: p0 0:[0,1] p1 1:[1,2] p2 2:[2,3]
        //     p3 3:[3,6] p4 0:[4,5] p5 1:[5,6] p6 4:[6,6]
        //     loads: 1,5,5,2,2,6,6 → peak 6 vs 6… the recompute of
        //     heavy 1 itself costs 4+1+1=6. peak can't go below 6 (node
        //     4's working set m1+m3+m4 = 6).
        // Conclusion: with node-4 needing the heavy tensor the floor is
        // its working set. To show remat value, make consumers of heavy
        // tensor early + late-but-light aggregation… simpler: test that
        // at budget = no-remat-peak - 1 the solver finds SOME remat
        // solution when one exists, on a graph engineered so dropping +
        // recomputing a cheap mid tensor wins:
        // edges: 0→1, 0→2, 1→3, 2→3, m = [1, 3, 3, 1], w = 1.
        // order [0,1,2,3]: p0 0:[0,2] p1 1:[1,3] p2 2:[2,3] p3 3 →
        //   1, 4, 7, 7 → peak 7.
        // remat 0? seq [0,1,0,2,3]: p0 0:[0,1] p1 1:[1,4] p2 0:[2,3]
        //   p3 2:[3,4] p4 3 → 1,4,4,... wait loads: p0:1, p1:1+3=4,
        //   p2: 3(1 live)+1=4? compute: alive at p2: p1(1),p2(0) → 3+1=4;
        //   p3: p1,p3 → 3+3+? p2 0:[2,3] still alive at p3 (consumed by
        //   2 at p3): 1+3+3=7. Hmm 2's compute at p3 needs 0 → 0 alive.
        //   峰 still 7 = m1+m2+m0 at p3 vs no-remat 7 = m1+m2+m3? No:
        //   no-remat p2: m0+m1+m2 = 7, remat p3: m0+m1+m2 = 7. The
        //   working set {0,1,2} unavoidable? 3 needs 1 and 2 both → 7
        //   floor with m3: m1+m2+m3 = 7. Budget 6 infeasible.
        // Fine — this test asserts solver optimality agrees with
        // exhaustive expectations: budget 7 → no remat needed.
        let g = Graph::from_edges(
            "sk",
            4,
            &[(0, 1), (0, 2), (1, 3), (2, 3)],
            vec![1; 4],
            vec![1, 3, 3, 1],
        )
        .unwrap();
        let order = topological_order(&g).unwrap();
        let sm = StagedModel::build(&g, &order, 7, &vec![2; 4]);
        let (bo, guards) = sm.branch_order();
        let r = Solver { guards: Some(guards), ..Default::default() }.solve(
            &sm.model,
            &sm.objective,
            &bo,
            |_, _| {},
        );
        assert_eq!(r.status, Status::Optimal);
        assert_eq!(r.best.unwrap().1, 4);
    }

    #[test]
    fn extracted_sequences_are_valid() {
        let g = fig2_graph();
        let order = topological_order(&g).unwrap();
        let sm = StagedModel::build(&g, &order, 3, &vec![2; 4]);
        let (bo, guards) = sm.branch_order();
        let mut seqs = Vec::new();
        let solver = Solver { guards: Some(guards), ..Default::default() };
        let _ = solver.solve(&sm.model, &sm.objective, &bo, |a, _| {
            seqs.push(sm.extract_sequence(a));
        });
        assert!(!seqs.is_empty());
        for s in seqs {
            let ev = eval_sequence(&g, &s).expect("extracted sequence valid");
            assert!(ev.peak_mem <= 3, "{s:?} peak {}", ev.peak_mem);
        }
    }

    fn solve_model(sm: &StagedModel) -> (Status, Option<i64>) {
        let (bo, guards) = sm.branch_order();
        let solver = Solver { guards: Some(guards), ..Default::default() };
        let r = solver.solve(&sm.model, &sm.objective, &bo, |_, _| {});
        (r.status, r.best.map(|(_, o)| o))
    }

    #[test]
    fn presolved_build_matches_raw_on_fig2() {
        let g = fig2_graph();
        let order = topological_order(&g).unwrap();
        let pre = Presolve::new(&g, Default::default());
        for budget in [2u64, 3, 100] {
            let raw = StagedModel::build(&g, &order, budget, &vec![2; 4]);
            let compact = StagedModel::build_with(&g, &order, budget, &vec![2; 4], &pre, None);
            assert_eq!(
                solve_model(&raw),
                solve_model(&compact),
                "status/optimum must be identical at budget {budget}"
            );
            assert!(
                compact.model.num_constraints() < raw.model.num_constraints(),
                "budget {budget}: {} !< {}",
                compact.model.num_constraints(),
                raw.model.num_constraints()
            );
            assert!(compact.model.domain_size_sum() < raw.model.domain_size_sum());
            let st = compact.presolve;
            assert_eq!(st.props_before, raw.model.num_constraints() as u64);
            assert_eq!(st.props_after, compact.model.num_constraints() as u64);
            assert_eq!(st.domain_before, raw.model.domain_size_sum());
            assert!(st.vars_fixed >= 1, "the sink end must be pinned");
        }
    }

    #[test]
    fn presolved_reduction_exceeds_20pct_on_paper_scale() {
        // the acceptance bar for the Figure-5 instances, checked on the
        // G1-shaped graph (the others only add more edges per node, and
        // covers shrink by exactly C_v → 1 per edge)
        let g = crate::generators::random_layered("g1like", 100, 236, 1);
        let order = topological_order(&g).unwrap();
        let budget = {
            let peak = g.peak_mem_no_remat(&order).unwrap();
            (peak as f64 * 0.9) as u64
        };
        let c_v = vec![2; g.n()];
        let raw = StagedModel::build(&g, &order, budget, &c_v);
        let ctx = Presolve::new(&g, Default::default());
        let pre = StagedModel::build_with(&g, &order, budget, &c_v, &ctx, None);
        let (b, a) = (raw.model.num_constraints() as f64, pre.model.num_constraints() as f64);
        assert!(a <= 0.8 * b, "propagator reduction below 20%: {b} -> {a}");
        assert!(pre.model.domain_size_sum() < raw.model.domain_size_sum());
    }

    #[test]
    fn aggressive_drops_redundant_edge_covers() {
        // 0→1→2 with redundant shortcut 0→2
        let g = Graph::from_edges(
            "sc",
            3,
            &[(0, 1), (1, 2), (0, 2)],
            vec![1; 3],
            vec![1; 3],
        )
        .unwrap();
        let order = topological_order(&g).unwrap();
        let exact = StagedModel::build_with(
            &g,
            &order,
            100,
            &vec![2; 3],
            &Presolve::new(&g, Default::default()),
            None,
        );
        let agg = StagedModel::build_with(
            &g,
            &order,
            100,
            &vec![2; 3],
            &Presolve::new(
                &g,
                crate::presolve::PresolveConfig {
                    level: crate::presolve::PresolveLevel::Aggressive,
                    max_interval_len: None,
                },
            ),
            None,
        );
        assert_eq!(exact.presolve.edges_redundant, 1);
        assert_eq!(exact.presolve.edges_removed, 0, "exact level keeps every cover");
        assert_eq!(agg.presolve.edges_removed, 1);
        assert_eq!(
            agg.model.num_constraints() + 1,
            exact.model.num_constraints(),
            "exactly the redundant edge's cover is gone"
        );
        // loose budget: the relaxation changes nothing here
        assert_eq!(solve_model(&exact), solve_model(&agg));
    }

    #[test]
    fn max_interval_len_caps_retention() {
        let g = fig2_graph();
        let order = topological_order(&g).unwrap();
        let build_l = |l: i64| {
            StagedModel::build_with(
                &g,
                &order,
                100,
                &vec![2; 4],
                &Presolve::new(
                    &g,
                    crate::presolve::PresolveConfig {
                        level: crate::presolve::PresolveLevel::Exact,
                        max_interval_len: Some(l),
                    },
                ),
                None,
            )
        };
        // a cap beyond the horizon changes nothing
        let (st, obj) = solve_model(&build_l(100));
        assert_eq!(st, Status::Optimal);
        assert_eq!(obj, Some(4));
        // L = 2: node 0 can no longer span both consumers (its first
        // use is event 3, its last event 6) → at least one remat
        let (st2, obj2) = solve_model(&build_l(2));
        assert_eq!(st2, Status::Optimal);
        assert!(obj2.unwrap() > 4, "the cap must force rematerialization");
    }

    #[test]
    fn presolved_unstaged_matches_raw_tiny() {
        let g = fig2_graph();
        let order = topological_order(&g).unwrap();
        let pre = Presolve::new(&g, Default::default());
        for budget in [3u64, 100] {
            let raw = StagedModel::build_unstaged(&g, &order, budget, &vec![2; 4]);
            let compact =
                StagedModel::build_unstaged_with(&g, &order, budget, &vec![2; 4], &pre);
            assert_eq!(solve_model(&raw), solve_model(&compact), "budget {budget}");
            assert!(compact.model.num_constraints() < raw.model.num_constraints());
            assert!(compact.model.domain_size_sum() < raw.model.domain_size_sum());
            assert!(compact.presolve.copies_deactivated >= 1, "sink copy dropped");
        }
    }

    #[test]
    fn unstaged_model_tiny() {
        let g = fig2_graph();
        let order = topological_order(&g).unwrap();
        let sm = StagedModel::build_unstaged(&g, &order, 3, &vec![2; 4]);
        assert!(!sm.staged);
        let (bo, guards) = sm.branch_order();
        let solver = Solver {
            guards: Some(guards),
            deadline: Deadline::after(Duration::from_secs(10)),
            ..Default::default()
        };
        let r = solver.solve(&sm.model, &sm.objective, &bo, |_, _| {});
        assert!(r.found(), "unstaged model should solve the 4-node graph");
        let (a, obj) = r.best.unwrap();
        assert_eq!(obj, 4);
        let seq = sm.extract_sequence(&a);
        assert!(eval_sequence(&g, &seq).is_ok());
    }
}
