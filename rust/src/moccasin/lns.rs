//! Phase-2 anytime optimization for large graphs: remat-removal polish
//! plus large-neighbourhood search (LNS) that re-solves stage windows
//! exactly with the CP engine.
//!
//! The paper reaches anytime behaviour through CP-SAT's LCG search over
//! the full model; our engine has no clause learning, so on large graphs
//! we get the same *anytime* characteristics by destroying/repairing
//! windows of the staged model: all retention intervals whose start lies
//! outside the chosen stage window are frozen to the incumbent, the
//! window is re-solved to (window-)optimality, and improvements are
//! accepted. The model being re-solved is exactly the paper's — same
//! variables, cumulative and cover constraints — just with most of it
//! pinned (see DESIGN.md "Substitutions").

use super::model::{event_id, StagedModel};
use super::solution::RematSolution;
use crate::cp::{SearchStats, SearchStrategy, SolveCtx, Solver};
use crate::graph::{Graph, NodeId};
use crate::presolve::Presolve;
use crate::util::{Deadline, Rng};
use std::time::Duration;

/// Remove rematerializations whose removal keeps the sequence feasible.
/// Strictly improving; returns the polished solution (possibly equal to
/// the input).
pub fn removal_polish(graph: &Graph, sol: &RematSolution, budget: u64) -> RematSolution {
    let mut seq = sol.seq.clone();
    let mut best = sol.clone();
    let mut evaluator = crate::graph::Evaluator::new(graph);
    // one scratch sequence reused across every candidate removal —
    // the repair loop used to clone `seq` per candidate
    let mut scratch: Vec<NodeId> = Vec::with_capacity(seq.len());
    let mut counts = vec![0u32; graph.n()];
    loop {
        counts.iter_mut().for_each(|c| *c = 0);
        for &v in &seq {
            counts[v as usize] += 1;
        }
        // candidate positions, most expensive node first
        let mut cands: Vec<usize> = (0..seq.len())
            .filter(|&p| counts[seq[p] as usize] > 1)
            .collect();
        cands.sort_by_key(|&p| std::cmp::Reverse(graph.duration[seq[p] as usize]));
        let mut improved = false;
        for &p in &cands {
            if counts[seq[p] as usize] <= 1 {
                continue;
            }
            scratch.clear();
            scratch.extend_from_slice(&seq[..p]);
            scratch.extend_from_slice(&seq[p + 1..]);
            if let Ok(ev) = evaluator.eval(&scratch) {
                if ev.peak_mem <= budget {
                    counts[seq[p] as usize] -= 1;
                    std::mem::swap(&mut seq, &mut scratch);
                    best = RematSolution { seq: seq.clone(), eval: ev };
                    improved = true;
                    // positions shifted; restart the scan
                    break;
                }
            }
        }
        if !improved {
            return best;
        }
    }
}

/// Assign a stage to every occurrence of the incumbent sequence:
/// first occurrences get their own topological stage; rematerializations
/// get the stage of the next not-yet-first-computed node (they are the
/// "earlier events" of that stage, §2.3). Trailing useless remats are
/// dropped. Returns per-node `(stage, is_first)` lists in sequence
/// order.
///
/// Returns `None` when the sequence's first occurrences do not follow
/// `order` exactly. This is a *validated* precondition, not a
/// `debug_assert!`: the staged model is order-relative, so staging an
/// out-of-order incumbent would silently build a wrong (unsound)
/// window model in release builds — every caller must treat `None` as
/// "this incumbent is not representable against this order".
fn stages_of_incumbent(
    graph: &Graph,
    order: &[NodeId],
    seq: &[NodeId],
) -> Option<Vec<Vec<usize>>> {
    let n = graph.n();
    // explicit membership sentinel: stages are 1-based, so 0 would
    // already never match, but usize::MAX makes "absent from `order`"
    // impossible to confuse with any stage under future renumbering
    let mut topo_index = vec![usize::MAX; n];
    for (i, &v) in order.iter().enumerate() {
        topo_index[v as usize] = i + 1;
    }
    let mut seen = vec![false; n];
    let mut stage_of: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut next_stage = 1usize;
    for &x in seq {
        let xi = x as usize;
        if !seen[xi] {
            if topo_index[xi] != next_stage {
                // out-of-order incumbent, or a node missing from
                // `order` (sentinel): unrepresentable
                return None;
            }
            seen[xi] = true;
            stage_of[xi].push(next_stage);
            next_stage += 1;
        } else if next_stage <= n {
            // remat inside stage `next_stage`; a node occupies one slot
            // per stage, so a duplicate (same node, same stage) would be
            // invalid — merge it (it's redundant anyway).
            if stage_of[xi].last() != Some(&next_stage) {
                stage_of[xi].push(next_stage);
            }
        }
        // occurrences after the last stage are useless → dropped
    }
    Some(stage_of)
}

/// Canonicalize a sequence into staged-event order: assign every
/// occurrence to its (stage, slot) event and rebuild the sequence in
/// event order. The staged CP model charges memory in slot order, so an
/// incumbent must be canonicalized before freezing it into a window
/// model — otherwise a feasible sequence whose within-stage remat order
/// differs from slot order can appear (marginally) infeasible to the
/// cumulative propagator.
///
/// Returns `None` when the sequence is invalid *or* its first
/// occurrences do not follow `order` — an out-of-order sequence can no
/// longer canonicalize silently into a wrong staging (it used to be
/// only a `debug_assert!`, i.e. unchecked in release builds).
pub fn canonicalize(
    graph: &Graph,
    order: &[NodeId],
    seq: &[NodeId],
) -> Option<RematSolution> {
    let stage_of = stages_of_incumbent(graph, order, seq)?;
    let n = graph.n();
    let mut topo_index = vec![0usize; n];
    for (i, &v) in order.iter().enumerate() {
        topo_index[v as usize] = i + 1;
    }
    let mut events: Vec<(usize, usize, NodeId)> = Vec::new(); // (stage, slot, node)
    for v in 0..n {
        for &j in &stage_of[v] {
            events.push((j, topo_index[v], v as NodeId));
        }
    }
    events.sort_unstable();
    let canon: Vec<NodeId> = events.into_iter().map(|(_, _, v)| v).collect();
    RematSolution::from_seq(graph, canon).ok()
}

/// Build the staged model with everything outside `window` (a stage
/// range `[j0, j1)`) frozen to the incumbent, solve the window, and
/// return an improved solution if found.
///
/// `ctx` is the loop's reusable solve context: window re-solves are the
/// hot path this type exists for — every kernel scratch buffer (domain
/// store, trail, queues, watch CSR, cumulative states, search scratch)
/// is stolen from `ctx` and handed back, so the steady-state loop runs
/// without per-window heap allocation (asserted by the counting-
/// allocator regression test).
#[allow(clippy::too_many_arguments)]
fn solve_window(
    graph: &Graph,
    order: &[NodeId],
    budget: u64,
    c: usize,
    incumbent: &RematSolution,
    j0: usize,
    j1: usize,
    deadline: Deadline,
    pre: &Presolve,
    search: SearchStrategy,
    ctx: &mut SolveCtx,
    stats: &mut SearchStats,
) -> Option<RematSolution> {
    // failpoint: a spurious timeout or error makes this window report
    // "no improvement" (the loop's natural failure path); a panic
    // unwinds to the degradation ladder; a delay simulates a slow
    // window for watchdog tests
    crate::fail_point!("lns.window", None);
    let n = graph.n();
    // an unrepresentable incumbent means this window cannot improve it
    // (lns_loop canonicalizes up front, so this only trips on exotic
    // callers) — never a wrong staging
    let stage_of = stages_of_incumbent(graph, order, &incumbent.seq)?;
    // per-node C: at least the incumbent's interval count
    let c_v: Vec<usize> = (0..n).map(|v| c.max(stage_of[v].len())).collect();
    // NOTE (EXPERIMENTS.md §Perf): near-tight budgets the staged event
    // grid can be marginally more pessimistic than the position-space
    // profile, making some frozen incumbents root-conflict (window then
    // reports no improvement, which is safe). Relaxing the cap instead
    // pollutes the B&B bound with eval-infeasible solutions — measured
    // strictly worse. Kept exact.
    //
    // The presolved build runs here too (this is the hot model-build
    // path); `stage_of` rides along as `keep_stages` so dominance can
    // never prune a copy the frozen incumbent occupies.
    let mut sm = StagedModel::build_with(graph, order, budget, &c_v, pre, Some(&stage_of));

    // Freeze: copy 0 is structurally fixed. For copies >= 1:
    // - if the incumbent uses this copy at a stage outside the window →
    //   fix active = 1, start = that event;
    // - if inside the window → leave free (destroyed);
    // - if the copy is unused by the incumbent → leave free (repair may
    //   add remats) but restrict to the window.
    for v in 0..n {
        let k = sm.topo_index[v];
        for ci in 0..sm.by_node[v].len() {
            if ci == 0 {
                continue;
            }
            let iv = sm.intervals[sm.by_node[v][ci]];
            match stage_of[v].get(ci) {
                Some(&j) if j < j0 || j >= j1 => {
                    sm.model.fix(iv.active, 1);
                    sm.model.fix(iv.start, event_id(j, k));
                }
                Some(_) => { /* destroyed: free inside full domain */ }
                None => {
                    // unused copy: restrict to window stages (or disable)
                    let lo = j0.max(k + ci);
                    if lo >= j1 {
                        sm.model.fix(iv.active, 0);
                    } else {
                        // keep full domain; branching prefers a=0 anyway
                    }
                }
            }
        }
    }

    let (bo, guards) = sm.branch_order();
    // NOTE: no shared pruning bound here (`bound: None` via Default) —
    // window re-solves must accept *local* incremental improvements
    // even when a racing portfolio member holds a better global best;
    // the deadline still carries the incumbent for cancellation.
    let solver = Solver {
        deadline,
        node_limit: 50_000,
        guards: Some(guards),
        strategy: search,
        ..Default::default()
    };
    let mut best: Option<RematSolution> = None;
    let r = solver.solve_with_ctx(
        &sm.model,
        &sm.objective,
        &bo,
        |a, _| {
            let seq = sm.extract_sequence(a);
            if let Ok(sol) = RematSolution::from_seq(graph, seq) {
                if sol.feasible(budget)
                    && best
                        .as_ref()
                        .map(|b| sol.eval.duration < b.eval.duration)
                        .unwrap_or(true)
                {
                    best = Some(sol);
                }
            }
        },
        ctx,
    );
    // the raw best assignment was already decoded through the callback;
    // recycle its vector so the next window pops it from the pool
    if let Some((v, _)) = r.best {
        ctx.recycle_solution(v);
    }
    if std::env::var("MOCCASIN_DEBUG_WIN").is_ok() {
        eprintln!(
            "  window [{j0},{j1}): status={:?} nodes={} best={:?} incumbent={}",
            r.status,
            r.stats.nodes,
            best.as_ref().map(|b| b.eval.duration),
            incumbent.eval.duration
        );
    }
    stats.merge(&r.stats);
    stats.presolve.add(&sm.presolve);
    best.filter(|b| b.eval.duration < incumbent.eval.duration)
}

/// The anytime LNS loop: random stage windows, exact re-solve, accept
/// improvements, until the deadline. CP kernel statistics of every
/// window re-solve are accumulated into `stats`; all window re-solves
/// share the caller's `ctx`, so only the first pays kernel allocation.
#[allow(clippy::too_many_arguments)]
pub fn lns_loop(
    graph: &Graph,
    order: &[NodeId],
    budget: u64,
    c: usize,
    window: usize,
    deadline: Deadline,
    rng: &mut Rng,
    pre: &Presolve,
    search: SearchStrategy,
    ctx: &mut SolveCtx,
    mut incumbent: RematSolution,
    stats: &mut SearchStats,
    mut on_improve: impl FnMut(&RematSolution),
) {
    let n = graph.n();
    if n < 3 {
        return;
    }
    let dbg = std::env::var("MOCCASIN_DEBUG").is_ok();
    // the staged model charges memory in slot order: canonicalize the
    // incumbent (and accept it if it improves or ties)
    if let Some(c) = canonicalize(graph, order, &incumbent.seq) {
        if c.feasible(budget) {
            if c.eval.duration < incumbent.eval.duration {
                on_improve(&c);
            }
            if c.eval.duration <= incumbent.eval.duration {
                incumbent = c;
            }
        } else if dbg {
            eprintln!(
                "lns: canonical incumbent infeasible (peak {} > {budget}); windows may fail",
                c.eval.peak_mem
            );
        }
    }
    // An incumbent that cannot be staged against `order` can never be
    // improved by a window re-solve (solve_window would return None on
    // every iteration): bail out instead of burning the whole time
    // budget spinning through no-op windows.
    if stages_of_incumbent(graph, order, &incumbent.seq).is_none() {
        if dbg {
            eprintln!("lns: incumbent not representable against the input order; giving up");
        }
        return;
    }
    let mut iters = 0usize;
    let mut wins = 0usize;
    let w = window.clamp(3, n);
    let mut stall = 0usize;
    while !deadline.exceeded() {
        iters += 1;
        // pick a window: uniformly random, occasionally centred on the
        // peak-memory position of the incumbent
        let j0 = if stall % 5 == 4 {
            // centre on the stage of the peak position
            let stage = incumbent
                .seq
                .iter()
                .take(incumbent.eval.peak_pos + 1)
                .copied()
                .collect::<std::collections::HashSet<_>>()
                .len();
            stage.saturating_sub(w / 2).max(2)
        } else {
            2 + rng.gen_range(n.saturating_sub(w).max(1))
        };
        let j1 = (j0 + w).min(n + 1);
        let slice = Duration::from_millis(1500).min(deadline.remaining());
        if slice.is_zero() {
            break;
        }
        // The sub-deadline inherits the shared incumbent, so window
        // re-solves prune against (and are cancelled by) the portfolio.
        // Deadline-gap audit (PR 7): besides this per-iteration poll,
        // the window's propagation engine checks cancellation and the
        // slice's hard stop *inside* each fixpoint call
        // (`PropagationEngine::watchdog_tick`), so a window wedged in a
        // single propagation pass cannot overrun the slice unbounded.
        let sub_deadline = deadline.sub(slice);
        match solve_window(
            graph, order, budget, c, &incumbent, j0, j1, sub_deadline, pre, search, ctx, stats,
        ) {
            Some(better) => {
                wins += 1;
                incumbent = better;
                on_improve(&incumbent);
                stall = 0;
            }
            None => {
                stall += 1;
            }
        }
    }
    if dbg {
        eprintln!(
            "lns: {iters} iterations, {wins} improvements, final duration {}",
            incumbent.eval.duration
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generators::random_layered;
    use crate::graph::topological_order;
    use crate::moccasin::greedy::greedy_remat;

    #[test]
    fn removal_polish_strips_useless_remats() {
        let g = Graph::from_edges(
            "d",
            4,
            &[(0, 1), (0, 2), (1, 3), (2, 3)],
            vec![1; 4],
            vec![1; 4],
        )
        .unwrap();
        // sequence with a pointless recompute of 0
        let sol = RematSolution::from_seq(&g, vec![0, 1, 2, 0, 3]).unwrap();
        let p = removal_polish(&g, &sol, 10);
        assert_eq!(p.eval.remat_count, 0);
        assert_eq!(p.seq.len(), 4);
    }

    #[test]
    fn removal_polish_respects_budget() {
        // remat needed at budget 10 (see greedy tests)
        let g = Graph::from_edges(
            "c",
            5,
            &[(0, 1), (1, 2), (2, 3), (3, 4), (0, 4)],
            vec![1, 1, 1, 1, 1],
            vec![5, 4, 4, 4, 1],
        )
        .unwrap();
        let order = topological_order(&g).unwrap();
        let sol = greedy_remat(&g, &order, 10).unwrap();
        let p = removal_polish(&g, &sol, 10);
        assert!(p.feasible(10));
        assert!(p.eval.remat_count >= 1, "cannot remove the load-bearing remat");
    }

    #[test]
    fn stages_assignment_roundtrip() {
        let g = Graph::from_edges(
            "d",
            4,
            &[(0, 1), (0, 2), (1, 3), (2, 3)],
            vec![1; 4],
            vec![1; 4],
        )
        .unwrap();
        let order = topological_order(&g).unwrap(); // [0,1,2,3]
        let st = stages_of_incumbent(&g, &order, &[0, 1, 2, 0, 3]).unwrap();
        assert_eq!(st[0], vec![1, 4]); // first at stage 1, remat in stage 4
        assert_eq!(st[3], vec![4]);
    }

    #[test]
    fn out_of_order_incumbent_is_rejected_not_silently_staged() {
        // Regression (release-build soundness): an incumbent whose
        // first occurrences do not follow the input topological order
        // used to pass a `debug_assert!` silently in release builds and
        // build a wrong staging, freezing unsound LNS windows. It must
        // be rejected by validation in every build profile.
        let g = Graph::from_edges(
            "d",
            4,
            &[(0, 1), (0, 2), (1, 3), (2, 3)],
            vec![1; 4],
            vec![1; 4],
        )
        .unwrap();
        let order = topological_order(&g).unwrap(); // [0,1,2,3]
        // 2 appears before 1: valid DAG execution, but out of `order`
        assert!(stages_of_incumbent(&g, &order, &[0, 2, 1, 3]).is_none());
        assert!(canonicalize(&g, &order, &[0, 2, 1, 3]).is_none());
        // a node missing from `order` (topo_index 0) is also rejected
        assert!(stages_of_incumbent(&g, &order[..3], &[0, 1, 2, 3]).is_none());
        // the in-order sequence still canonicalizes
        assert!(canonicalize(&g, &order, &[0, 1, 2, 0, 3]).is_some());
    }

    #[test]
    fn lns_improves_greedy_on_random_graph() {
        let g = random_layered("t", 60, 150, 12);
        let order = topological_order(&g).unwrap();
        let peak = g.peak_mem_no_remat(&order).unwrap();
        let budget = (peak as f64 * 0.9) as u64;
        let greedy = greedy_remat(&g, &order, budget).unwrap();
        let polished = removal_polish(&g, &greedy, budget);
        let mut best = polished.clone();
        let mut rng = Rng::seed_from_u64(1);
        let mut stats = SearchStats::default();
        let mut ctx = SolveCtx::default();
        lns_loop(
            &g,
            &order,
            budget,
            2,
            10,
            Deadline::after(Duration::from_secs(4)),
            &mut rng,
            &Presolve::new(&g, Default::default()),
            SearchStrategy::default(),
            &mut ctx,
            polished.clone(),
            &mut stats,
            |s| best = s.clone(),
        );
        assert!(best.eval.duration <= polished.eval.duration);
        assert!(best.feasible(budget));
        assert!(stats.propagations > 0, "window re-solves must report kernel stats");
    }
}
