//! Exact branch & bound over the full staged model (small graphs).
//!
//! Used (a) to prove optimality on small instances — mirroring what
//! CP-SAT achieves on the paper's smaller graphs — and (b) as the
//! window re-solver inside LNS (through `solve_window` in [`super::lns`]).

use super::model::StagedModel;
use super::solution::RematSolution;
use crate::cp::{SearchStats, SearchStrategy, SolveCtx, Solver, Status};
use crate::graph::{Graph, NodeId};
use crate::presolve::Presolve;
use crate::util::Deadline;

/// Result of an exact solve.
pub struct ExactResult {
    /// Search space exhausted (under any incumbent pruning bound): no
    /// solution strictly better than [`ExactResult::best_duration`] —
    /// or than the shared incumbent's bound — exists.
    pub proved_optimal: bool,
    /// Best validated duration the exact search itself found
    /// (`u64::MAX` if everything was pruned or infeasible).
    pub best_duration: u64,
    /// CP kernel statistics for the run (nodes, propagations, event
    /// counters).
    pub stats: SearchStats,
}

/// Run B&B on the full model, built through the root presolve.
/// `on_solution` fires for each improving extracted solution (already
/// validated). With a non-exactness-preserving presolve (aggressive
/// level or an interval-length cap), exhausting the search space does
/// not prove anything about the original problem, so
/// [`ExactResult::proved_optimal`] stays false.
///
/// `ctx` is the caller's reusable solve context: the CP kernel steals
/// its scratch buffers and hands them back before returning, so a
/// caller running exact + LNS (or several ladder rungs) pays the kernel
/// allocation cost once per [`super::MoccasinSolver`] solve.
#[allow(clippy::too_many_arguments)]
pub fn solve_exact(
    graph: &Graph,
    order: &[NodeId],
    budget: u64,
    c: usize,
    deadline: Deadline,
    staged: bool,
    pre: &Presolve,
    search: SearchStrategy,
    ctx: &mut SolveCtx,
    mut on_solution: impl FnMut(&RematSolution),
) -> ExactResult {
    let c_v = vec![c; graph.n()];
    let sm = if staged {
        StagedModel::build_with(graph, order, budget, &c_v, pre, None)
    } else {
        StagedModel::build_unstaged_with(graph, order, budget, &c_v, pre)
    };
    let (bo, guards) = sm.branch_order();
    // full model: prune against the best duration found by any
    // cooperating solver (riding along on the deadline)
    let bound = deadline.incumbent().cloned();
    let solver = Solver {
        deadline,
        bound,
        guards: Some(guards),
        strategy: search,
        ..Default::default()
    };
    let mut best_duration = u64::MAX;
    let r = solver.solve_with_ctx(
        &sm.model,
        &sm.objective,
        &bo,
        |a, _| {
            let seq = sm.extract_sequence(a);
            if let Ok(sol) = RematSolution::from_seq(graph, seq) {
                if sol.feasible(budget) && sol.eval.duration < best_duration {
                    best_duration = sol.eval.duration;
                    on_solution(&sol);
                }
            }
        },
        ctx,
    );
    // the best-assignment vector is consumed here (solutions were
    // already extracted through the callback) — return it to the pool
    if let Some((v, _)) = r.best {
        ctx.recycle_solution(v);
    }
    let mut stats = r.stats;
    stats.presolve.add(&sm.presolve);
    ExactResult {
        proved_optimal: (r.status == Status::Optimal || r.status == Status::Infeasible)
            && pre.exactness_preserving(),
        best_duration,
        stats,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{topological_order, Graph};
    use std::time::Duration;

    #[test]
    fn proves_optimality_on_diamond() {
        let g = Graph::from_edges(
            "d",
            4,
            &[(0, 1), (0, 2), (1, 3), (2, 3)],
            vec![1; 4],
            vec![1; 4],
        )
        .unwrap();
        let order = topological_order(&g).unwrap();
        let mut best = None;
        let mut ctx = SolveCtx::default();
        let r = solve_exact(
            &g,
            &order,
            3,
            2,
            Deadline::after(Duration::from_secs(10)),
            true,
            &Presolve::new(&g, Default::default()),
            SearchStrategy::default(),
            &mut ctx,
            |s| best = Some(s.clone()),
        );
        assert!(r.proved_optimal);
        assert_eq!(r.best_duration, 4);
        assert!(best.unwrap().feasible(3));
    }

    #[test]
    fn detects_infeasible_budget() {
        let g = Graph::from_edges("d", 2, &[(0, 1)], vec![1, 1], vec![5, 5]).unwrap();
        let order = topological_order(&g).unwrap();
        // node 1's working set is 10 > 9
        let r = solve_exact(
            &g,
            &order,
            9,
            2,
            Deadline::after(Duration::from_secs(5)),
            true,
            &Presolve::new(&g, Default::default()),
            SearchStrategy::default(),
            &mut SolveCtx::default(),
            |_| {},
        );
        assert!(r.proved_optimal); // proved infeasible
        assert_eq!(r.best_duration, u64::MAX);
    }
}
