//! Phase-1 feasibility heuristic (§2.4).
//!
//! The paper's Phase 1 solves the CP with objective `max(M_var, M)` to
//! obtain a budget-feasible incumbent, noting that "any topological
//! order of the graph provides a trivial feasible solution" to the
//! relaxed problem. We implement a constructive planner with the same
//! role: start from the input order (no rematerialization) and, while
//! the Appendix-A.3 profile exceeds the budget anywhere, **split a
//! retention interval at a hot position**: pick a tensor that is
//! resident-but-idle across an overflow position and insert a fresh
//! recomputation of it (together with the recompute chain of any
//! ancestors whose reuse would drag their own retentions back across
//! the hot position) right before its next use. Every candidate is
//! scored with the exact sequence evaluator; the accepted move must
//! strictly decrease the lexicographic measure (total overflow, peak,
//! plateau width), so the loop terminates. When no single split
//! improves, a two-step lookahead (split + repair split) is tried
//! before giving up.
//!
//! The result is always a *valid* sequence with peak ≤ budget on
//! success; Phase 2 then only shrinks duration.

use super::solution::RematSolution;
use crate::graph::{Evaluator, Graph, NodeId, SeqEval};

/// A candidate move: insert `chain` (topo-ordered recompute chain,
/// ending with the split node) at position `insert_at`.
struct Cand {
    insert_at: usize,
    chain: Vec<NodeId>,
    /// tensor size of the split node (sort key)
    size: u64,
}

/// Planner state: sequence + evaluation + profile + overflow.
struct State {
    seq: Vec<NodeId>,
    ev: SeqEval,
    profile: Vec<u64>,
    overflow: u64,
}

impl State {
    fn measure(&self) -> (u64, u64, usize) {
        (self.overflow, self.ev.peak_mem, self.ev.peak_count)
    }
}

fn overflow_of(profile: &[u64], budget: u64) -> u64 {
    profile.iter().map(|&m| m.saturating_sub(budget)).sum()
}

fn eval_state(
    graph: &Graph,
    evaluator: &mut Evaluator,
    seq: Vec<NodeId>,
    budget: u64,
) -> Option<State> {
    let _ = graph;
    let (ev, profile) = evaluator.eval_profile(&seq).ok()?;
    let overflow = overflow_of(&profile, budget);
    Some(State { seq, ev, profile, overflow })
}

/// Generate split candidates for the current state, best-first (largest
/// split tensor first).
fn gen_candidates(graph: &Graph, st: &State, budget: u64) -> Vec<Cand> {
    let n = graph.n();
    let seq = &st.seq;
    // hot positions: global peak + up to two more overflow maxima from
    // distinct regions
    let mut hot: Vec<usize> = vec![st.ev.peak_pos];
    {
        let mut idx: Vec<usize> =
            (0..st.profile.len()).filter(|&i| st.profile[i] > budget).collect();
        idx.sort_unstable_by_key(|&i| std::cmp::Reverse(st.profile[i]));
        for &i in &idx {
            if hot.len() >= 3 {
                break;
            }
            if hot.iter().all(|&h| i.abs_diff(h) > 4) {
                hot.push(i);
            }
        }
    }

    // instance consumers + releases
    let mut last_occ = vec![usize::MAX; n];
    let mut consumers: Vec<Vec<usize>> = vec![Vec::new(); seq.len()];
    for (q, &z) in seq.iter().enumerate() {
        for &v in &graph.preds[z as usize] {
            consumers[last_occ[v as usize]].push(q);
        }
        last_occ[z as usize] = q;
    }
    let release: Vec<usize> = consumers
        .iter()
        .enumerate()
        .map(|(p, cons)| cons.last().copied().unwrap_or(p))
        .collect();
    let mut inst_of_node: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (p, &v) in seq.iter().enumerate() {
        inst_of_node[v as usize].push(p);
    }
    let last_inst_before = |v: usize, q: usize| -> usize {
        let occ = &inst_of_node[v];
        let i = occ.partition_point(|&p| p < q);
        debug_assert!(i > 0, "pred never computed before use");
        occ[i - 1]
    };

    let mut cands: Vec<Cand> = Vec::new();
    let mut seen_move = std::collections::HashSet::new();
    for &hot_pos in &hot {
        for (p, cons) in consumers.iter().enumerate() {
            if p >= hot_pos {
                continue;
            }
            let Some(&last_use) = cons.last() else { continue };
            let v = seq[p];
            if last_use <= hot_pos {
                continue; // not live past this hot position
            }
            if cons.iter().any(|&q| q == hot_pos) {
                continue; // input of the hot op: unavoidable there
            }
            // `last_use > hot_pos` above guarantees a later consumer
            let Some(&nxt) = cons.iter().find(|&&q| q > hot_pos) else { continue };
            if !seen_move.insert((v, nxt)) {
                continue;
            }
            // Build recompute-chain variants and let the evaluator pick:
            // recomputing a dead ancestor fresh avoids stretching its old
            // retention back across the hot position, but deep closures
            // cost duration and transient memory — the right depth is
            // instance-specific.
            // depth-limited dead-ancestor closure
            let closure = |max_depth: usize| -> Option<Vec<NodeId>> {
                let mut chain: Vec<NodeId> = Vec::new();
                let mut mark = std::collections::HashSet::new();
                let mut stack = vec![(v, 0usize)];
                mark.insert(v);
                while let Some((x, d)) = stack.pop() {
                    chain.push(x);
                    if d >= max_depth {
                        continue;
                    }
                    for &pr in &graph.preds[x as usize] {
                        if mark.contains(&pr) {
                            continue;
                        }
                        let inst = last_inst_before(pr as usize, nxt);
                        if release[inst] < hot_pos {
                            mark.insert(pr);
                            stack.push((pr, d + 1));
                        }
                    }
                    if chain.len() > 1 + n / 2 {
                        return None;
                    }
                }
                chain.sort_unstable_by_key(|&x| inst_of_node[x as usize][0]);
                Some(chain)
            };
            let mut variants: Vec<Vec<NodeId>> = Vec::new();
            for depth in [0usize, 2, usize::MAX] {
                if let Some(ch) = closure(depth) {
                    if !variants.contains(&ch) {
                        variants.push(ch);
                    }
                }
            }
            for chain in variants {
                cands.push(Cand { insert_at: nxt, chain, size: graph.mem[v as usize] });
            }
        }
    }
    cands.sort_by(|a, b| b.size.cmp(&a.size));
    cands
}

fn apply_cand(seq: &[NodeId], c: &Cand) -> Vec<NodeId> {
    let mut t = Vec::with_capacity(seq.len() + c.chain.len());
    t.extend_from_slice(&seq[..c.insert_at]);
    t.extend_from_slice(&c.chain);
    t.extend_from_slice(&seq[c.insert_at..]);
    t
}

/// Best strictly-improving single split, if any.
fn best_single_split(
    graph: &Graph,
    evaluator: &mut Evaluator,
    st: &State,
    budget: u64,
) -> Option<State> {
    let cands = gen_candidates(graph, st, budget);
    let mut best: Option<State> = None;
    for c in &cands {
        if let Some(ns) = eval_state(graph, evaluator, apply_cand(&st.seq, c), budget) {
            if ns.measure() < st.measure()
                && best.as_ref().map(|b| ns.measure() < b.measure()).unwrap_or(true)
            {
                best = Some(ns);
            }
        }
    }
    best
}

/// Produce a budget-feasible rematerialization sequence starting from
/// `order`. Returns `None` if the planner cannot reach the budget.
pub fn greedy_remat(graph: &Graph, order: &[NodeId], budget: u64) -> Option<RematSolution> {
    let n = graph.n();
    debug_assert_eq!(order.len(), n);
    let mut evaluator = Evaluator::new(graph);
    let mut st = eval_state(graph, &mut evaluator, order.to_vec(), budget)?;
    // one accepted move per iteration; generous bound for termination
    let max_iters = 10 * n + 100;

    let dbg = std::env::var("MOCCASIN_DEBUG").is_ok();
    for it in 0..max_iters {
        if dbg {
            eprintln!(
                "iter {it}: overflow={} peak={} pos={} count={} len={}",
                st.overflow, st.ev.peak_mem, st.ev.peak_pos, st.ev.peak_count, st.seq.len()
            );
        }
        if st.overflow == 0 {
            debug_assert!(st.ev.peak_mem <= budget);
            return Some(RematSolution { seq: st.seq, eval: st.ev });
        }
        if let Some(ns) = best_single_split(graph, &mut evaluator, &st, budget) {
            st = ns;
            continue;
        }
        // Two-step lookahead: apply a top candidate even though it
        // regresses, then repair with the best single split on the
        // result; accept the pair if the combined effect improves.
        let cands = gen_candidates(graph, &st, budget);
        let mut pair: Option<State> = None;
        for c in cands.iter().take(8) {
            let Some(mid) = eval_state(graph, &mut evaluator, apply_cand(&st.seq, c), budget)
            else {
                continue;
            };
            if let Some(fin) = best_single_split(graph, &mut evaluator, &mid, budget) {
                if fin.measure() < st.measure()
                    && pair.as_ref().map(|p| fin.measure() < p.measure()).unwrap_or(true)
                {
                    pair = Some(fin);
                }
            }
        }
        match pair {
            Some(p) => st = p,
            None => {
                if dbg {
                    eprintln!("  STUCK cands={}", cands.len());
                    // composition at the peak
                    let hot = st.ev.peak_pos;
                    let seq = &st.seq;
                    let mut last_occ = vec![usize::MAX; n];
                    let mut consumers: Vec<Vec<usize>> = vec![Vec::new(); seq.len()];
                    for (q, &z) in seq.iter().enumerate() {
                        for &v in &graph.preds[z as usize] {
                            consumers[last_occ[v as usize]].push(q);
                        }
                        last_occ[z as usize] = q;
                    }
                    let (mut inputs, mut cross, mut ncross) = (0u64, 0u64, 0usize);
                    for (p, cons) in consumers.iter().enumerate() {
                        if p >= hot { continue; }
                        let rel = cons.last().copied().unwrap_or(p);
                        if rel < hot { continue; }
                        if cons.iter().any(|&q| q == hot) {
                            inputs += graph.mem[seq[p] as usize];
                        } else if rel > hot {
                            cross += graph.mem[seq[p] as usize];
                            ncross += 1;
                            eprintln!("    cross inst p={p} node={} rel={rel}", seq[p]);
                        }
                    }
                    eprintln!(
                        "  hot={hot} self={} inputs={inputs} cross={cross} ncross={ncross} \
                         load={}",
                        graph.mem[seq[hot] as usize],
                        st.profile[hot]
                    );
                    for c in cands.iter().take(12) {
                        let ns = eval_state(graph, &mut evaluator, apply_cand(&st.seq, c), budget);
                        match ns {
                            Some(ns) => eprintln!(
                                "  cand node={} size={} ins={} chain={} -> of={} peak={}",
                                c.chain.last().copied().unwrap_or_default(),
                                c.size, c.insert_at, c.chain.len(),
                                ns.overflow, ns.ev.peak_mem
                            ),
                            None => eprintln!("  cand invalid"),
                        }
                    }
                }
                return None; // genuinely stuck: budget unreachable
            }
        }
    }
    (st.ev.peak_mem <= budget).then(|| RematSolution { seq: st.seq, eval: st.ev })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generators::{random_layered, real_world_like};
    use crate::graph::topological_order;

    /// 0→1→2→3→4 plus the long skip 0→4, with a heavy source tensor:
    /// holding node 0's output across the whole chain is the memory hog;
    /// dropping it after node 1 and recomputing it before node 4 trades
    /// one recompute for 3 units of peak memory.
    /// No-remat peak = 13 (m0+m1+m2 at step 2); with remat of 0 the
    /// optimal sequence [0,1,2,3,0,4] peaks at 10 (= node 4's working
    /// set, the structural floor).
    fn chain_graph() -> Graph {
        Graph::from_edges(
            "c",
            5,
            &[(0, 1), (1, 2), (2, 3), (3, 4), (0, 4)],
            vec![1, 1, 1, 1, 1],
            vec![5, 4, 4, 4, 1],
        )
        .unwrap()
    }

    #[test]
    fn loose_budget_no_remat() {
        let g = chain_graph();
        let order = topological_order(&g).unwrap();
        let sol = greedy_remat(&g, &order, 1000).unwrap();
        assert_eq!(sol.eval.remat_count, 0);
        assert_eq!(sol.seq.len(), 5);
    }

    #[test]
    fn tight_budget_induces_remat() {
        let g = chain_graph();
        let order = topological_order(&g).unwrap();
        let no_remat = g.peak_mem_no_remat(&order).unwrap();
        assert_eq!(no_remat, 13);
        let sol = greedy_remat(&g, &order, 10).expect("feasible with remat");
        assert!(sol.eval.peak_mem <= 10);
        assert!(sol.eval.remat_count >= 1);
    }

    #[test]
    fn impossible_budget_returns_none() {
        let g = chain_graph();
        let order = topological_order(&g).unwrap();
        // node 4's working set is m0+m3+m4 = 10 — no sequence fits in 9
        assert_eq!(g.working_set_floor(), 10);
        assert!(greedy_remat(&g, &order, 9).is_none());
    }

    #[test]
    fn random_graphs_feasible_at_90pct() {
        for seed in 0..5 {
            let g = random_layered("t", 120, 300, seed);
            let order = topological_order(&g).unwrap();
            let peak = g.peak_mem_no_remat(&order).unwrap();
            let budget = (peak as f64 * 0.9) as u64;
            let sol = greedy_remat(&g, &order, budget)
                .unwrap_or_else(|| panic!("seed {seed}: greedy infeasible at 90%"));
            assert!(sol.eval.peak_mem <= budget, "seed {seed}");
        }
    }

    #[test]
    fn real_world_like_feasible_at_90pct() {
        let g = real_world_like("t", 150, 400, 7);
        let order = topological_order(&g).unwrap();
        let peak = g.peak_mem_no_remat(&order).unwrap();
        let sol = greedy_remat(&g, &order, (peak as f64 * 0.9) as u64).unwrap();
        assert!(sol.feasible((peak as f64 * 0.9) as u64));
    }

    #[test]
    fn exact_budget_equals_peak_is_identity() {
        let g = chain_graph();
        let order = topological_order(&g).unwrap();
        let peak = g.peak_mem_no_remat(&order).unwrap();
        let sol = greedy_remat(&g, &order, peak).unwrap();
        assert_eq!(sol.eval.remat_count, 0);
    }

    #[test]
    fn deep_budget_cut_terminates() {
        // push far below 80% — the planner should keep splitting
        // (cascading remats) without panicking or looping forever;
        // feasibility that deep is not guaranteed for a heuristic.
        let g = random_layered("t", 120, 300, 0);
        let order = topological_order(&g).unwrap();
        let peak = g.peak_mem_no_remat(&order).unwrap();
        let floor = g.working_set_floor();
        let budget = floor + (peak - floor) / 4;
        if let Some(sol) = greedy_remat(&g, &order, budget) {
            assert!(sol.eval.peak_mem <= budget);
        }
    }
}
