//! Solution representation and sequence ⇄ interval conversions.

use crate::graph::{eval_sequence, Graph, NodeId, SeqEval};

/// A rematerialization solution: the executable sequence plus its
/// Appendix-A.3 evaluation. Every constructor re-evaluates the sequence,
/// so `eval` can always be trusted.
#[derive(Debug, Clone)]
pub struct RematSolution {
    /// The executable (re)computation sequence.
    pub seq: Vec<NodeId>,
    /// Its Appendix-A.3 evaluation (always consistent with `seq`).
    pub eval: SeqEval,
}

impl RematSolution {
    /// Build from a sequence, validating it against the graph.
    pub fn from_seq(graph: &Graph, seq: Vec<NodeId>) -> Result<Self, crate::graph::SeqError> {
        let eval = eval_sequence(graph, &seq)?;
        Ok(RematSolution { seq, eval })
    }

    /// Is this solution within the memory budget?
    pub fn feasible(&self, budget: u64) -> bool {
        self.eval.peak_mem <= budget
    }
}

/// A retention interval in *sequence position* coordinates: node `v` is
/// computed at position `start` and its output retained through
/// `end` (inclusive), per the minimal-retention semantics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetentionInterval {
    /// The node whose output this interval retains.
    pub node: NodeId,
    /// Sequence position of the (re)computation.
    pub start: usize,
    /// Last sequence position at which the output is retained
    /// (inclusive).
    pub end: usize,
}

/// Derive the (minimal) retention intervals of a sequence: instance at
/// position `p` is retained until the last consumer occurrence that
/// reads it. This is the inverse of interval-model extraction and is
/// used to warm-start / window-freeze the CP model from an incumbent
/// sequence.
pub fn intervals_from_sequence(graph: &Graph, seq: &[NodeId]) -> Vec<RetentionInterval> {
    let n = graph.n();
    let mut last_occ = vec![usize::MAX; n];
    let mut release: Vec<usize> = (0..seq.len()).collect();
    for (q, &z) in seq.iter().enumerate() {
        for &v in &graph.preds[z as usize] {
            let p = last_occ[v as usize];
            debug_assert_ne!(p, usize::MAX, "sequence must be valid");
            if release[p] < q {
                release[p] = q;
            }
        }
        last_occ[z as usize] = q;
    }
    seq.iter()
        .enumerate()
        .map(|(p, &v)| RetentionInterval { node: v, start: p, end: release[p] })
        .collect()
}

/// Count the number of intervals per node (to check against `C_v`).
pub fn intervals_per_node(graph: &Graph, seq: &[NodeId]) -> Vec<usize> {
    let mut counts = vec![0usize; graph.n()];
    for &v in seq {
        counts[v as usize] += 1;
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;

    fn diamond() -> Graph {
        Graph::from_edges(
            "d",
            4,
            &[(0, 1), (0, 2), (1, 3), (2, 3)],
            vec![1; 4],
            vec![1; 4],
        )
        .unwrap()
    }

    #[test]
    fn from_seq_validates() {
        let g = diamond();
        assert!(RematSolution::from_seq(&g, vec![0, 1, 2, 3]).is_ok());
        assert!(RematSolution::from_seq(&g, vec![1, 0, 2, 3]).is_err());
    }

    #[test]
    fn feasibility_check() {
        let g = diamond();
        let s = RematSolution::from_seq(&g, vec![0, 1, 2, 3]).unwrap();
        assert!(s.feasible(3));
        assert!(!s.feasible(2));
    }

    #[test]
    fn intervals_match_minimal_retention() {
        let g = diamond();
        let iv = intervals_from_sequence(&g, &[0, 1, 2, 3]);
        // node 0 read by 1 (pos 1) and 2 (pos 2) → [0, 2]
        assert_eq!(iv[0], RetentionInterval { node: 0, start: 0, end: 2 });
        // node 1 read by 3 → [1, 3]
        assert_eq!(iv[1], RetentionInterval { node: 1, start: 1, end: 3 });
        // node 3 never read → [3, 3]
        assert_eq!(iv[3], RetentionInterval { node: 3, start: 3, end: 3 });
    }

    #[test]
    fn intervals_with_remat_split() {
        let g = diamond();
        let iv = intervals_from_sequence(&g, &[0, 1, 0, 2, 3]);
        // first instance of 0 read by 1 only → [0,1]
        assert_eq!(iv[0], RetentionInterval { node: 0, start: 0, end: 1 });
        // second instance of 0 read by 2 at pos 3 → [2,3]
        assert_eq!(iv[2], RetentionInterval { node: 0, start: 2, end: 3 });
    }

    #[test]
    fn per_node_counts() {
        let g = diamond();
        let c = intervals_per_node(&g, &[0, 1, 0, 2, 3]);
        assert_eq!(c, vec![2, 1, 1, 1]);
    }
}
