//! MOCCASIN: the paper's retention-interval formulation and its solvers.
//!
//! The problem (paper §1): given a compute DAG, find a rematerialization
//! sequence minimizing total duration subject to peak local memory ≤ M.
//! MOCCASIN models it with **retention intervals** (§2): node `v` gets up
//! to `C_v` intervals `(s_v^i, e_v^i, a_v^i)` over an event-based time
//! domain; the start of an interval is the (re)computation event, the
//! interval is the residency of the output in local memory. Memory is a
//! `cumulative` constraint, precedence a reservoir-style cover
//! constraint, and the objective is `Σ w_v a_v^i` — O(n) integer
//! variables instead of CHECKMATE's O(n²) Booleans.
//!
//! Module map:
//! * [`model`] — the staged (§2.3) and unstaged (§2.1) CP models over
//!   the in-tree CP engine, plus variable/constraint counting (Table 1).
//! * [`greedy`] — Phase-1 feasibility (§2.4): an on-demand recompute
//!   simulator with Belady-style eviction that produces a
//!   budget-feasible sequence from any topological order.
//! * [`solution`] — sequence ⇄ retention-interval conversions and the
//!   solution type; every solution is re-validated against the
//!   Appendix-A.3 evaluator.
//! * [`exact`] — full-model branch & bound (small graphs; optimality).
//! * [`lns`] — the anytime loop for large graphs: remat-removal polish +
//!   large-neighbourhood search that re-solves stage windows exactly
//!   with the CP engine.
//!
//! The top-level entry point is [`MoccasinSolver::solve`], which runs
//! two phases exactly as §2.4 describes (Phase 1 feasibility → Phase 2
//! duration minimization warm-started from Phase 1) and reports an
//! anytime progress trace (used by the Figure 1/5/6 benches).

pub mod degradation;
pub mod exact;
pub mod greedy;
pub mod lns;
pub mod model;
pub mod solution;

pub use degradation::{Degradation, PhaseBudgets, PhaseSpend, Rung};
pub use model::{IntervalVars, StagedModel};
pub use solution::{intervals_from_sequence, RematSolution};

use crate::cp::{SearchMode, SearchStats, SearchStrategy, SolveCtx};
use crate::graph::{topological_order, Graph, NodeId};
use crate::presolve::{GraphAnalysis, Presolve, PresolveConfig};
use crate::util::{Deadline, Incumbent, Rng};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;
use std::time::Duration;

/// One point of an anytime progress trace: (elapsed, best duration,
/// best TDI %).
#[derive(Debug, Clone, Copy)]
pub struct ProgressPoint {
    /// Wall-clock time since the solve started.
    pub elapsed: Duration,
    /// Best total duration at that point.
    pub duration: u64,
    /// Best total-duration-increase percentage at that point.
    pub tdi_percent: f64,
}

/// Outcome of a MOCCASIN solve.
#[derive(Debug, Clone)]
pub struct SolveOutcome {
    /// Best solution found (None if even Phase 1 failed — budget below
    /// any achievable footprint).
    pub best: Option<RematSolution>,
    /// Anytime trace of improving solutions (Phase-1 time included, as
    /// in the paper's shifted curves).
    pub trace: Vec<ProgressPoint>,
    /// Whether the exact search proved optimality (small graphs only).
    pub proved_optimal: bool,
    /// Time spent in Phase 1.
    pub phase1_time: Duration,
    /// Aggregated CP kernel statistics across the exact solve and every
    /// LNS window re-solve (nodes, propagations, event counters).
    pub stats: SearchStats,
    /// Degradation provenance: which ladder rung produced the answer,
    /// every failure absorbed along the way, and per-phase wall-clock
    /// spend. [`Degradation::is_clean`] is `true` on a fault-free run.
    pub degradation: Degradation,
}

/// Configuration of the MOCCASIN solver (paper defaults: `C = 2`,
/// staged model on a given input topological order).
#[derive(Debug, Clone)]
pub struct MoccasinSolver {
    /// Max number of retention intervals per node (`C_v`, uniform).
    pub c: usize,
    /// Wall-clock limit for the whole solve.
    pub time_limit: Duration,
    /// Enforce an input topological order (§2.3). The paper uses this in
    /// all experiments.
    pub staged: bool,
    /// Threshold (in nodes) below which the full exact model is run to
    /// prove optimality.
    pub exact_threshold: usize,
    /// LNS stage-window size.
    pub window: usize,
    /// RNG seed (LNS neighbourhood selection).
    pub seed: u64,
    /// Shared portfolio incumbent: when set, every improving solution is
    /// published to it, the exact/LNS branch & bound prunes against the
    /// best duration found by *any* cooperating solver, and cooperative
    /// cancellation stops this solve early. `None` (the default) gives a
    /// private incumbent, which still lets the exact phase prune against
    /// the Phase-1 warm start.
    pub incumbent: Option<Arc<Incumbent>>,
    /// Root presolve configuration applied to every CP model built
    /// during the solve (exact B&B and every LNS window re-solve).
    /// Default: the exactness-preserving level.
    pub presolve: PresolveConfig,
    /// Optional pre-computed graph analysis: the portfolio computes it
    /// once per request and shares it across racing members; `None`
    /// analyzes lazily per solve.
    pub analysis: Option<Arc<GraphAnalysis>>,
    /// CP kernel search strategy used by the exact B&B and every LNS
    /// window re-solve (chronological DFS or conflict-driven learned
    /// search — both exact; see [`SearchStrategy`]).
    pub search: SearchStrategy,
    /// Per-phase wall-clock budget partition. `None` (the default)
    /// splits `time_limit` with [`PhaseBudgets::split`]; the exact
    /// search phase is capped at its slice so a pathological proof
    /// attempt cannot starve the anytime LNS polish.
    pub budgets: Option<PhaseBudgets>,
}

impl Default for MoccasinSolver {
    fn default() -> Self {
        MoccasinSolver {
            c: 2,
            time_limit: Duration::from_secs(60),
            staged: true,
            exact_threshold: 24,
            window: 14,
            seed: 0,
            incumbent: None,
            presolve: PresolveConfig::default(),
            analysis: None,
            search: SearchStrategy::default(),
            budgets: None,
        }
    }
}

impl MoccasinSolver {
    /// Solve the rematerialization problem for `graph` under memory
    /// budget `budget`. `order` is the input topological order (§2.3);
    /// `None` uses the deterministic Kahn order.
    pub fn solve(&self, graph: &Graph, budget: u64, order: Option<Vec<NodeId>>) -> SolveOutcome {
        self.solve_with(graph, budget, order, |_| {})
    }

    /// Like [`MoccasinSolver::solve`], additionally invoking
    /// `on_improve` for every improving validated solution *as it is
    /// found* — the hook the portfolio coordinator uses to publish
    /// results across racing worker threads while the solve is still
    /// running.
    pub fn solve_with(
        &self,
        graph: &Graph,
        budget: u64,
        order: Option<Vec<NodeId>>,
        mut on_improve: impl FnMut(&RematSolution),
    ) -> SolveOutcome {
        let incumbent =
            self.incumbent.clone().unwrap_or_else(|| Arc::new(Incumbent::new()));
        let deadline = Deadline::with_incumbent(self.time_limit, Arc::clone(&incumbent));
        // Root presolve context: the order-independent analysis is
        // shared (portfolio) or computed once here; the order-dependent
        // part runs inside each model build.
        let pre = match (&self.analysis, self.presolve.level) {
            (_, crate::presolve::PresolveLevel::Off) => Presolve::off(),
            (Some(a), _) => Presolve::with_shared(Arc::clone(a), self.presolve),
            (None, _) => Presolve::new(graph, self.presolve),
        };
        let order = match order.or_else(|| topological_order(graph)) {
            Some(o) => o,
            None => {
                // cyclic input: no schedule exists; report a structured
                // failure instead of unwinding through the caller
                let rung = match self.search.mode {
                    SearchMode::Learned => Rung::Learned,
                    SearchMode::Chronological => Rung::Chronological,
                };
                let mut degradation = Degradation::clean(rung);
                degradation.note_failure("input graph is not a DAG (cycle detected)".to_string());
                return SolveOutcome {
                    best: None,
                    trace: Vec::new(),
                    proved_optimal: false,
                    phase1_time: Duration::ZERO,
                    stats: SearchStats::default(),
                    degradation,
                };
            }
        };
        let mut trace: Vec<ProgressPoint> = Vec::new();
        let mut best: Option<RematSolution> = None;
        let mut proved_optimal = false;
        let mut stats = SearchStats::default();
        // One reusable CP solve context for the whole solve: the exact
        // B&B and every LNS window re-solve (across every ladder rung)
        // steal and return the same kernel scratch buffers, so only the
        // first kernel run pays allocation. Panic-safe: a rung that
        // unwinds mid-solve leaves `ctx` valid but partially drained
        // (the buffers the dying engine held are simply gone); the next
        // rung re-grows what it needs.
        let mut ctx = SolveCtx::default();
        let budgets = self.budgets.unwrap_or_else(|| PhaseBudgets::split(self.time_limit));
        let configured_rung = match self.search.mode {
            SearchMode::Learned => Rung::Learned,
            SearchMode::Chronological => Rung::Chronological,
        };

        let mut record = |sol: &RematSolution,
                          trace: &mut Vec<ProgressPoint>,
                          best: &mut Option<RematSolution>| {
                let improved =
                    best.as_ref().map(|b| sol.eval.duration < b.eval.duration).unwrap_or(true);
                if improved {
                    incumbent.record(sol.eval.duration);
                    trace.push(ProgressPoint {
                        elapsed: deadline.elapsed(),
                        duration: sol.eval.duration,
                        tdi_percent: sol.eval.tdi_percent,
                    });
                    *best = Some(sol.clone());
                    on_improve(sol);
                }
            };

        // ---- Phase 1: feasibility (§2.4) ----
        // A topological order is trivially feasible for the *relaxed*
        // problem; the splitting planner turns it into a budget-feasible
        // sequence (the role Phase 1's max(M_var, M) objective plays in
        // the paper). If the input order resists, retry from a few
        // random topological orders — the paper itself randomizes the
        // input order (§3.3) — and adopt the successful one as the
        // staged model's input order.
        let mut order = order;
        let mut phase1 = greedy::greedy_remat(graph, &order, budget);
        if phase1.is_none() {
            let mut rng = Rng::seed_from_u64(self.seed ^ 0x9e37);
            for _ in 0..8 {
                if deadline.exceeded() {
                    break;
                }
                let alt = crate::graph::random_topological_order(graph, &mut rng);
                if let Some(sol) = greedy::greedy_remat(graph, &alt, budget) {
                    order = alt;
                    phase1 = Some(sol);
                    break;
                }
            }
        }
        let phase1_time = deadline.elapsed();
        let Some(p1) = phase1 else {
            // Budget unreachable by the heuristic. Try the exact model
            // for tiny graphs; otherwise report failure. The exact run
            // is panic-contained like every ladder rung: a crash here
            // degrades to "no solution found" instead of unwinding
            // through the caller.
            let mut degradation = Degradation::clean(configured_rung);
            degradation.spend.presolve_ms = phase1_time.as_millis() as u64;
            if graph.n() <= self.exact_threshold {
                let t0 = deadline.elapsed();
                let r = catch_unwind(AssertUnwindSafe(|| {
                    exact::solve_exact(
                        graph,
                        &order,
                        budget,
                        self.c,
                        deadline.clone(),
                        self.staged,
                        &pre,
                        self.search,
                        &mut ctx,
                        |sol| record(sol, &mut trace, &mut best),
                    )
                }));
                degradation.spend.search_ms =
                    deadline.elapsed().saturating_sub(t0).as_millis() as u64;
                match r {
                    Ok(ex) => {
                        proved_optimal = ex.proved_optimal;
                        stats.merge(&ex.stats);
                    }
                    Err(p) => {
                        stats.member_panics += 1;
                        degradation.note_failure(format!(
                            "panic at rung {}: {}",
                            configured_rung.as_str(),
                            crate::util::panic_note(p.as_ref()),
                        ));
                    }
                }
            }
            return SolveOutcome { best, trace, proved_optimal, phase1_time, stats, degradation };
        };
        record(&p1, &mut trace, &mut best);

        // ---- Phase 2: duration minimization, warm-started ----
        // 2a. Remat-removal polish: drop recomputations whose removal
        //     keeps the sequence within budget (strictly improving).
        let polished = match best.as_ref() {
            Some(cur) => lns::removal_polish(graph, cur, budget),
            None => {
                // Phase 1 returned a solution but validation rejected it
                // (record left `best` empty): report failure instead of
                // polishing nothing.
                let mut degradation = Degradation::clean(configured_rung);
                degradation.spend.presolve_ms = phase1_time.as_millis() as u64;
                degradation
                    .note_failure("phase-1 solution failed validation".to_string());
                return SolveOutcome {
                    best,
                    trace,
                    proved_optimal,
                    phase1_time,
                    stats,
                    degradation,
                };
            }
        };
        record(&polished, &mut trace, &mut best);

        // 2b/2c. Improvement phase, run down the degradation ladder.
        //
        //     Each rung attempts exact B&B for small instances (proves
        //     optimality; capped at its phase-budget slice so a
        //     pathological proof cannot starve the polish) followed by
        //     the LNS anytime loop, all inside `catch_unwind`: a panic
        //     anywhere in the CP kernel (or injected by a failpoint)
        //     burns that rung, records provenance, and falls through to
        //     the next cheaper strategy — learned → chronological →
        //     LNS-from-greedy — with the greedy/polished incumbent as
        //     the guaranteed floor (rung `greedy-only`). The incumbent
        //     can only improve monotonically, so a degraded answer is
        //     never worse than plain greedy.
        let mut degradation = Degradation::clean(configured_rung);
        degradation.spend.presolve_ms = phase1_time.as_millis() as u64;
        let chrono = SearchStrategy::chronological()
            .with_profile(self.search.profile)
            .with_filtering(self.search.filtering)
            .with_disjunctive(self.search.disjunctive);
        let mut attempts: Vec<(Rung, SearchStrategy, bool)> = Vec::new();
        attempts.push((configured_rung, self.search, true));
        if self.search.mode == SearchMode::Learned {
            attempts.push((Rung::Chronological, chrono, true));
        }
        attempts.push((Rung::LnsGreedy, chrono, false));
        let mut answered: Option<Rung> = None;
        for (attempt_idx, (rung, strat, allow_exact)) in attempts.iter().enumerate() {
            if deadline.exceeded() {
                break;
            }
            // attempt 0 keeps the configured seed so a clean run is
            // bit-identical to the pre-ladder behavior; fallback rungs
            // diversify it
            let seed = if attempt_idx == 0 {
                self.seed
            } else {
                self.seed ^ (attempt_idx as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
            };
            let r = catch_unwind(AssertUnwindSafe(|| {
                let mut astats = SearchStats::default();
                let mut proved = false;
                let mut search_ms = 0u64;
                if *allow_exact && graph.n() <= self.exact_threshold {
                    let t0 = deadline.elapsed();
                    let ex = exact::solve_exact(
                        graph,
                        &order,
                        budget,
                        self.c,
                        deadline.sub(budgets.search),
                        self.staged,
                        &pre,
                        *strat,
                        &mut ctx,
                        |sol| record(sol, &mut trace, &mut best),
                    );
                    search_ms = deadline.elapsed().saturating_sub(t0).as_millis() as u64;
                    astats.merge(&ex.stats);
                    // exhausting the space proves the incumbent optimal
                    // unless a racing portfolio member holds a strictly
                    // better duration
                    let global = incumbent.best();
                    proved = ex.proved_optimal
                        && best
                            .as_ref()
                            .map(|b| {
                                b.eval.duration <= ex.best_duration
                                    && global.map_or(true, |g| b.eval.duration <= g)
                            })
                            .unwrap_or(false);
                }
                let mut polish_ms = 0u64;
                // `best` is Some by phase-2 entry; the guard keeps the
                // LNS start well-defined even if a record path drained it
                if let Some(start) = if proved { None } else { best.clone() } {
                    let t0 = deadline.elapsed();
                    let mut rng = Rng::seed_from_u64(seed);
                    lns::lns_loop(
                        graph,
                        &order,
                        budget,
                        self.c,
                        self.window,
                        deadline.clone(),
                        &mut rng,
                        &pre,
                        *strat,
                        &mut ctx,
                        start,
                        &mut astats,
                        |sol| record(sol, &mut trace, &mut best),
                    );
                    polish_ms = deadline.elapsed().saturating_sub(t0).as_millis() as u64;
                }
                (astats, proved, search_ms, polish_ms)
            }));
            match r {
                Ok((astats, proved, search_ms, polish_ms)) => {
                    stats.merge(&astats);
                    proved_optimal = proved;
                    degradation.spend.search_ms += search_ms;
                    degradation.spend.polish_ms += polish_ms;
                    answered = Some(*rung);
                    break;
                }
                Err(p) => {
                    stats.member_panics += 1;
                    degradation.note_failure(format!(
                        "panic at rung {}: {}",
                        rung.as_str(),
                        crate::util::panic_note(p.as_ref()),
                    ));
                }
            }
        }
        degradation.rung = answered.unwrap_or(Rung::GreedyOnly);

        SolveOutcome { best, trace, proved_optimal, phase1_time, stats, degradation }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::generators::random_layered;
    use crate::graph::eval_sequence;

    /// Chain + long skip with heavy source (see greedy tests):
    /// no-remat peak 13; rematting node 0 reaches the structural floor
    /// of 10 with exactly one recompute.
    fn tiny_graph() -> Graph {
        Graph::from_edges(
            "tiny",
            5,
            &[(0, 1), (1, 2), (2, 3), (3, 4), (0, 4)],
            vec![1, 1, 1, 1, 1],
            vec![5, 4, 4, 4, 1],
        )
        .unwrap()
    }

    #[test]
    fn solves_tiny_graph_within_budget() {
        let g = tiny_graph();
        let out = MoccasinSolver::default().solve(&g, 10, None);
        let best = out.best.expect("feasible");
        assert!(best.eval.peak_mem <= 10, "{} > 10", best.eval.peak_mem);
        assert!(eval_sequence(&g, &best.seq).is_ok());
        // optimal: exactly one remat (duration 6), proved by exact B&B
        assert_eq!(best.eval.duration, 6);
        assert!(out.proved_optimal);
    }

    #[test]
    fn clean_solve_reports_clean_degradation() {
        let g = tiny_graph();
        let out = MoccasinSolver::default().solve(&g, 10, None);
        assert!(out.degradation.is_clean(), "{:?}", out.degradation);
        // default strategy is chronological, so that rung answers
        assert_eq!(out.degradation.rung, Rung::Chronological);
        assert_eq!(out.degradation.retries, 0);
        assert_eq!(out.stats.member_panics, 0);
        assert_eq!(out.stats.watchdog_kills, 0);
    }

    #[test]
    fn presolve_counters_reach_solver_stats() {
        let g = tiny_graph();
        let out = MoccasinSolver::default().solve(&g, 10, None);
        let ps = out.stats.presolve;
        assert!(ps.props_before > 0, "presolve must report raw counts");
        assert!(
            ps.props_after < ps.props_before,
            "compaction must construct fewer propagators ({} -> {})",
            ps.props_before,
            ps.props_after
        );
        assert!(
            ps.domain_after < ps.domain_before,
            "tightening must shrink summed domain size ({} -> {})",
            ps.domain_before,
            ps.domain_after
        );
    }

    #[test]
    fn presolve_off_matches_default_optimum() {
        let g = tiny_graph();
        let on = MoccasinSolver::default().solve(&g, 10, None);
        let off = MoccasinSolver { presolve: PresolveConfig::off(), ..Default::default() }
            .solve(&g, 10, None);
        assert_eq!(
            on.best.as_ref().unwrap().eval.duration,
            off.best.as_ref().unwrap().eval.duration
        );
        assert!(on.proved_optimal && off.proved_optimal);
        assert_eq!(off.stats.presolve.props_before, 0, "disabled presolve reports nothing");
    }

    #[test]
    fn no_remat_needed_when_budget_loose() {
        let g = tiny_graph();
        let out = MoccasinSolver::default().solve(&g, g.total_mem() * 2, None);
        let best = out.best.unwrap();
        assert_eq!(best.eval.remat_count, 0, "loose budget should need no remat");
        assert_eq!(best.eval.tdi_percent, 0.0);
    }

    #[test]
    fn trace_is_monotone_improving() {
        let g = random_layered("t", 60, 150, 3);
        let peak = g.peak_mem_no_remat(&topological_order(&g).unwrap()).unwrap();
        let out = MoccasinSolver {
            time_limit: Duration::from_secs(5),
            ..Default::default()
        }
        .solve(&g, (peak as f64 * 0.85) as u64, None);
        assert!(out.best.is_some());
        let durs: Vec<u64> = out.trace.iter().map(|p| p.duration).collect();
        assert!(durs.windows(2).all(|w| w[1] < w[0] || w.len() < 2), "{durs:?}");
    }

    #[test]
    fn medium_graph_feasible_under_80pct() {
        let g = random_layered("t", 100, 236, 1);
        let peak = g.peak_mem_no_remat(&topological_order(&g).unwrap()).unwrap();
        let budget = (peak as f64 * 0.8) as u64;
        let out = MoccasinSolver {
            time_limit: Duration::from_secs(10),
            ..Default::default()
        }
        .solve(&g, budget, None);
        let best = out.best.expect("feasible at 80%");
        assert!(best.eval.peak_mem <= budget);
        // TDI should be modest (paper: < 5% for such budgets)
        assert!(best.eval.tdi_percent < 50.0, "tdi = {}", best.eval.tdi_percent);
    }
}
