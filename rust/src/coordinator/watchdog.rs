//! Watchdog monitor for in-flight solves.
//!
//! A solve should never be able to wedge its caller: cooperative
//! deadline polls cover the common paths, and the propagation engine
//! checks cancellation inside each fixpoint, but *something* has to
//! trip the cancellation flag when a solve stops making progress — a
//! propagator spinning on a pathological instance, an injected delay,
//! or a member blocked where no poll runs. The [`Watchdog`] is a small
//! monitor thread that observes the solve's shared
//! [`Incumbent`]: the wall clock against the budget slice, the
//! heartbeat epoch published by the engine's fixpoint loop
//! ([`Incumbent::beat`]), and the process peak RSS
//! ([`crate::util::peak_rss_kb`]) against an optional memory limit.
//! On a violation it cancels the incumbent — which every deadline and
//! every in-fixpoint check observes — records the kill in the global
//! resilience counters ([`crate::util::events`]), and reports the
//! reason to the caller for degradation provenance.

use crate::util::{events, peak_rss_kb, Incumbent};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Why the watchdog cancelled a solve.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KillReason {
    /// Wall clock ran past the budget slice plus grace.
    WallOverrun,
    /// The heartbeat epoch stood still past the stall threshold.
    HeartbeatStall,
    /// Process peak RSS crossed the memory limit (bail to the incumbent
    /// before the OS OOM-killer bails for us).
    RssLimit,
}

impl KillReason {
    /// Stable lower-case name (diagnostics / JSON).
    pub fn as_str(&self) -> &'static str {
        match self {
            KillReason::WallOverrun => "wall-overrun",
            KillReason::HeartbeatStall => "heartbeat-stall",
            KillReason::RssLimit => "rss-limit",
        }
    }
}

/// Watchdog tuning for one monitored solve.
#[derive(Debug, Clone, Copy)]
pub struct WatchdogConfig {
    /// The solve's wall budget; the watchdog cancels at `wall + grace`
    /// (the cooperative deadline should have stopped the solve at
    /// `wall` — the watchdog is the backstop for when it could not).
    pub wall: Duration,
    /// Grace past `wall` before a wall-overrun kill.
    pub grace: Duration,
    /// Heartbeat stall threshold once the first beat has been seen.
    pub stall: Duration,
    /// Stall allowance before the first beat (model build, presolve and
    /// Phase-1 greedy run before the engine starts beating).
    pub warmup: Duration,
    /// Peak-RSS limit in kilobytes (`None` = no memory guard).
    pub rss_limit_kb: Option<u64>,
    /// Monitor poll interval.
    pub poll: Duration,
}

impl WatchdogConfig {
    /// Derive a config from a wall budget: grace = wall/4 clamped to
    /// [250ms, 5s], stall = wall/3 clamped to [500ms, 10s] (overridable
    /// via `stall_ms` — mainly for tests and ops), warmup = 4×stall.
    /// The stall default is deliberately generous: heartbeats come from
    /// the propagation engine, so long beat-free phases (greedy
    /// simulation, model builds on large graphs) must not read as wedged.
    pub fn for_wall(wall: Duration, rss_limit_kb: Option<u64>, stall_ms: Option<u64>) -> Self {
        let grace = (wall / 4).clamp(Duration::from_millis(250), Duration::from_secs(5));
        let stall = match stall_ms {
            Some(ms) => Duration::from_millis(ms.max(1)),
            None => (wall / 3).clamp(Duration::from_millis(500), Duration::from_secs(10)),
        };
        WatchdogConfig {
            wall,
            grace,
            stall,
            warmup: stall * 4,
            rss_limit_kb,
            poll: Duration::from_millis(10).min(stall / 2).max(Duration::from_millis(1)),
        }
    }
}

/// What the watchdog observed over the solve it monitored.
#[derive(Debug, Clone, Copy, Default)]
pub struct WatchdogReport {
    /// Number of kills performed (0 or 1 — a watchdog kills at most
    /// once; the cancellation flag is sticky).
    pub kills: u32,
    /// Reason for the kill, if one happened.
    pub reason: Option<KillReason>,
}

/// A monitor thread watching one solve's shared [`Incumbent`]. Create
/// with [`Watchdog::spawn`] before starting the solve, and call
/// [`Watchdog::stop`] after it returns to collect the report.
pub struct Watchdog {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<WatchdogReport>>,
}

impl Watchdog {
    /// Spawn the monitor over `inc`. If the OS refuses a thread the
    /// watchdog degrades to a no-op (the solve still has its
    /// cooperative deadline) rather than failing the solve.
    pub fn spawn(inc: Arc<Incumbent>, cfg: WatchdogConfig) -> Self {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("moccasin-watchdog".to_string())
            .spawn(move || monitor(&inc, cfg, &stop2))
            .ok();
        Watchdog { stop, handle }
    }

    /// Signal the monitor to exit and collect its report.
    pub fn stop(self) -> WatchdogReport {
        self.stop.store(true, Ordering::Release);
        match self.handle {
            Some(h) => h.join().unwrap_or_default(),
            None => WatchdogReport::default(),
        }
    }
}

fn monitor(inc: &Incumbent, cfg: WatchdogConfig, stop: &AtomicBool) -> WatchdogReport {
    let start = Instant::now();
    let mut report = WatchdogReport::default();
    let mut last_epoch = inc.epoch();
    let mut last_change = start;
    let mut beaten = false;
    while !stop.load(Ordering::Acquire) {
        std::thread::sleep(cfg.poll);
        if stop.load(Ordering::Acquire) || report.kills > 0 || inc.should_stop() {
            // killed already (sticky flag), the race is over, or a
            // serving-tier controller preempted the solve — a preempted
            // solve stops beating *by design*, and turning that into a
            // stall kill would relabel a wanted best-so-far answer as a
            // watchdog casualty. Nothing left to watch; wait for stop.
            continue;
        }
        let now = Instant::now();
        let epoch = inc.epoch();
        if epoch != last_epoch {
            last_epoch = epoch;
            last_change = now;
            beaten = true;
        }
        let stall_allow = if beaten { cfg.stall } else { cfg.stall.max(cfg.warmup) };
        let reason = if now.duration_since(start) >= cfg.wall + cfg.grace {
            Some(KillReason::WallOverrun)
        } else if now.duration_since(last_change) >= stall_allow {
            Some(KillReason::HeartbeatStall)
        } else if cfg.rss_limit_kb.is_some_and(|lim| peak_rss_kb().unwrap_or(0) > lim) {
            Some(KillReason::RssLimit)
        } else {
            None
        };
        if let Some(reason) = reason {
            inc.cancel();
            events::note_watchdog_kill();
            report.kills += 1;
            report.reason = Some(reason);
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_heartbeat_triggers_stall_kill() {
        let inc = Arc::new(Incumbent::new());
        let cfg = WatchdogConfig::for_wall(Duration::from_secs(60), None, Some(20));
        // warmup = 4×20ms = 80ms with no beats → stall kill well before
        // the wall
        let wd = Watchdog::spawn(Arc::clone(&inc), cfg);
        let t0 = Instant::now();
        while !inc.is_cancelled() && t0.elapsed() < Duration::from_secs(10) {
            std::thread::sleep(Duration::from_millis(5));
        }
        let report = wd.stop();
        assert!(inc.is_cancelled(), "watchdog must cancel a silent solve");
        assert_eq!(report.kills, 1);
        assert_eq!(report.reason, Some(KillReason::HeartbeatStall));
    }

    #[test]
    fn steady_heartbeat_is_left_alone() {
        let inc = Arc::new(Incumbent::new());
        let cfg = WatchdogConfig::for_wall(Duration::from_secs(60), None, Some(50));
        let wd = Watchdog::spawn(Arc::clone(&inc), cfg);
        for _ in 0..20 {
            inc.beat();
            std::thread::sleep(Duration::from_millis(10));
        }
        let report = wd.stop();
        assert!(!inc.is_cancelled(), "beating solve must not be killed");
        assert_eq!(report.kills, 0);
    }

    #[test]
    fn wall_overrun_kills_even_with_heartbeat() {
        let inc = Arc::new(Incumbent::new());
        let cfg = WatchdogConfig {
            wall: Duration::from_millis(30),
            grace: Duration::from_millis(10),
            stall: Duration::from_secs(10),
            warmup: Duration::from_secs(10),
            rss_limit_kb: None,
            poll: Duration::from_millis(5),
        };
        let wd = Watchdog::spawn(Arc::clone(&inc), cfg);
        let t0 = Instant::now();
        while !inc.is_cancelled() && t0.elapsed() < Duration::from_secs(10) {
            inc.beat();
            std::thread::sleep(Duration::from_millis(2));
        }
        let report = wd.stop();
        assert!(inc.is_cancelled());
        assert_eq!(report.reason, Some(KillReason::WallOverrun));
    }
}
