//! L3 coordinator: the solve service a downstream user (or the CLI)
//! calls.
//!
//! Wraps the solver portfolio behind a cache: schedules are keyed by
//! (graph fingerprint, budget, C, backend, …, explicit-order hash), so
//! a compiler pipeline that re-lowers the same model hits the cache
//! instead of re-solving — the "compile-time" cost the paper optimizes
//! is paid once per (graph, budget). The CHECKMATE baselines are
//! exposed behind the same interface for the benchmark harness.
//!
//! Two parallel entry points sit on top of the serial `solve`:
//!
//! * [`Backend::Portfolio`] — one request, many worker threads racing
//!   diversified solvers that share an atomic incumbent bound and a
//!   cancellation flag (see [`portfolio`]).
//! * [`Coordinator::solve_many`] — many requests (e.g. a budget sweep)
//!   scheduled across a worker pool with cache-aware deduplication:
//!   requests whose key is already cached are answered inline,
//!   duplicates inside the batch are solved once, and only unique
//!   misses reach the pool.

pub mod portfolio;
pub mod watchdog;

pub use portfolio::{solve_portfolio, PortfolioConfig};
pub use watchdog::{KillReason, Watchdog, WatchdogConfig, WatchdogReport};

use crate::checkmate::{self, CheckmateError};
use crate::cp::{SearchMode, SearchStats, SearchStrategy};
use crate::graph::{topological_order, Graph, NodeId};
use crate::moccasin::{Degradation, MoccasinSolver, RematSolution, Rung, SolveOutcome};
use crate::presolve::{Presolve, PresolveConfig};
use crate::util::{events, panic_note, Deadline, Incumbent, LruCache, Rng};
use std::collections::{HashMap, HashSet};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Which solver backend to use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// The MOCCASIN retention-interval solver (serial: Phase-1 greedy,
    /// exact B&B on small graphs, anytime LNS on large ones).
    Moccasin,
    /// The CHECKMATE exact MILP baseline (pseudo-Boolean B&B).
    CheckmateMilp,
    /// The CHECKMATE LP-relaxation + two-stage-rounding baseline.
    CheckmateLpRounding,
    /// Parallel portfolio race: MOCCASIN members with diversified
    /// orders/seeds plus the CHECKMATE MILP, sharing an atomic
    /// incumbent; the first optimality proof cancels the rest.
    Portfolio,
}

/// A solve request.
#[derive(Debug, Clone)]
pub struct SolveRequest {
    /// Memory budget `M` (peak-footprint cap).
    pub budget: u64,
    /// Max retention intervals per node (the paper's `C`).
    pub c: usize,
    /// Wall-clock limit for the solve.
    pub time_limit: Duration,
    /// Solver backend.
    pub backend: Backend,
    /// optional explicit input topological order
    pub order: Option<Vec<NodeId>>,
    /// Root presolve configuration (default: the exactness-preserving
    /// level). Part of the cache key — different reductions may yield
    /// different anytime traces or (non-exact levels) different optima.
    pub presolve: PresolveConfig,
    /// CP kernel search strategy (chronological | learned). Part of the
    /// cache key: both modes reach the same optimum, but traces, stats
    /// and proofs-per-member differ, so responses are not interchangeable.
    pub search: SearchStrategy,
    /// Watchdog heartbeat-stall threshold override in milliseconds
    /// (`None` = derived from `time_limit`; see
    /// [`WatchdogConfig::for_wall`]). Part of the cache key: a solve
    /// killed under an aggressive stall budget is not interchangeable
    /// with an unconstrained one.
    pub stall_ms: Option<u64>,
    /// Watchdog peak-RSS limit in kilobytes (`None` = no memory guard).
    /// Part of the cache key for the same reason as `stall_ms`.
    pub rss_limit_kb: Option<u64>,
}

impl Default for SolveRequest {
    fn default() -> Self {
        SolveRequest {
            budget: u64::MAX,
            c: 2,
            time_limit: Duration::from_secs(60),
            backend: Backend::Moccasin,
            order: None,
            presolve: PresolveConfig::default(),
            search: SearchStrategy::default(),
            stall_ms: None,
            rss_limit_kb: None,
        }
    }
}

/// A solve response: the best schedule plus anytime metadata.
#[derive(Debug, Clone)]
pub struct SolveResponse {
    /// Best schedule found (`None` if the budget was unreachable within
    /// the limits).
    pub solution: Option<RematSolution>,
    /// (elapsed, duration) anytime trace
    pub trace: Vec<(Duration, u64)>,
    /// Whether optimality (or infeasibility) was proved.
    pub proved_optimal: bool,
    /// Whether this response was served from the schedule cache.
    pub from_cache: bool,
    /// Why no solution was produced, when one wasn't.
    pub error: Option<String>,
    /// Aggregated CP kernel statistics (summed across portfolio
    /// members for [`Backend::Portfolio`]; zero for pure-LP backends
    /// and preserved from the original solve on cache hits).
    pub stats: SearchStats,
    /// Degradation provenance: which ladder rung answered and what
    /// failed along the way (see [`Degradation`]). `Some` for the
    /// MOCCASIN and portfolio backends (which run the fallback ladder);
    /// `None` for baseline backends unless the watchdog intervened, and
    /// for synthesized member-failure responses.
    pub degradation: Option<Degradation>,
}

/// Cache key: (graph fingerprint, budget, C, backend discriminant,
/// presolve level discriminant, interval-length cap, search-strategy
/// discriminant, explicit-order hash, stall override, RSS limit). The
/// order hash matters: the staged model is order-relative, so responses
/// for different explicit orders — including order-validation failures
/// — are not interchangeable (0 = no explicit order). The watchdog
/// knobs are `value + 1` with 0 = unset, so `Some(0)` and `None` stay
/// distinct.
pub(crate) type CacheKey = (u64, u64, usize, u8, u8, i64, u8, u64, u64, u64);

/// Default schedule-cache capacity (entries). Sized so a long-running
/// daemon serving fleet traffic stays bounded while a compile pipeline's
/// working set (one model × a budget sweep) fits comfortably.
pub const DEFAULT_CACHE_CAP: usize = 4096;

/// The coordinator: solver portfolio + solution cache + worker pool
/// configuration for batched solves.
///
/// The schedule cache is a *bounded* LRU ([`LruCache`]) — it used to be
/// an unbounded `HashMap`, which was fine for one batch but a slow leak
/// for a long-running serve daemon whose key space (graph fingerprint ×
/// budget × knobs) grows without bound. Eviction counts are exposed via
/// [`Coordinator::cache_evictions`].
pub struct Coordinator {
    cache: LruCache<CacheKey, SolveResponse>,
    /// Worker threads used by [`Coordinator::solve_many`] and by
    /// [`Backend::Portfolio`] members. `0` = auto (available
    /// parallelism).
    pub threads: usize,
    /// Cache hits served so far (including batch-deduplicated requests).
    pub hits: u64,
    /// Cache misses (actual solves) so far.
    pub misses: u64,
}

impl Default for Coordinator {
    fn default() -> Self {
        Self::with_cache_cap(DEFAULT_CACHE_CAP)
    }
}

impl Coordinator {
    /// Fresh coordinator with an empty cache (default capacity) and
    /// automatic parallelism.
    pub fn new() -> Self {
        Self::default()
    }

    /// Fresh coordinator with an explicit schedule-cache capacity
    /// (`0` disables caching entirely).
    pub fn with_cache_cap(cap: usize) -> Self {
        Coordinator { cache: LruCache::new(cap), threads: 0, hits: 0, misses: 0 }
    }

    /// Entries evicted from the schedule cache to make room (never
    /// counts explicit invalidation — there is none).
    pub fn cache_evictions(&self) -> u64 {
        self.cache.evictions
    }

    /// Live schedule-cache entry count.
    pub fn cache_len(&self) -> usize {
        self.cache.len()
    }

    /// Configured schedule-cache capacity.
    pub fn cache_cap(&self) -> usize {
        self.cache.cap()
    }

    /// Worker count for batched solves (resolves the `0` = auto
    /// default).
    fn worker_count(&self) -> usize {
        if self.threads != 0 {
            return self.threads;
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    }

    pub(crate) fn cache_key(graph: &Graph, req: &SolveRequest) -> CacheKey {
        let order_hash = req
            .order
            .as_ref()
            .map(|o| {
                use std::hash::{Hash, Hasher};
                let mut h = std::collections::hash_map::DefaultHasher::new();
                o.hash(&mut h);
                // | 1 keeps every explicit order distinct from the
                // "no explicit order" sentinel 0
                h.finish() | 1
            })
            .unwrap_or(0);
        (
            graph.fingerprint(),
            req.budget,
            req.c,
            req.backend as u8,
            req.presolve.level as u8,
            // builders clamp negative caps to 0, so key them as 0 too —
            // the -1 sentinel stays reserved for "no cap"
            req.presolve.max_interval_len.map(|l| l.max(0)).unwrap_or(-1),
            req.search.cache_key(),
            order_hash,
            req.stall_ms.map(|v| v.saturating_add(1)).unwrap_or(0),
            req.rss_limit_kb.map(|v| v.saturating_add(1)).unwrap_or(0),
        )
    }

    /// Solve (or fetch from cache). The uncached solve runs under
    /// `catch_unwind`: whatever a backend does — including an injected
    /// failpoint panic — the caller gets a structured member-failure
    /// response, never an unwound stack. Panic responses are not
    /// cached (a surviving panic is not input-deterministic; a retry
    /// may well succeed).
    pub fn solve(&mut self, graph: &Graph, req: &SolveRequest) -> SolveResponse {
        let key = Self::cache_key(graph, req);
        if let Some(hit) = self.cache.get(&key) {
            self.hits += 1;
            let mut r = hit.clone();
            r.from_cache = true;
            return r;
        }
        self.misses += 1;
        let solved = catch_unwind(AssertUnwindSafe(|| self.solve_uncached(graph, req)));
        match solved {
            Ok(resp) => {
                self.cache.insert(key, resp.clone());
                resp
            }
            Err(p) => {
                events::note_member_panic();
                member_failure_response(&panic_note(p.as_ref()))
            }
        }
    }

    /// Solve a batch of requests across the worker pool with cache-aware
    /// deduplication.
    ///
    /// Semantics per request, in order:
    /// 1. key already in the cache → answered from cache (`from_cache`);
    /// 2. key duplicated earlier in the batch → solved once, duplicate
    ///    answered from the fresh cache entry (`from_cache`, counted as
    ///    a hit);
    /// 3. otherwise → solved on the pool (counted as a miss).
    ///
    /// Responses are returned in request order. Wall-clock for a sweep
    /// of `k` unique requests approaches `ceil(k / threads)` serial
    /// solves.
    pub fn solve_many(&mut self, requests: &[(&Graph, SolveRequest)]) -> Vec<SolveResponse> {
        let keys: Vec<CacheKey> =
            requests.iter().map(|(g, r)| Self::cache_key(g, r)).collect();
        let mut out: Vec<Option<SolveResponse>> = vec![None; requests.len()];

        // cache pass + batch dedup: `jobs` holds request indices of
        // unique misses, `job_of_key` maps each missed key to its job
        // slot so duplicates can inherit uncacheable failure responses
        let mut jobs: Vec<usize> = Vec::new();
        let mut job_of_key: HashMap<CacheKey, usize> = HashMap::new();
        let mut seen: HashSet<CacheKey> = HashSet::new();
        for (i, key) in keys.iter().enumerate() {
            if let Some(hit) = self.cache.get(key) {
                self.hits += 1;
                let mut r = hit.clone();
                r.from_cache = true;
                out[i] = Some(r);
            } else if !seen.insert(*key) {
                self.hits += 1; // batch duplicate: filled after the solves
            } else {
                self.misses += 1;
                job_of_key.insert(*key, jobs.len());
                jobs.push(i);
            }
        }

        // Run unique misses on the worker pool. Failure containment
        // (regression-tested by the `resilience` integration suite):
        // a panicking solve used to poison its slot mutex and abort the
        // *whole batch* when the scope re-raised the panic — now each
        // solve runs under `catch_unwind`, a poisoned slot lock is
        // recovered (the data is a plain `Option` write, so poisoning
        // carries no invariant), and a slot a worker never filled is
        // surfaced as that request's member failure instead of an
        // `expect` abort. A panicked solve is additionally retried
        // *once* after a short deterministic jittered backoff: a
        // surviving panic is by construction not input-deterministic
        // (order validation removed those), so a retry often succeeds
        // — and when it does, the response carries `retries: 1` plus
        // the first attempt's panic in its degradation provenance.
        // slot payload: (response, cacheable) — a response from a
        // *completed* solve (including deterministic validation
        // failures) is cacheable; one synthesized from a doubly
        // contained panic is not, so a later retry of the same request
        // actually re-solves
        let results: Vec<Option<(SolveResponse, bool)>> = {
            let slots: Vec<Mutex<Option<(SolveResponse, bool)>>> =
                jobs.iter().map(|_| Mutex::new(None)).collect();
            let next = AtomicUsize::new(0);
            let workers = self.worker_count().min(jobs.len().max(1));
            let me: &Coordinator = self;
            let jobs_ref = &jobs;
            std::thread::scope(|s| {
                for _ in 0..workers {
                    let slots = &slots;
                    let next = &next;
                    s.spawn(move || loop {
                        let j = next.fetch_add(1, Ordering::Relaxed);
                        if j >= jobs_ref.len() {
                            break;
                        }
                        let i = jobs_ref[j];
                        let (graph, req) = &requests[i];
                        let resp = match catch_unwind(AssertUnwindSafe(|| {
                            me.solve_uncached(graph, req)
                        })) {
                            Ok(r) => (r, true),
                            Err(p) => {
                                events::note_member_panic();
                                let note = panic_note(p.as_ref());
                                (me.retry_after_panic(graph, req, i, &note), false)
                            }
                        };
                        *crate::util::lock_recover(&slots[j]) = Some(resp);
                    });
                }
            });
            slots
                .into_iter()
                .map(|m| m.into_inner().unwrap_or_else(|p| p.into_inner()))
                .collect()
        };

        // Publish results into the cache + the output slots. A solve
        // that completed — successfully or with a deterministic error
        // response — is cached; contained panics and unfilled slots
        // are surfaced but never cached, so a retry of the same
        // request actually re-solves.
        for (j, &i) in jobs.iter().enumerate() {
            match &results[j] {
                Some((resp, cacheable)) => {
                    if *cacheable {
                        self.cache.insert(keys[i], resp.clone());
                    }
                    out[i] = Some(resp.clone());
                }
                None => {
                    out[i] = Some(member_failure_response(
                        "worker exited without filling its slot",
                    ));
                }
            }
        }
        // batch duplicates read the now-warm cache, or inherit their
        // twin's uncacheable failure response verbatim (so both copies
        // of a panicked request report the same diagnostic)
        for (i, slot) in out.iter_mut().enumerate() {
            if slot.is_none() {
                *slot = Some(match self.cache.get(&keys[i]) {
                    Some(hit) => {
                        let mut r = hit.clone();
                        r.from_cache = true;
                        r
                    }
                    None => job_of_key
                        .get(&keys[i])
                        .and_then(|&j| results[j].as_ref())
                        .map(|(resp, _)| resp.clone())
                        .unwrap_or_else(|| {
                            member_failure_response("batch twin's solve did not complete")
                        }),
                });
            }
        }
        out.into_iter()
            .map(|o| {
                o.unwrap_or_else(|| member_failure_response("request left unanswered"))
            })
            .collect()
    }

    /// Retry a request whose first solve attempt panicked: one retry
    /// after a short deterministic jittered backoff (seeded by the
    /// request's batch index so concurrent retries do not stampede in
    /// lockstep, yet runs stay reproducible). A successful retry
    /// reports `retries: 1` and the first attempt's panic in its
    /// degradation provenance; a second panic becomes a member-failure
    /// response carrying both payloads.
    fn retry_after_panic(
        &self,
        graph: &Graph,
        req: &SolveRequest,
        job_idx: usize,
        first_panic: &str,
    ) -> SolveResponse {
        events::note_member_retry();
        let mut rng = Rng::seed_from_u64(0xBACC ^ job_idx as u64);
        std::thread::sleep(Duration::from_millis(5 + rng.next_u64() % 20));
        match catch_unwind(AssertUnwindSafe(|| self.solve_uncached(graph, req))) {
            Ok(mut r) => {
                let deg = r
                    .degradation
                    .get_or_insert_with(|| Degradation::clean(base_rung(req.search)));
                deg.retries += 1;
                deg.note_failure(format!("first attempt panicked: {first_panic}"));
                r.stats.member_panics += 1;
                r.stats.member_retries += 1;
                r
            }
            Err(p2) => {
                events::note_member_panic();
                member_failure_response(&format!(
                    "{first_panic}; retry also panicked: {}",
                    panic_note(p2.as_ref())
                ))
            }
        }
    }

    /// Solve one request without consulting the cache. An explicit
    /// order is validated up front (right length, in-range ids, a
    /// permutation, topological): every backend indexes by order
    /// positions and the staged model is order-relative, so a bad
    /// order must become an error response — on the serial path
    /// [`Coordinator::solve`]'s `catch_unwind` is the last line of
    /// defense against other panics (including injected faults from
    /// the `coordinator.solve` failpoint).
    fn solve_uncached(&self, graph: &Graph, req: &SolveRequest) -> SolveResponse {
        if let Some(o) = &req.order {
            if let Err(why) = validate_order(graph, o) {
                return member_failure_response(&why);
            }
        }
        // fault-injection site replacing the PR-5 `panic_for_test`
        // hook: `panic` exercises the containment above/in solve_many,
        // `error`/`timeout` exercise the structured failure path
        #[cfg(any(test, feature = "failpoints"))]
        if crate::util::failpoint::hit("coordinator.solve").is_some() {
            return member_failure_response("failpoint 'coordinator.solve': injected failure");
        }
        let order = match req.order.clone().or_else(|| topological_order(graph)) {
            Some(o) => o,
            // cycle: no schedule exists; answer structurally, like any
            // other member failure, instead of unwinding
            None => return member_failure_response("graph is not a DAG (cycle detected)"),
        };
        match req.backend {
            Backend::Moccasin => {
                let inc = Arc::new(Incumbent::new());
                let solver = MoccasinSolver {
                    c: req.c,
                    time_limit: req.time_limit,
                    presolve: req.presolve,
                    search: req.search,
                    incumbent: Some(Arc::clone(&inc)),
                    ..Default::default()
                };
                let wd = Watchdog::spawn(
                    Arc::clone(&inc),
                    WatchdogConfig::for_wall(req.time_limit, req.rss_limit_kb, req.stall_ms),
                );
                let out: SolveOutcome = solver.solve(graph, req.budget, Some(order));
                let report = wd.stop();
                let mut degradation = out.degradation;
                if let Some(reason) = report.reason {
                    degradation.note_failure(format!("watchdog: {}", reason.as_str()));
                }
                let mut stats = out.stats;
                // exact attribution: this solve's own watchdog reports
                // its kills — the old global snapshot/delta absorption
                // let concurrent solves steal each other's counts
                stats.watchdog_kills += u64::from(report.kills);
                SolveResponse {
                    trace: out.trace.iter().map(|p| (p.elapsed, p.duration)).collect(),
                    proved_optimal: out.proved_optimal,
                    solution: out.best,
                    from_cache: false,
                    error: None,
                    stats,
                    degradation: Some(degradation),
                }
            }
            Backend::Portfolio => {
                let cfg = PortfolioConfig {
                    threads: self.threads,
                    time_limit: req.time_limit,
                    c: req.c,
                    seed: 0,
                    include_checkmate: true,
                    presolve: req.presolve,
                    search: req.search,
                    stall_ms: req.stall_ms,
                    rss_limit_kb: req.rss_limit_kb,
                };
                solve_portfolio(graph, req.budget, Some(order), &cfg)
            }
            Backend::CheckmateMilp => {
                // the incumbent gives the watchdog a cancellation path
                // into the MILP's engine (which beats + polls it inside
                // each fixpoint; see `PropagationEngine::set_watchdog`)
                let inc = Arc::new(Incumbent::new());
                let deadline = Deadline::with_incumbent(req.time_limit, Arc::clone(&inc));
                let wd = Watchdog::spawn(
                    Arc::clone(&inc),
                    WatchdogConfig::for_wall(req.time_limit, req.rss_limit_kb, req.stall_ms),
                );
                let mut trace = Vec::new();
                let r = checkmate::solve_milp(
                    graph,
                    &order,
                    req.budget,
                    deadline.clone(),
                    // solve_milp's reduction is purely logical — skip
                    // the reachability analysis on this path
                    &Presolve::config_only(req.presolve),
                    req.search,
                    |sol| {
                        trace.push((deadline.elapsed(), sol.eval.duration));
                    },
                );
                let report = wd.stop();
                let degradation = report.reason.map(|reason| {
                    let mut d = Degradation::clean(base_rung(req.search));
                    d.note_failure(format!("watchdog: {}", reason.as_str()));
                    d
                });
                match r {
                    Ok(res) => {
                        let mut stats = res.stats;
                        stats.watchdog_kills += u64::from(report.kills);
                        SolveResponse {
                            solution: Some(res.solution),
                            trace,
                            // a watchdog kill means the proof race was
                            // cancelled, not decided
                            proved_optimal: res.proved_optimal && report.kills == 0,
                            from_cache: false,
                            error: None,
                            stats,
                            degradation,
                        }
                    }
                    Err(e) => {
                        let mut stats = match &e {
                            CheckmateError::NoSolution { stats } => *stats,
                            _ => SearchStats::default(),
                        };
                        stats.watchdog_kills += u64::from(report.kills);
                        SolveResponse {
                            solution: None,
                            trace,
                            proved_optimal: matches!(e, CheckmateError::NoSolution { .. })
                                && report.kills == 0,
                            from_cache: false,
                            stats,
                            error: Some(e.to_string()),
                            degradation,
                        }
                    }
                }
            }
            Backend::CheckmateLpRounding => {
                let t0 = std::time::Instant::now();
                // iteration count scaled to the time limit (PDHG is the
                // dominant cost)
                let iters = (req.time_limit.as_millis() as usize * 2).clamp(2_000, 200_000);
                // no watchdog here: the PDHG loop has no cancellation
                // channel (no engine, no incumbent), and its iteration
                // count is already scaled to the time limit above
                match checkmate::solve_lp_rounding(graph, &order, req.budget, iters) {
                    Ok(res) => SolveResponse {
                        trace: vec![(t0.elapsed(), res.solution.eval.duration)],
                        solution: Some(res.solution),
                        proved_optimal: false,
                        from_cache: false,
                        error: None,
                        stats: SearchStats::default(),
                        degradation: None,
                    },
                    Err(e) => SolveResponse {
                        solution: None,
                        trace: Vec::new(),
                        proved_optimal: false,
                        from_cache: false,
                        error: Some(e.to_string()),
                        stats: SearchStats::default(),
                        degradation: None,
                    },
                }
            }
        }
    }
}

/// Check that an explicit request order is a topological permutation
/// of the graph's nodes (what every backend assumes): right length,
/// in-range ids, no duplicates, and every predecessor scheduled before
/// its consumer. Returns a description of the first violation.
fn validate_order(graph: &Graph, order: &[NodeId]) -> Result<(), String> {
    let n = graph.n();
    if order.len() != n {
        return Err(format!(
            "invalid explicit order: {} entries for a {n}-node graph",
            order.len()
        ));
    }
    let mut seen = vec![false; n];
    for &v in order {
        let vi = v as usize;
        if vi >= n {
            return Err(format!("invalid explicit order: node id {v} out of range (n = {n})"));
        }
        if seen[vi] {
            return Err(format!("invalid explicit order: node {v} appears twice"));
        }
        for &p in &graph.preds[vi] {
            if !seen[p as usize] {
                return Err(format!(
                    "invalid explicit order: not topological (node {v} before its \
                     predecessor {p})"
                ));
            }
        }
        seen[vi] = true;
    }
    Ok(())
}

/// The ladder rung a request's configured search strategy corresponds
/// to (where a retried or baseline response's provenance starts).
fn base_rung(search: SearchStrategy) -> Rung {
    match search.mode {
        SearchMode::Learned => Rung::Learned,
        SearchMode::Chronological => Rung::Chronological,
    }
}

/// The response reported for a request whose solve did not complete
/// (panicked worker / unfilled slot): an error, never an abort.
fn member_failure_response(why: &str) -> SolveResponse {
    SolveResponse {
        solution: None,
        trace: Vec::new(),
        proved_optimal: false,
        from_cache: false,
        error: Some(format!("solver member failed: {why}")),
        stats: SearchStats::default(),
        degradation: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain() -> Graph {
        Graph::from_edges(
            "c",
            5,
            &[(0, 1), (1, 2), (2, 3), (3, 4), (0, 4)],
            vec![1; 5],
            vec![5, 4, 4, 4, 1],
        )
        .unwrap()
    }

    #[test]
    fn cache_hit_on_second_solve() {
        let g = chain();
        let mut c = Coordinator::new();
        let req =
            SolveRequest { budget: 10, time_limit: Duration::from_secs(5), ..Default::default() };
        let a = c.solve(&g, &req);
        assert!(!a.from_cache);
        let b = c.solve(&g, &req);
        assert!(b.from_cache);
        assert_eq!(c.hits, 1);
        assert_eq!(
            a.solution.unwrap().eval.duration,
            b.solution.unwrap().eval.duration
        );
    }

    #[test]
    fn different_budgets_are_different_entries() {
        let g = chain();
        let mut c = Coordinator::new();
        let mut req =
            SolveRequest { budget: 10, time_limit: Duration::from_secs(5), ..Default::default() };
        let _ = c.solve(&g, &req);
        req.budget = 13;
        let r = c.solve(&g, &req);
        assert!(!r.from_cache);
        assert_eq!(r.solution.unwrap().eval.remat_count, 0);
    }

    #[test]
    fn backends_agree_on_tiny_graph() {
        let g = chain();
        let mut c = Coordinator::new();
        let m = c.solve(
            &g,
            &SolveRequest { budget: 10, time_limit: Duration::from_secs(10), ..Default::default() },
        );
        let k = c.solve(
            &g,
            &SolveRequest {
                budget: 10,
                time_limit: Duration::from_secs(30),
                backend: Backend::CheckmateMilp,
                ..Default::default()
            },
        );
        // paper §1.2: "demonstrate equivalence of solutions"
        assert_eq!(
            m.solution.unwrap().eval.duration,
            k.solution.unwrap().eval.duration
        );
    }

    // NOTE: the panicking-member containment tests (formerly driven by
    // a test-only `panic_for_test` request flag) live in the
    // `resilience` integration suite now — panics are injected through
    // the `coordinator.solve` failpoint, which must not be armed from
    // in-process unit tests (the registry is process-global and unit
    // tests run concurrently).

    #[test]
    fn clean_solve_carries_clean_provenance() {
        let g = chain();
        let mut c = Coordinator::new();
        let req =
            SolveRequest { budget: 10, time_limit: Duration::from_secs(5), ..Default::default() };
        let r = c.solve(&g, &req);
        assert!(r.solution.is_some());
        let deg = r.degradation.expect("moccasin backend reports provenance");
        assert!(deg.is_clean(), "fault-free solve must be clean: {:?}", deg.failures);
        // (no zero-assertion on the absorbed global event counters:
        // they are process-global and other tests run concurrently)
        // cached copies keep the provenance verbatim
        let again = c.solve(&g, &req);
        assert!(again.from_cache);
        assert!(again.degradation.expect("cached provenance").is_clean());
    }

    #[test]
    fn invalid_orders_are_rejected_without_aborting() {
        // Regression: the serial path has no catch_unwind, so every
        // malformed explicit order — wrong length, out-of-range ids,
        // duplicates, non-topological permutations (all of which used
        // to abort the process inside a backend's model build) — must
        // be rejected by validation as an error response.
        let g = chain();
        let mut c = Coordinator::new();
        let base = SolveRequest {
            budget: 10,
            time_limit: Duration::from_secs(5),
            backend: Backend::CheckmateMilp,
            ..Default::default()
        };
        let cases: Vec<(u64, Vec<u32>, &str)> = vec![
            (10, vec![99, 98, 97, 96, 95], "out of range"),
            (11, vec![0, 1], "2 entries"),
            (12, vec![0, 0, 1, 2, 3], "appears twice"),
            (13, vec![4, 3, 2, 1, 0], "not topological"),
        ];
        for (budget, order, needle) in cases {
            let req = SolveRequest { budget, order: Some(order), ..base.clone() };
            let resp = c.solve(&g, &req);
            assert!(resp.solution.is_none());
            let err = resp.error.as_deref().unwrap_or("");
            assert!(
                err.contains("invalid explicit order") && err.contains(needle),
                "unexpected error: {err}"
            );
        }
        // a valid explicit order (the chain's only one) still solves,
        // and its cache entry is distinct from the order-less request's
        let ok = SolveRequest { order: Some(vec![0, 1, 2, 3, 4]), ..base.clone() };
        assert!(c.solve(&g, &ok).solution.is_some());
        let no_order = c.solve(&g, &base);
        assert!(no_order.solution.is_some());
        assert!(!no_order.from_cache, "explicit-order response must not be shared");
    }

    #[test]
    fn bounded_cache_evicts_lru_and_counts() {
        let g = chain();
        let mut c = Coordinator::with_cache_cap(1);
        assert_eq!(c.cache_cap(), 1);
        let req = |budget: u64| SolveRequest {
            budget,
            time_limit: Duration::from_secs(5),
            ..Default::default()
        };
        let _ = c.solve(&g, &req(10));
        assert_eq!(c.cache_len(), 1);
        // second key evicts the first (cap 1)
        let _ = c.solve(&g, &req(13));
        assert_eq!(c.cache_len(), 1);
        assert_eq!(c.cache_evictions(), 1);
        // the evicted request re-solves: a miss, not a hit
        let r = c.solve(&g, &req(10));
        assert!(!r.from_cache, "evicted entry must re-solve");
        assert_eq!(c.hits, 0);
        assert_eq!(c.misses, 3);
        // cap 0 disables caching without disabling solving
        let mut off = Coordinator::with_cache_cap(0);
        let a = off.solve(&g, &req(10));
        let b = off.solve(&g, &req(10));
        assert!(a.solution.is_some() && !b.from_cache);
        assert_eq!(off.cache_len(), 0);
    }

    #[test]
    fn solve_many_dedups_and_fills_cache() {
        let g = chain();
        let mut c = Coordinator::new();
        let req = |budget: u64| SolveRequest {
            budget,
            time_limit: Duration::from_secs(5),
            ..Default::default()
        };
        // 5 requests, 2 unique keys, one duplicated three times
        let batch = vec![
            (&g, req(10)),
            (&g, req(13)),
            (&g, req(10)),
            (&g, req(10)),
            (&g, req(13)),
        ];
        let responses = c.solve_many(&batch);
        assert_eq!(responses.len(), 5);
        assert_eq!(c.misses, 2, "only unique keys are solved");
        assert_eq!(c.hits, 3, "batch duplicates count as hits");
        assert!(!responses[0].from_cache);
        assert!(responses[2].from_cache && responses[3].from_cache);
        assert_eq!(
            responses[0].solution.as_ref().unwrap().eval.duration,
            responses[2].solution.as_ref().unwrap().eval.duration
        );
        // a second batch is now fully cached
        let again = c.solve_many(&batch[..2]);
        assert!(again.iter().all(|r| r.from_cache));
        assert_eq!(c.misses, 2);
    }
}
