//! L3 coordinator: the solve service a downstream user (or the CLI)
//! calls.
//!
//! Wraps the solver portfolio behind a cache: schedules are keyed by
//! (graph fingerprint, budget, C), so a compiler pipeline that
//! re-lowers the same model hits the cache instead of re-solving — the
//! "compile-time" cost the paper optimizes is paid once per
//! (graph, budget). Also exposes the CHECKMATE baselines behind the
//! same interface for the benchmark harness.

use crate::checkmate::{self, CheckmateError};
use crate::graph::{topological_order, Graph, NodeId};
use crate::moccasin::{MoccasinSolver, RematSolution, SolveOutcome};
use crate::util::Deadline;
use std::collections::HashMap;
use std::time::Duration;

/// Which solver backend to use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    Moccasin,
    CheckmateMilp,
    CheckmateLpRounding,
}

/// A solve request.
#[derive(Debug, Clone)]
pub struct SolveRequest {
    pub budget: u64,
    pub c: usize,
    pub time_limit: Duration,
    pub backend: Backend,
    /// optional explicit input topological order
    pub order: Option<Vec<NodeId>>,
}

impl Default for SolveRequest {
    fn default() -> Self {
        SolveRequest {
            budget: u64::MAX,
            c: 2,
            time_limit: Duration::from_secs(60),
            backend: Backend::Moccasin,
            order: None,
        }
    }
}

/// A solve response: the best schedule plus anytime metadata.
#[derive(Debug, Clone)]
pub struct SolveResponse {
    pub solution: Option<RematSolution>,
    /// (elapsed, duration) anytime trace
    pub trace: Vec<(Duration, u64)>,
    pub proved_optimal: bool,
    pub from_cache: bool,
    pub error: Option<String>,
}

/// The coordinator: solver portfolio + solution cache.
#[derive(Default)]
pub struct Coordinator {
    cache: HashMap<(u64, u64, usize, u8), SolveResponse>,
    pub hits: u64,
    pub misses: u64,
}

impl Coordinator {
    pub fn new() -> Self {
        Self::default()
    }

    /// Solve (or fetch from cache).
    pub fn solve(&mut self, graph: &Graph, req: &SolveRequest) -> SolveResponse {
        let key = (graph.fingerprint(), req.budget, req.c, req.backend as u8);
        if let Some(hit) = self.cache.get(&key) {
            self.hits += 1;
            let mut r = hit.clone();
            r.from_cache = true;
            return r;
        }
        self.misses += 1;
        let resp = self.solve_uncached(graph, req);
        self.cache.insert(key, resp.clone());
        resp
    }

    fn solve_uncached(&self, graph: &Graph, req: &SolveRequest) -> SolveResponse {
        let order = req
            .order
            .clone()
            .unwrap_or_else(|| topological_order(graph).expect("DAG required"));
        match req.backend {
            Backend::Moccasin => {
                let solver = MoccasinSolver {
                    c: req.c,
                    time_limit: req.time_limit,
                    ..Default::default()
                };
                let out: SolveOutcome = solver.solve(graph, req.budget, Some(order));
                SolveResponse {
                    trace: out.trace.iter().map(|p| (p.elapsed, p.duration)).collect(),
                    proved_optimal: out.proved_optimal,
                    solution: out.best,
                    from_cache: false,
                    error: None,
                }
            }
            Backend::CheckmateMilp => {
                let deadline = Deadline::after(req.time_limit);
                let mut trace = Vec::new();
                let r = checkmate::solve_milp(graph, &order, req.budget, deadline, |sol| {
                    trace.push((deadline.elapsed(), sol.eval.duration));
                });
                match r {
                    Ok(res) => SolveResponse {
                        solution: Some(res.solution),
                        trace,
                        proved_optimal: res.proved_optimal,
                        from_cache: false,
                        error: None,
                    },
                    Err(e) => SolveResponse {
                        solution: None,
                        trace,
                        proved_optimal: matches!(e, CheckmateError::NoSolution),
                        from_cache: false,
                        error: Some(e.to_string()),
                    },
                }
            }
            Backend::CheckmateLpRounding => {
                let t0 = std::time::Instant::now();
                // iteration count scaled to the time limit (PDHG is the
                // dominant cost)
                let iters = (req.time_limit.as_millis() as usize * 2).clamp(2_000, 200_000);
                match checkmate::solve_lp_rounding(graph, &order, req.budget, iters) {
                    Ok(res) => SolveResponse {
                        trace: vec![(t0.elapsed(), res.solution.eval.duration)],
                        solution: Some(res.solution),
                        proved_optimal: false,
                        from_cache: false,
                        error: None,
                    },
                    Err(e) => SolveResponse {
                        solution: None,
                        trace: Vec::new(),
                        proved_optimal: false,
                        from_cache: false,
                        error: Some(e.to_string()),
                    },
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain() -> Graph {
        Graph::from_edges(
            "c",
            5,
            &[(0, 1), (1, 2), (2, 3), (3, 4), (0, 4)],
            vec![1; 5],
            vec![5, 4, 4, 4, 1],
        )
        .unwrap()
    }

    #[test]
    fn cache_hit_on_second_solve() {
        let g = chain();
        let mut c = Coordinator::new();
        let req = SolveRequest { budget: 10, time_limit: Duration::from_secs(5), ..Default::default() };
        let a = c.solve(&g, &req);
        assert!(!a.from_cache);
        let b = c.solve(&g, &req);
        assert!(b.from_cache);
        assert_eq!(c.hits, 1);
        assert_eq!(
            a.solution.unwrap().eval.duration,
            b.solution.unwrap().eval.duration
        );
    }

    #[test]
    fn different_budgets_are_different_entries() {
        let g = chain();
        let mut c = Coordinator::new();
        let mut req = SolveRequest { budget: 10, time_limit: Duration::from_secs(5), ..Default::default() };
        let _ = c.solve(&g, &req);
        req.budget = 13;
        let r = c.solve(&g, &req);
        assert!(!r.from_cache);
        assert_eq!(r.solution.unwrap().eval.remat_count, 0);
    }

    #[test]
    fn backends_agree_on_tiny_graph() {
        let g = chain();
        let mut c = Coordinator::new();
        let m = c.solve(
            &g,
            &SolveRequest { budget: 10, time_limit: Duration::from_secs(10), ..Default::default() },
        );
        let k = c.solve(
            &g,
            &SolveRequest {
                budget: 10,
                time_limit: Duration::from_secs(30),
                backend: Backend::CheckmateMilp,
                ..Default::default()
            },
        );
        // paper §1.2: "demonstrate equivalence of solutions"
        assert_eq!(
            m.solution.unwrap().eval.duration,
            k.solution.unwrap().eval.duration
        );
    }
}
