//! L3 coordinator: the solve service a downstream user (or the CLI)
//! calls.
//!
//! Wraps the solver portfolio behind a cache: schedules are keyed by
//! (graph fingerprint, budget, C, backend, …, explicit-order hash), so
//! a compiler pipeline that re-lowers the same model hits the cache
//! instead of re-solving — the "compile-time" cost the paper optimizes
//! is paid once per (graph, budget). The CHECKMATE baselines are
//! exposed behind the same interface for the benchmark harness.
//!
//! Two parallel entry points sit on top of the serial `solve`:
//!
//! * [`Backend::Portfolio`] — one request, many worker threads racing
//!   diversified solvers that share an atomic incumbent bound and a
//!   cancellation flag (see [`portfolio`]).
//! * [`Coordinator::solve_many`] — many requests (e.g. a budget sweep)
//!   scheduled across a worker pool with cache-aware deduplication:
//!   requests whose key is already cached are answered inline,
//!   duplicates inside the batch are solved once, and only unique
//!   misses reach the pool.

pub mod portfolio;

pub use portfolio::{solve_portfolio, PortfolioConfig};

use crate::checkmate::{self, CheckmateError};
use crate::cp::{SearchStats, SearchStrategy};
use crate::graph::{topological_order, Graph, NodeId};
use crate::moccasin::{MoccasinSolver, RematSolution, SolveOutcome};
use crate::presolve::{Presolve, PresolveConfig};
use crate::util::Deadline;
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Which solver backend to use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// The MOCCASIN retention-interval solver (serial: Phase-1 greedy,
    /// exact B&B on small graphs, anytime LNS on large ones).
    Moccasin,
    /// The CHECKMATE exact MILP baseline (pseudo-Boolean B&B).
    CheckmateMilp,
    /// The CHECKMATE LP-relaxation + two-stage-rounding baseline.
    CheckmateLpRounding,
    /// Parallel portfolio race: MOCCASIN members with diversified
    /// orders/seeds plus the CHECKMATE MILP, sharing an atomic
    /// incumbent; the first optimality proof cancels the rest.
    Portfolio,
}

/// A solve request.
#[derive(Debug, Clone)]
pub struct SolveRequest {
    /// Memory budget `M` (peak-footprint cap).
    pub budget: u64,
    /// Max retention intervals per node (the paper's `C`).
    pub c: usize,
    /// Wall-clock limit for the solve.
    pub time_limit: Duration,
    /// Solver backend.
    pub backend: Backend,
    /// optional explicit input topological order
    pub order: Option<Vec<NodeId>>,
    /// Root presolve configuration (default: the exactness-preserving
    /// level). Part of the cache key — different reductions may yield
    /// different anytime traces or (non-exact levels) different optima.
    pub presolve: PresolveConfig,
    /// CP kernel search strategy (chronological | learned). Part of the
    /// cache key: both modes reach the same optimum, but traces, stats
    /// and proofs-per-member differ, so responses are not interchangeable.
    pub search: SearchStrategy,
    /// Test-only fault injection: makes the uncached solve panic, so
    /// the batched path's panic containment (catch_unwind, poisoned
    /// slot recovery) stays regression-tested even though order
    /// validation removed every representable panicking input.
    #[cfg(test)]
    pub(crate) panic_for_test: bool,
}

impl Default for SolveRequest {
    fn default() -> Self {
        SolveRequest {
            budget: u64::MAX,
            c: 2,
            time_limit: Duration::from_secs(60),
            backend: Backend::Moccasin,
            order: None,
            presolve: PresolveConfig::default(),
            search: SearchStrategy::default(),
            #[cfg(test)]
            panic_for_test: false,
        }
    }
}

/// A solve response: the best schedule plus anytime metadata.
#[derive(Debug, Clone)]
pub struct SolveResponse {
    /// Best schedule found (`None` if the budget was unreachable within
    /// the limits).
    pub solution: Option<RematSolution>,
    /// (elapsed, duration) anytime trace
    pub trace: Vec<(Duration, u64)>,
    /// Whether optimality (or infeasibility) was proved.
    pub proved_optimal: bool,
    /// Whether this response was served from the schedule cache.
    pub from_cache: bool,
    /// Why no solution was produced, when one wasn't.
    pub error: Option<String>,
    /// Aggregated CP kernel statistics (summed across portfolio
    /// members for [`Backend::Portfolio`]; zero for pure-LP backends
    /// and preserved from the original solve on cache hits).
    pub stats: SearchStats,
}

/// Cache key: (graph fingerprint, budget, C, backend discriminant,
/// presolve level discriminant, interval-length cap, search-strategy
/// discriminant, explicit-order hash). The order hash matters: the
/// staged model is order-relative, so responses for different explicit
/// orders — including order-validation failures — are not
/// interchangeable (0 = no explicit order).
type CacheKey = (u64, u64, usize, u8, u8, i64, u8, u64);

/// The coordinator: solver portfolio + solution cache + worker pool
/// configuration for batched solves.
#[derive(Default)]
pub struct Coordinator {
    cache: HashMap<CacheKey, SolveResponse>,
    /// Worker threads used by [`Coordinator::solve_many`] and by
    /// [`Backend::Portfolio`] members. `0` = auto (available
    /// parallelism).
    pub threads: usize,
    /// Cache hits served so far (including batch-deduplicated requests).
    pub hits: u64,
    /// Cache misses (actual solves) so far.
    pub misses: u64,
}

impl Coordinator {
    /// Fresh coordinator with an empty cache and automatic parallelism.
    pub fn new() -> Self {
        Self::default()
    }

    /// Worker count for batched solves (resolves the `0` = auto
    /// default).
    fn worker_count(&self) -> usize {
        if self.threads != 0 {
            return self.threads;
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
    }

    fn cache_key(graph: &Graph, req: &SolveRequest) -> CacheKey {
        let order_hash = req
            .order
            .as_ref()
            .map(|o| {
                use std::hash::{Hash, Hasher};
                let mut h = std::collections::hash_map::DefaultHasher::new();
                o.hash(&mut h);
                // | 1 keeps every explicit order distinct from the
                // "no explicit order" sentinel 0
                h.finish() | 1
            })
            .unwrap_or(0);
        (
            graph.fingerprint(),
            req.budget,
            req.c,
            req.backend as u8,
            req.presolve.level as u8,
            // builders clamp negative caps to 0, so key them as 0 too —
            // the -1 sentinel stays reserved for "no cap"
            req.presolve.max_interval_len.map(|l| l.max(0)).unwrap_or(-1),
            req.search.cache_key(),
            order_hash,
        )
    }

    /// Solve (or fetch from cache).
    pub fn solve(&mut self, graph: &Graph, req: &SolveRequest) -> SolveResponse {
        let key = Self::cache_key(graph, req);
        if let Some(hit) = self.cache.get(&key) {
            self.hits += 1;
            let mut r = hit.clone();
            r.from_cache = true;
            return r;
        }
        self.misses += 1;
        let resp = self.solve_uncached(graph, req);
        self.cache.insert(key, resp.clone());
        resp
    }

    /// Solve a batch of requests across the worker pool with cache-aware
    /// deduplication.
    ///
    /// Semantics per request, in order:
    /// 1. key already in the cache → answered from cache (`from_cache`);
    /// 2. key duplicated earlier in the batch → solved once, duplicate
    ///    answered from the fresh cache entry (`from_cache`, counted as
    ///    a hit);
    /// 3. otherwise → solved on the pool (counted as a miss).
    ///
    /// Responses are returned in request order. Wall-clock for a sweep
    /// of `k` unique requests approaches `ceil(k / threads)` serial
    /// solves.
    pub fn solve_many(&mut self, requests: &[(&Graph, SolveRequest)]) -> Vec<SolveResponse> {
        let keys: Vec<CacheKey> =
            requests.iter().map(|(g, r)| Self::cache_key(g, r)).collect();
        let mut out: Vec<Option<SolveResponse>> = vec![None; requests.len()];

        // cache pass + batch dedup: `jobs` holds request indices of
        // unique misses, `job_of_key` maps each missed key to its job
        // slot so duplicates can inherit uncacheable failure responses
        let mut jobs: Vec<usize> = Vec::new();
        let mut job_of_key: HashMap<CacheKey, usize> = HashMap::new();
        let mut seen: HashSet<CacheKey> = HashSet::new();
        for (i, key) in keys.iter().enumerate() {
            if let Some(hit) = self.cache.get(key) {
                self.hits += 1;
                let mut r = hit.clone();
                r.from_cache = true;
                out[i] = Some(r);
            } else if !seen.insert(*key) {
                self.hits += 1; // batch duplicate: filled after the solves
            } else {
                self.misses += 1;
                job_of_key.insert(*key, jobs.len());
                jobs.push(i);
            }
        }

        // Run unique misses on the worker pool. Failure containment
        // (regression-tested by `solve_many_survives_panicking_member`):
        // a panicking solve used to poison its slot mutex and abort the
        // *whole batch* when the scope re-raised the panic — now each
        // solve runs under `catch_unwind`, a poisoned slot lock is
        // recovered (the data is a plain `Option` write, so poisoning
        // carries no invariant), and a slot a worker never filled is
        // surfaced as that request's member failure instead of an
        // `expect` abort.
        // slot payload: (response, cacheable) — a response from a
        // *completed* solve (including deterministic validation
        // failures) is cacheable; one synthesized from a contained
        // panic is not, since a surviving panic is by construction not
        // input-deterministic (validation removed those) and a retry
        // may well succeed
        let results: Vec<Option<(SolveResponse, bool)>> = {
            let slots: Vec<Mutex<Option<(SolveResponse, bool)>>> =
                jobs.iter().map(|_| Mutex::new(None)).collect();
            let next = AtomicUsize::new(0);
            let workers = self.worker_count().min(jobs.len().max(1));
            let me: &Coordinator = self;
            let jobs_ref = &jobs;
            std::thread::scope(|s| {
                for _ in 0..workers {
                    let slots = &slots;
                    let next = &next;
                    s.spawn(move || loop {
                        let j = next.fetch_add(1, Ordering::Relaxed);
                        if j >= jobs_ref.len() {
                            break;
                        }
                        let i = jobs_ref[j];
                        let (graph, req) = &requests[i];
                        let resp = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                            || me.solve_uncached(graph, req),
                        ))
                        .map(|r| (r, true))
                        .unwrap_or_else(|p| {
                            (member_failure_response(&panic_message(&p)), false)
                        });
                        match slots[j].lock() {
                            Ok(mut g) => *g = Some(resp),
                            Err(poisoned) => *poisoned.into_inner() = Some(resp),
                        }
                    });
                }
            });
            slots
                .into_iter()
                .map(|m| m.into_inner().unwrap_or_else(|p| p.into_inner()))
                .collect()
        };

        // Publish results into the cache + the output slots. A solve
        // that completed — successfully or with a deterministic error
        // response — is cached; contained panics and unfilled slots
        // are surfaced but never cached, so a retry of the same
        // request actually re-solves.
        for (j, &i) in jobs.iter().enumerate() {
            match &results[j] {
                Some((resp, cacheable)) => {
                    if *cacheable {
                        self.cache.insert(keys[i], resp.clone());
                    }
                    out[i] = Some(resp.clone());
                }
                None => {
                    out[i] = Some(member_failure_response(
                        "worker exited without filling its slot",
                    ));
                }
            }
        }
        // batch duplicates read the now-warm cache, or inherit their
        // twin's uncacheable failure response verbatim (so both copies
        // of a panicked request report the same diagnostic)
        for (i, slot) in out.iter_mut().enumerate() {
            if slot.is_none() {
                *slot = Some(match self.cache.get(&keys[i]) {
                    Some(hit) => {
                        let mut r = hit.clone();
                        r.from_cache = true;
                        r
                    }
                    None => job_of_key
                        .get(&keys[i])
                        .and_then(|&j| results[j].as_ref())
                        .map(|(resp, _)| resp.clone())
                        .unwrap_or_else(|| {
                            member_failure_response("batch twin's solve did not complete")
                        }),
                });
            }
        }
        out.into_iter()
            .map(|o| {
                o.unwrap_or_else(|| member_failure_response("request left unanswered"))
            })
            .collect()
    }

    /// Solve one request without consulting the cache. An explicit
    /// order is validated up front (right length, in-range ids, a
    /// permutation, topological): every backend indexes by order
    /// positions and the staged model is order-relative, so a bad
    /// order must become an error response — on the serial path there
    /// is no `catch_unwind` to save the process (the batched path
    /// keeps one anyway as defense in depth against other panics).
    fn solve_uncached(&self, graph: &Graph, req: &SolveRequest) -> SolveResponse {
        if let Some(o) = &req.order {
            if let Err(why) = validate_order(graph, o) {
                return member_failure_response(&why);
            }
        }
        #[cfg(test)]
        if req.panic_for_test {
            panic!("injected test panic (solver fault injection)");
        }
        let order = req
            .order
            .clone()
            .unwrap_or_else(|| topological_order(graph).expect("DAG required"));
        match req.backend {
            Backend::Moccasin => {
                let solver = MoccasinSolver {
                    c: req.c,
                    time_limit: req.time_limit,
                    presolve: req.presolve,
                    search: req.search,
                    ..Default::default()
                };
                let out: SolveOutcome = solver.solve(graph, req.budget, Some(order));
                SolveResponse {
                    trace: out.trace.iter().map(|p| (p.elapsed, p.duration)).collect(),
                    proved_optimal: out.proved_optimal,
                    solution: out.best,
                    from_cache: false,
                    error: None,
                    stats: out.stats,
                }
            }
            Backend::Portfolio => {
                let cfg = PortfolioConfig {
                    threads: self.threads,
                    time_limit: req.time_limit,
                    c: req.c,
                    seed: 0,
                    include_checkmate: true,
                    presolve: req.presolve,
                    search: req.search,
                };
                solve_portfolio(graph, req.budget, Some(order), &cfg)
            }
            Backend::CheckmateMilp => {
                let deadline = Deadline::after(req.time_limit);
                let mut trace = Vec::new();
                let r = checkmate::solve_milp(
                    graph,
                    &order,
                    req.budget,
                    deadline.clone(),
                    // solve_milp's reduction is purely logical — skip
                    // the reachability analysis on this path
                    &Presolve::config_only(req.presolve),
                    req.search,
                    |sol| {
                        trace.push((deadline.elapsed(), sol.eval.duration));
                    },
                );
                match r {
                    Ok(res) => SolveResponse {
                        solution: Some(res.solution),
                        trace,
                        proved_optimal: res.proved_optimal,
                        from_cache: false,
                        error: None,
                        stats: res.stats,
                    },
                    Err(e) => SolveResponse {
                        solution: None,
                        trace,
                        proved_optimal: matches!(e, CheckmateError::NoSolution { .. }),
                        from_cache: false,
                        stats: match &e {
                            CheckmateError::NoSolution { stats } => *stats,
                            _ => SearchStats::default(),
                        },
                        error: Some(e.to_string()),
                    },
                }
            }
            Backend::CheckmateLpRounding => {
                let t0 = std::time::Instant::now();
                // iteration count scaled to the time limit (PDHG is the
                // dominant cost)
                let iters = (req.time_limit.as_millis() as usize * 2).clamp(2_000, 200_000);
                match checkmate::solve_lp_rounding(graph, &order, req.budget, iters) {
                    Ok(res) => SolveResponse {
                        trace: vec![(t0.elapsed(), res.solution.eval.duration)],
                        solution: Some(res.solution),
                        proved_optimal: false,
                        from_cache: false,
                        error: None,
                        stats: SearchStats::default(),
                    },
                    Err(e) => SolveResponse {
                        solution: None,
                        trace: Vec::new(),
                        proved_optimal: false,
                        from_cache: false,
                        error: Some(e.to_string()),
                        stats: SearchStats::default(),
                    },
                }
            }
        }
    }
}

/// Check that an explicit request order is a topological permutation
/// of the graph's nodes (what every backend assumes): right length,
/// in-range ids, no duplicates, and every predecessor scheduled before
/// its consumer. Returns a description of the first violation.
fn validate_order(graph: &Graph, order: &[NodeId]) -> Result<(), String> {
    let n = graph.n();
    if order.len() != n {
        return Err(format!(
            "invalid explicit order: {} entries for a {n}-node graph",
            order.len()
        ));
    }
    let mut seen = vec![false; n];
    for &v in order {
        let vi = v as usize;
        if vi >= n {
            return Err(format!("invalid explicit order: node id {v} out of range (n = {n})"));
        }
        if seen[vi] {
            return Err(format!("invalid explicit order: node {v} appears twice"));
        }
        for &p in &graph.preds[vi] {
            if !seen[p as usize] {
                return Err(format!(
                    "invalid explicit order: not topological (node {v} before its \
                     predecessor {p})"
                ));
            }
        }
        seen[vi] = true;
    }
    Ok(())
}

/// The response reported for a request whose solve did not complete
/// (panicked worker / unfilled slot): an error, never an abort.
fn member_failure_response(why: &str) -> SolveResponse {
    SolveResponse {
        solution: None,
        trace: Vec::new(),
        proved_optimal: false,
        from_cache: false,
        error: Some(format!("solver member failed: {why}")),
        stats: SearchStats::default(),
    }
}

/// Best-effort panic payload message (panics carry `&str` or `String`
/// in practice).
fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "panicked (non-string payload)".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain() -> Graph {
        Graph::from_edges(
            "c",
            5,
            &[(0, 1), (1, 2), (2, 3), (3, 4), (0, 4)],
            vec![1; 5],
            vec![5, 4, 4, 4, 1],
        )
        .unwrap()
    }

    #[test]
    fn cache_hit_on_second_solve() {
        let g = chain();
        let mut c = Coordinator::new();
        let req =
            SolveRequest { budget: 10, time_limit: Duration::from_secs(5), ..Default::default() };
        let a = c.solve(&g, &req);
        assert!(!a.from_cache);
        let b = c.solve(&g, &req);
        assert!(b.from_cache);
        assert_eq!(c.hits, 1);
        assert_eq!(
            a.solution.unwrap().eval.duration,
            b.solution.unwrap().eval.duration
        );
    }

    #[test]
    fn different_budgets_are_different_entries() {
        let g = chain();
        let mut c = Coordinator::new();
        let mut req =
            SolveRequest { budget: 10, time_limit: Duration::from_secs(5), ..Default::default() };
        let _ = c.solve(&g, &req);
        req.budget = 13;
        let r = c.solve(&g, &req);
        assert!(!r.from_cache);
        assert_eq!(r.solution.unwrap().eval.remat_count, 0);
    }

    #[test]
    fn backends_agree_on_tiny_graph() {
        let g = chain();
        let mut c = Coordinator::new();
        let m = c.solve(
            &g,
            &SolveRequest { budget: 10, time_limit: Duration::from_secs(10), ..Default::default() },
        );
        let k = c.solve(
            &g,
            &SolveRequest {
                budget: 10,
                time_limit: Duration::from_secs(30),
                backend: Backend::CheckmateMilp,
                ..Default::default()
            },
        );
        // paper §1.2: "demonstrate equivalence of solutions"
        assert_eq!(
            m.solution.unwrap().eval.duration,
            k.solution.unwrap().eval.duration
        );
    }

    #[test]
    fn solve_many_survives_panicking_member() {
        // Regression: one panicking worker used to poison its slot
        // mutex and abort the whole batch (scope re-raises the panic);
        // now it must surface as that request's member failure while
        // every other request in the batch is answered normally.
        // Order validation (below) removed every representable
        // panicking input, so the panic is injected via the test-only
        // fault flag. (A panic backtrace on stderr is expected output
        // of this test.)
        let g = chain();
        let mut c = Coordinator::new();
        let good = SolveRequest {
            budget: 10,
            time_limit: Duration::from_secs(5),
            ..Default::default()
        };
        let bad = SolveRequest {
            budget: 11, // distinct cache key from `good`
            time_limit: Duration::from_secs(5),
            panic_for_test: true,
            ..Default::default()
        };
        let responses =
            c.solve_many(&[(&g, good.clone()), (&g, bad), (&g, good)]);
        assert_eq!(responses.len(), 3);
        assert!(responses[0].solution.is_some(), "good request must still solve");
        assert!(responses[2].solution.is_some(), "dup of good request answered");
        assert!(responses[1].solution.is_none());
        let err = responses[1].error.as_deref().unwrap_or("");
        assert!(err.contains("member failed"), "unexpected error text: {err}");
        assert!(err.contains("injected test panic"), "panic payload lost: {err}");
    }

    #[test]
    fn invalid_orders_are_rejected_without_aborting() {
        // Regression: the serial path has no catch_unwind, so every
        // malformed explicit order — wrong length, out-of-range ids,
        // duplicates, non-topological permutations (all of which used
        // to abort the process inside a backend's model build) — must
        // be rejected by validation as an error response.
        let g = chain();
        let mut c = Coordinator::new();
        let base = SolveRequest {
            budget: 10,
            time_limit: Duration::from_secs(5),
            backend: Backend::CheckmateMilp,
            ..Default::default()
        };
        let cases: Vec<(u64, Vec<u32>, &str)> = vec![
            (10, vec![99, 98, 97, 96, 95], "out of range"),
            (11, vec![0, 1], "2 entries"),
            (12, vec![0, 0, 1, 2, 3], "appears twice"),
            (13, vec![4, 3, 2, 1, 0], "not topological"),
        ];
        for (budget, order, needle) in cases {
            let req = SolveRequest { budget, order: Some(order), ..base.clone() };
            let resp = c.solve(&g, &req);
            assert!(resp.solution.is_none());
            let err = resp.error.as_deref().unwrap_or("");
            assert!(
                err.contains("invalid explicit order") && err.contains(needle),
                "unexpected error: {err}"
            );
        }
        // a valid explicit order (the chain's only one) still solves,
        // and its cache entry is distinct from the order-less request's
        let ok = SolveRequest { order: Some(vec![0, 1, 2, 3, 4]), ..base.clone() };
        assert!(c.solve(&g, &ok).solution.is_some());
        let no_order = c.solve(&g, &base);
        assert!(no_order.solution.is_some());
        assert!(!no_order.from_cache, "explicit-order response must not be shared");
    }

    #[test]
    fn solve_many_dedups_and_fills_cache() {
        let g = chain();
        let mut c = Coordinator::new();
        let req = |budget: u64| SolveRequest {
            budget,
            time_limit: Duration::from_secs(5),
            ..Default::default()
        };
        // 5 requests, 2 unique keys, one duplicated three times
        let batch = vec![
            (&g, req(10)),
            (&g, req(13)),
            (&g, req(10)),
            (&g, req(10)),
            (&g, req(13)),
        ];
        let responses = c.solve_many(&batch);
        assert_eq!(responses.len(), 5);
        assert_eq!(c.misses, 2, "only unique keys are solved");
        assert_eq!(c.hits, 3, "batch duplicates count as hits");
        assert!(!responses[0].from_cache);
        assert!(responses[2].from_cache && responses[3].from_cache);
        assert_eq!(
            responses[0].solution.as_ref().unwrap().eval.duration,
            responses[2].solution.as_ref().unwrap().eval.duration
        );
        // a second batch is now fully cached
        let again = c.solve_many(&batch[..2]);
        assert!(again.iter().all(|r| r.from_cache));
        assert_eq!(c.misses, 2);
    }
}
