//! Parallel portfolio solve: worker threads racing diversified solvers
//! over one `(graph, budget)` request.
//!
//! The paper's headline claim is wall-clock (§3): MOCCASIN's O(n) model
//! solves an order of magnitude faster than CHECKMATE's O(n²) MILP, and
//! its anytime behaviour is what makes it usable on large graphs. The
//! portfolio turns that anytime behaviour into a multi-core solve
//! service: member 0 runs MOCCASIN on the canonical (Kahn) topological
//! order, further members run MOCCASIN from *random* topological orders
//! with different LNS seeds, window sizes and **search strategies**
//! (odd members use the conflict-driven learned kernel, member 0 stays
//! chronological so proofs are reproduced by a learning-free search;
//! the paper itself randomizes the input order, §3.3), and — when the
//! model fits — one member runs the CHECKMATE MILP baseline.
//!
//! All members share an [`Incumbent`]: every validated improving
//! solution is published to the atomic best-duration bound, every
//! branch-and-bound member prunes against the best solution found
//! *anywhere* (see `cp::search`), and the first optimality proof
//! cancels the rest of the race through the cancellation flag each
//! member's [`Deadline`] carries.
//!
//! Because the staged model (§2.3) is *order-relative*, only the
//! canonical-order member (and the order-respecting CHECKMATE member)
//! may declare the race decided — a random-order member's optimality
//! proof bounds its own order only, so such members contribute
//! solutions and pruning bounds but never cancel the race.

use super::watchdog::{Watchdog, WatchdogConfig};
use super::SolveResponse;
use crate::checkmate;
use crate::cp::{SearchStats, SearchStrategy};
use crate::graph::{random_topological_order, topological_order, Graph, NodeId};
use crate::moccasin::{Degradation, MoccasinSolver, RematSolution, Rung};
use crate::presolve::{GraphAnalysis, Presolve, PresolveConfig, PresolveLevel};
use crate::util::{events, Deadline, Incumbent, Rng};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Configuration of a portfolio solve.
#[derive(Debug, Clone)]
pub struct PortfolioConfig {
    /// Racing members (worker threads). `0` = auto: the machine's
    /// available parallelism, capped at 4.
    pub threads: usize,
    /// Wall-clock limit shared by all members.
    pub time_limit: Duration,
    /// Max retention intervals per node (the paper's `C`).
    pub c: usize,
    /// Base RNG seed for member diversification (orders + LNS).
    pub seed: u64,
    /// Dedicate one member to the CHECKMATE MILP baseline (skipped
    /// automatically on graphs whose O(n²) model would trip the build
    /// guard anyway).
    pub include_checkmate: bool,
    /// Root presolve configuration. The order-independent graph
    /// analysis is computed *once* per request and shared across every
    /// racing member (each member still derives its own order-dependent
    /// staged caps, since members race on different topological orders).
    pub presolve: PresolveConfig,
    /// Requested base search strategy. Members diversify over
    /// *strategies*, not just orders and seeds: member 0 always runs
    /// chronologically (so optimality proofs are reproduced by a
    /// learning-free search), odd members run the learned strategy, and
    /// the remaining members follow this setting.
    pub search: SearchStrategy,
    /// Watchdog heartbeat-stall threshold override in milliseconds
    /// (`None` = derived from the wall budget; see
    /// [`WatchdogConfig::for_wall`]).
    pub stall_ms: Option<u64>,
    /// Watchdog peak-RSS limit in kilobytes (`None` = no memory guard).
    pub rss_limit_kb: Option<u64>,
}

impl Default for PortfolioConfig {
    fn default() -> Self {
        PortfolioConfig {
            threads: 0,
            time_limit: Duration::from_secs(60),
            c: 2,
            seed: 0,
            include_checkmate: true,
            presolve: PresolveConfig::default(),
            search: SearchStrategy::default(),
            stall_ms: None,
            rss_limit_kb: None,
        }
    }
}

impl PortfolioConfig {
    /// Resolve `threads == 0` to the machine's parallelism (capped at 4
    /// so a default solve does not monopolize a large host).
    pub fn effective_threads(&self) -> usize {
        if self.threads != 0 {
            return self.threads.max(1);
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2).min(4).max(1)
    }
}

/// State shared by all racing members.
struct Shared {
    incumbent: Arc<Incumbent>,
    best: Mutex<Option<RematSolution>>,
    /// merged anytime trace: (elapsed since race start, duration)
    trace: Mutex<Vec<(Duration, u64)>>,
    /// CP kernel statistics summed across all members
    stats: Mutex<SearchStats>,
    /// degradation provenance for the whole race: member 0 (the
    /// canonical-order member) contributes its rung and phase spend;
    /// every member contributes absorbed failures
    degradation: Mutex<Degradation>,
    /// per-request resilience counters: this race's lock recoveries and
    /// member panics, never another in-flight request's (the serving
    /// tier runs many races concurrently)
    rec: events::Recorder,
    proved: AtomicBool,
    started: Instant,
}

/// Lock a member-shared mutex, recovering from poisoning: the guarded
/// data are plain values (an `Option`, a `Vec`, counters) written in
/// single statements, so a panic while holding the lock leaves no
/// broken invariant — and one crashed member must degrade to a member
/// failure, never abort the race for everyone. Recoveries are counted
/// against the race's [`events::Recorder`] (which also bumps the
/// process-global diagnostics) so they surface in *this request's*
/// stats instead of passing silently or leaking into a concurrent
/// solve's.
fn lock_recover<'a, T>(
    m: &'a Mutex<T>,
    rec: &events::Recorder,
) -> std::sync::MutexGuard<'a, T> {
    m.lock().unwrap_or_else(|p| {
        rec.note_lock_recovery();
        p.into_inner()
    })
}

impl Shared {
    /// Publish a member's validated solution into the shared best +
    /// merged trace (strict improvements only).
    fn publish(&self, sol: &RematSolution) {
        let mut best = lock_recover(&self.best, &self.rec);
        let improved =
            best.as_ref().map(|b| sol.eval.duration < b.eval.duration).unwrap_or(true);
        if improved {
            lock_recover(&self.trace, &self.rec)
                .push((self.started.elapsed(), sol.eval.duration));
            *best = Some(sol.clone());
        }
    }

    /// Record an optimality (or infeasibility) proof and cancel the
    /// race — but only if the proof still covers the shared best.
    ///
    /// `proven` is the duration the exhausted member proved unbeatable
    /// (`None` = it proved its model infeasible). The check runs under
    /// the same lock `publish` takes, so a racing member cannot slip a
    /// strictly better solution in between the proof check and the
    /// `proved` flag — without this, the response could claim
    /// optimality for a solution no proof covers.
    fn decide(&self, proven: Option<u64>) {
        let best = lock_recover(&self.best, &self.rec);
        let current = best.as_ref().map(|b| b.eval.duration);
        let covered = match (proven, current) {
            // optimality proof at exactly the shared best
            (Some(d), Some(c)) => c == d,
            // infeasibility proof, and nobody found anything either
            (None, None) => true,
            // proof is stale (someone else did better) or covers a
            // different order's model only
            _ => false,
        };
        if covered {
            self.proved.store(true, Ordering::Release);
            self.incumbent.cancel();
        }
    }
}

/// Race `cfg` members over one request and return the best solution
/// found anywhere, with the merged anytime trace. `order`, when given,
/// is the canonical input topological order used by member 0 (and the
/// CHECKMATE member); `None` uses the deterministic Kahn order.
pub fn solve_portfolio(
    graph: &Graph,
    budget: u64,
    order: Option<Vec<NodeId>>,
    cfg: &PortfolioConfig,
) -> SolveResponse {
    let threads = cfg.effective_threads();
    let base_order = match order.or_else(|| topological_order(graph)) {
        Some(o) => o,
        // cycle: no schedule exists; fail structurally like any member
        None => return super::member_failure_response("graph is not a DAG (cycle detected)"),
    };
    let shared = Shared {
        incumbent: Arc::new(Incumbent::new()),
        best: Mutex::new(None),
        trace: Mutex::new(Vec::new()),
        stats: Mutex::new(SearchStats::default()),
        // member 0 runs chronologically (see `member_strategy`), so that
        // is the race's baseline rung until member 0 reports otherwise
        degradation: Mutex::new(Degradation::clean(Rung::Chronological)),
        rec: events::Recorder::new(),
        proved: AtomicBool::new(false),
        started: Instant::now(),
    };
    let checkmate_member =
        cfg.include_checkmate && threads >= 2 && checkmate_member_viable(graph);
    // presolve once, share across members: the expensive reachability /
    // transitive-reduction analysis is order-independent (run before the
    // watchdog starts so analysis time does not eat the stall warmup)
    let analysis: Option<Arc<GraphAnalysis>> = (cfg.presolve.level != PresolveLevel::Off)
        .then(|| Arc::new(GraphAnalysis::analyze(graph)));
    let watchdog = Watchdog::spawn(
        Arc::clone(&shared.incumbent),
        WatchdogConfig::for_wall(cfg.time_limit, cfg.rss_limit_kb, cfg.stall_ms),
    );

    std::thread::scope(|s| {
        for m in 0..threads {
            let shared = &shared;
            let base_order = &base_order;
            let analysis = &analysis;
            s.spawn(move || {
                // contain member panics: a crashed member contributes
                // nothing, but must not poison the race for the rest
                // (the scope would re-raise its panic otherwise)
                let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    #[cfg(any(test, feature = "failpoints"))]
                    if crate::util::failpoint::hit("portfolio.member").is_some() {
                        lock_recover(&shared.degradation, &shared.rec).note_failure(format!(
                            "failpoint 'portfolio.member': member {m} suppressed at startup"
                        ));
                        return;
                    }
                    if checkmate_member && m == threads - 1 {
                        run_checkmate_member(graph, budget, base_order, cfg, analysis, shared);
                    } else {
                        run_moccasin_member(graph, budget, base_order, cfg, analysis, shared, m);
                    }
                }));
                if let Err(p) = r {
                    shared.rec.note_member_panic();
                    lock_recover(&shared.degradation, &shared.rec).note_failure(format!(
                        "portfolio member {m} panicked: {}",
                        crate::util::panic_note(p.as_ref())
                    ));
                }
            });
        }
    });

    let report = watchdog.stop();
    // exact per-request attribution: this race's own recorder plus its
    // own watchdog's kill count — never a concurrent solve's (the old
    // global snapshot/delta absorption spanned overlapping windows)
    let local_events = shared.rec.local();
    let Shared { best, trace, stats, degradation, proved, .. } = shared;
    let best = best.into_inner().unwrap_or_else(|p| p.into_inner());
    let mut trace = trace.into_inner().unwrap_or_else(|p| p.into_inner());
    trace.sort_unstable();
    let mut degradation = degradation.into_inner().unwrap_or_else(|p| p.into_inner());
    if let Some(reason) = report.reason {
        degradation.note_failure(format!("watchdog: {}", reason.as_str()));
    }
    let mut stats = stats.into_inner().unwrap_or_else(|p| p.into_inner());
    stats.absorb_events(&local_events);
    stats.watchdog_kills += u64::from(report.kills);
    SolveResponse {
        error: best
            .is_none()
            .then(|| "portfolio: no member found a solution".to_string()),
        solution: best,
        trace,
        proved_optimal: proved.load(Ordering::Acquire),
        from_cache: false,
        stats,
        degradation: Some(degradation),
    }
}

/// Whether spending a thread on the O(n² + nm) CHECKMATE model is
/// worthwhile (its build guard trips far earlier than MOCCASIN's).
fn checkmate_member_viable(graph: &Graph) -> bool {
    graph.n() <= 200
}

/// Search strategy for MOCCASIN member `m`: member 0 stays
/// chronological so the race always carries a learning-free member
/// whose optimality proofs are independently reproduced; odd members
/// run the conflict-driven learned search; the rest follow the
/// requested base strategy. Strategy diversification compounds with
/// the order/seed/window diversification below.
fn member_strategy(cfg: &PortfolioConfig, m: usize) -> SearchStrategy {
    // members diversify over search *modes* only; the timetable-profile,
    // filtering-strength and disjunctive choices are orthogonal A/B
    // knobs that must follow the request, or `--profile linear` /
    // `--filtering edge-finding` / `--disjunctive off` could never
    // force their path through a portfolio solve
    if m == 0 {
        SearchStrategy::chronological()
            .with_profile(cfg.search.profile)
            .with_filtering(cfg.search.filtering)
            .with_disjunctive(cfg.search.disjunctive)
    } else if m % 2 == 1 {
        SearchStrategy::learned()
            .with_profile(cfg.search.profile)
            .with_filtering(cfg.search.filtering)
            .with_disjunctive(cfg.search.disjunctive)
    } else {
        cfg.search
    }
}

/// One MOCCASIN member: canonical order for member 0, random
/// topological orders (the paper's §3.3 randomization) plus diversified
/// LNS seeds/windows for the rest.
fn run_moccasin_member(
    graph: &Graph,
    budget: u64,
    base_order: &[NodeId],
    cfg: &PortfolioConfig,
    analysis: &Option<Arc<GraphAnalysis>>,
    shared: &Shared,
    member: usize,
) {
    let order: Vec<NodeId> = if member == 0 {
        base_order.to_vec()
    } else {
        let mut rng = Rng::seed_from_u64(
            cfg.seed ^ (member as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        random_topological_order(graph, &mut rng)
    };
    let solver = MoccasinSolver {
        c: cfg.c,
        time_limit: cfg.time_limit,
        seed: cfg.seed.wrapping_add(member as u64),
        window: 14 + 4 * (member % 3),
        incumbent: Some(Arc::clone(&shared.incumbent)),
        presolve: cfg.presolve,
        analysis: analysis.clone(),
        search: member_strategy(cfg, member),
        ..Default::default()
    };
    let out = solver.solve_with(graph, budget, Some(order), |sol| shared.publish(sol));
    lock_recover(&shared.stats, &shared.rec).merge(&out.stats);
    // fold degradation provenance: member 0 is the canonical member, so
    // its rung and phase spend describe the race; every member's
    // absorbed failures and retries are worth surfacing
    {
        let mut deg = lock_recover(&shared.degradation, &shared.rec);
        if member == 0 {
            deg.rung = out.degradation.rung;
            deg.spend = out.degradation.spend;
        }
        deg.retries += out.degradation.retries;
        for f in &out.degradation.failures {
            deg.note_failure(format!("member {member}: {f}"));
        }
    }
    // Only the canonical-order member may declare the race decided (the
    // staged model is order-relative; see module docs). Its proof is
    // either optimality at its best duration or infeasibility.
    if member == 0 && out.proved_optimal {
        shared.decide(out.best.as_ref().map(|b| b.eval.duration));
    }
}

/// The CHECKMATE MILP member: same canonical order, same shared
/// incumbent (published through the deadline), cancelling the race when
/// it proves its best — which then equals the shared best — optimal.
fn run_checkmate_member(
    graph: &Graph,
    budget: u64,
    order: &[NodeId],
    cfg: &PortfolioConfig,
    analysis: &Option<Arc<GraphAnalysis>>,
    shared: &Shared,
) {
    let deadline =
        Deadline::with_incumbent(cfg.time_limit, Arc::clone(&shared.incumbent));
    let pre = match analysis {
        Some(a) => Presolve::with_shared(Arc::clone(a), cfg.presolve),
        None => Presolve::off(),
    };
    let result = checkmate::solve_milp(graph, order, budget, deadline, &pre, cfg.search, |sol| {
        shared.publish(sol)
    });
    match result {
        Ok(res) => {
            lock_recover(&shared.stats, &shared.rec).merge(&res.stats);
            if res.proved_optimal {
                shared.decide(Some(res.solution.eval.duration));
            }
        }
        // a failed attempt still did kernel work worth counting
        Err(checkmate::CheckmateError::NoSolution { stats }) => {
            lock_recover(&shared.stats, &shared.rec).merge(&stats);
        }
        Err(_) => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Chain + long skip with heavy source: optimum is one remat of
    /// node 0 (duration 6) at budget 10, and the topological order is
    /// forced, so every member works on the same order.
    fn chain() -> Graph {
        Graph::from_edges(
            "c",
            5,
            &[(0, 1), (1, 2), (2, 3), (3, 4), (0, 4)],
            vec![1; 5],
            vec![5, 4, 4, 4, 1],
        )
        .unwrap()
    }

    #[test]
    fn portfolio_matches_known_optimum() {
        let cfg = PortfolioConfig {
            threads: 2,
            time_limit: Duration::from_secs(20),
            ..Default::default()
        };
        let resp = solve_portfolio(&chain(), 10, None, &cfg);
        let sol = resp.solution.expect("feasible at budget 10");
        assert_eq!(sol.eval.duration, 6);
        assert!(sol.eval.peak_mem <= 10);
        assert!(resp.proved_optimal, "exact member must prove the optimum");
    }

    #[test]
    fn portfolio_reports_infeasibility() {
        // budget below the working-set floor: provably infeasible
        let g = Graph::from_edges("d", 2, &[(0, 1)], vec![1, 1], vec![5, 5]).unwrap();
        let cfg = PortfolioConfig {
            threads: 2,
            time_limit: Duration::from_secs(10),
            include_checkmate: false,
            ..Default::default()
        };
        let resp = solve_portfolio(&g, 9, None, &cfg);
        assert!(resp.solution.is_none());
        assert!(resp.error.is_some());
    }

    #[test]
    fn effective_threads_resolves_auto() {
        let cfg = PortfolioConfig::default();
        let t = cfg.effective_threads();
        assert!((1..=4).contains(&t));
        let fixed = PortfolioConfig { threads: 7, ..Default::default() };
        assert_eq!(fixed.effective_threads(), 7);
    }
}
