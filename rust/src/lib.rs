//! MOCCASIN: Efficient Tensor Rematerialization for Neural Networks.
//!
//! Full-system reproduction of Bartan et al., ICML 2023. The library is a
//! three-layer stack:
//!
//! * **graph** — compute-graph DAG core: topological orders, sequence
//!   validity, and the paper's Appendix-A.3 peak-memory semantics.
//! * **generators** — the paper's evaluation graph families (random
//!   layered, CHECKMATE-style training graphs, real-world-like inference
//!   graphs).
//! * **cp** — a from-scratch constraint-programming engine (trailed
//!   domains, cumulative / reservoir / linear propagators, DFS branch &
//!   bound) used to solve the MOCCASIN retention-interval model.
//! * **presolve** — root presolve + model compaction: transitive
//!   reduction / reachability analysis, liveness-derived bounds
//!   tightening, dominance fixing and domain/cover compaction applied
//!   by every solve path before propagators are constructed (plus the
//!   logical row reduction used by the CHECKMATE MILP).
//! * **moccasin** — the paper's contribution: the retention-interval
//!   formulation (§2), staged domain reduction (§2.3), two-phase solve
//!   (§2.4), plus the anytime LNS loop used for large graphs.
//! * **checkmate** / **milp** — the CHECKMATE MILP baseline (Jain et al.,
//!   MLSys 2020) with an exact pseudo-Boolean branch & bound and the
//!   LP-relaxation + two-stage-rounding approximation (PDHG LP solver).
//! * **runtime** / **executor** — PJRT-based execution of AOT-compiled
//!   XLA artifacts under a rematerialization schedule with a tracked
//!   memory pool.
//! * **coordinator** — the solve service + CLI a downstream user calls:
//!   cached serial solves, the parallel portfolio race
//!   ([`coordinator::Backend::Portfolio`]), and the batched
//!   [`coordinator::Coordinator::solve_many`] used for parallel budget
//!   sweeps.
//! * **serve** — solver-as-a-service: an admission-controlled request
//!   queue in front of interruptible worker sessions, streaming anytime
//!   incumbents and shedding overload with structured answers (NDJSON
//!   over a Unix socket via `moccasin serve`).
//! * **bench** — harness regenerating every table and figure of the paper.
//!
//! See `README.md` for the quickstart and the paper-to-module map, and
//! `docs/BENCHMARKS.md` for the reproduction methodology.

#![deny(missing_docs)]
// Style lints the codebase deliberately diverges from (indexed loops
// over parallel arrays in the propagation engine, explicit min/max
// chains, fixed-size `&vec![..]` literals in tests). Correctness lints
// stay enabled — CI runs `cargo clippy --all-targets -- -D warnings`.
#![allow(
    clippy::needless_range_loop,
    clippy::manual_clamp,
    clippy::too_many_arguments,
    clippy::new_without_default,
    clippy::useless_vec,
    clippy::collapsible_if,
    clippy::collapsible_else_if,
    clippy::comparison_chain,
    clippy::type_complexity
)]

// Unit-test builds run under a counting allocator so allocation-
// regression tests (zero steady-state heap allocation across re-solves
// on a reused cp::SolveCtx) can assert exact deltas; every other build
// profile uses the system allocator untouched.
#[cfg(test)]
#[global_allocator]
static COUNTING_ALLOC: util::alloc_count::CountingAlloc = util::alloc_count::CountingAlloc;

pub mod analysis;
pub mod generators;
pub mod graph;
pub mod util;
pub mod cp;
pub mod presolve;
pub mod moccasin;
pub mod checkmate;
pub mod milp;
pub mod executor;
pub mod runtime;
pub mod bench;
pub mod coordinator;
pub mod serve;
