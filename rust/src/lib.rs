//! MOCCASIN: Efficient Tensor Rematerialization for Neural Networks.
//!
//! Full-system reproduction of Bartan et al., ICML 2023. The library is a
//! three-layer stack:
//!
//! * **graph** — compute-graph DAG core: topological orders, sequence
//!   validity, and the paper's Appendix-A.3 peak-memory semantics.
//! * **generators** — the paper's evaluation graph families (random
//!   layered, CHECKMATE-style training graphs, real-world-like inference
//!   graphs).
//! * **cp** — a from-scratch constraint-programming engine (trailed
//!   domains, cumulative / reservoir / linear propagators, DFS branch &
//!   bound) used to solve the MOCCASIN retention-interval model.
//! * **moccasin** — the paper's contribution: the retention-interval
//!   formulation (§2), staged domain reduction (§2.3), two-phase solve
//!   (§2.4), plus the anytime LNS loop used for large graphs.
//! * **checkmate** / **milp** — the CHECKMATE MILP baseline (Jain et al.,
//!   MLSys 2020) with an exact pseudo-Boolean branch & bound and the
//!   LP-relaxation + two-stage-rounding approximation (PDHG LP solver).
//! * **runtime** / **executor** — PJRT-based execution of AOT-compiled
//!   XLA artifacts under a rematerialization schedule with a tracked
//!   memory pool.
//! * **coordinator** — the solve service + CLI a downstream user calls:
//!   cached serial solves, the parallel portfolio race
//!   ([`coordinator::Backend::Portfolio`]), and the batched
//!   [`coordinator::Coordinator::solve_many`] used for parallel budget
//!   sweeps.
//! * **bench** — harness regenerating every table and figure of the paper.
//!
//! See `README.md` for the quickstart and the paper-to-module map, and
//! `docs/BENCHMARKS.md` for the reproduction methodology.

#![deny(missing_docs)]

pub mod generators;
pub mod graph;
pub mod util;
pub mod cp;
pub mod moccasin;
pub mod checkmate;
pub mod milp;
pub mod executor;
pub mod runtime;
pub mod bench;
pub mod coordinator;
