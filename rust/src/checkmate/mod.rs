//! CHECKMATE baseline (Jain et al., MLSys 2020).
//!
//! The comparison target of the paper: a Boolean MILP over *stages*.
//! With an input topological order `π`, stage `t` re-executes some
//! subset of nodes `π(1..t)` and ends by computing `π(t)`:
//!
//! * `R[t,k] ∈ {0,1}` — node `π(k)` is (re)computed in stage `t` (`k ≤ t`,
//!   `R[t,t] = 1`)
//! * `S[t,k]` — tensor `π(k)` is carried in memory into stage `t`
//! * `FREE[t,i,j]` — tensor `i` is deallocated in stage `t` right after
//!   consumer `j` executes (the O(nm) block that dominates the variable
//!   count)
//!
//! Constraints: dependency availability (`R[t,b] ≤ R[t,a] + S[t,a]` per
//! edge), carry/availability with deallocation, free-validity, and the
//! within-stage memory recurrence `U[t,k] ≤ M` expanded into linear
//! form. Objective: `Σ w·R`. This reproduces the formulation's
//! complexity signature — O(n²+nm) Booleans and constraints — which is
//! exactly what the paper contrasts against MOCCASIN's O(n) integers.
//!
//! Two solvers are provided, mirroring the paper's two CHECKMATE
//! columns:
//! * [`solve_milp`] — exact pseudo-Boolean branch & bound (in-tree CP
//!   engine), anytime under a deadline.
//! * [`solve_lp_rounding`] — LP relaxation via PDHG + the two-stage
//!   rounding heuristic (round `S`, complete `R` minimally); the result
//!   may violate the memory budget, as the paper reports.

use crate::cp::{Model, SearchStrategy, Solver, VarId};
use crate::graph::{Graph, NodeId};
use crate::milp::{pdhg_solve, Csr};
use crate::moccasin::RematSolution;
use crate::presolve::{reduce_rows, Presolve};
use crate::util::Deadline;

/// Why a CHECKMATE attempt produced no result.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CheckmateError {
    /// Model exceeds the build-size guard (the "out of memory" failure
    /// mode the paper reports for G3/G4).
    TooLarge { vars: usize, terms: usize },
    /// No solution found within the limits. Carries the CP kernel
    /// statistics of the attempt so the work done (possibly an
    /// exhaustive infeasibility proof) still reaches the aggregated
    /// counters.
    NoSolution {
        /// Kernel statistics of the failed branch & bound.
        stats: crate::cp::SearchStats,
    },
    /// A model-construction invariant failed (e.g. a free-column lookup
    /// missed during build). Continuing would emit an unsound model, so
    /// the attempt is abandoned with this structured error instead.
    Internal(&'static str),
}

impl std::fmt::Display for CheckmateError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CheckmateError::TooLarge { vars, terms } => {
                write!(f, "model too large: {vars} vars, {terms} constraint terms")
            }
            CheckmateError::NoSolution { .. } => write!(f, "no solution within limits"),
            CheckmateError::Internal(what) => {
                write!(f, "internal model-construction error: {what}")
            }
        }
    }
}

/// Linear-row representation shared by the CP and LP backends.
struct Rows {
    /// Σ c·x ≤ rhs
    rows: Vec<(Vec<(i64, u32)>, i64)>,
    nvars: usize,
    terms: usize,
}

/// Variable layout for the CHECKMATE formulation.
pub struct Layout {
    n: usize,
    /// order[k-1] = node at topo position k (1-based positions)
    order: Vec<NodeId>,
    topo_index: Vec<usize>,
    /// r_base[t-1] + (k-1) = column of R[t,k], k ≤ t
    r_base: Vec<usize>,
    /// s_base[t-1] + (k-1) = column of S[t,k], k < t (t ≥ 2)
    s_base: Vec<usize>,
    /// free vars: (t, edge_idx) → column
    free_cols: std::collections::HashMap<(usize, usize), usize>,
    /// edges as (topo pos of producer, topo pos of consumer, mem of producer)
    edges_pos: Vec<(usize, usize, u64)>,
    nvars: usize,
}

impl Layout {
    fn r(&self, t: usize, k: usize) -> u32 {
        debug_assert!(k >= 1 && k <= t && t <= self.n);
        (self.r_base[t - 1] + (k - 1)) as u32
    }
    fn s(&self, t: usize, k: usize) -> u32 {
        debug_assert!(k >= 1 && k < t && t <= self.n);
        (self.s_base[t - 1] + (k - 1)) as u32
    }
    fn free(&self, t: usize, e: usize) -> Option<u32> {
        self.free_cols.get(&(t, e)).map(|&c| c as u32)
    }

    /// Formulation size counts for Table 1: (#Boolean vars, #constraints).
    pub fn complexity(&self, rows: usize) -> (usize, usize) {
        (self.nvars, rows)
    }
}

/// Build the variable layout + all constraint rows.
fn build(
    graph: &Graph,
    order: &[NodeId],
    budget: u64,
    max_vars: usize,
    max_terms: usize,
) -> Result<(Layout, Rows), CheckmateError> {
    let n = graph.n();
    let mut topo_index = vec![0usize; n];
    for (i, &v) in order.iter().enumerate() {
        topo_index[v as usize] = i + 1;
    }
    let edges_pos: Vec<(usize, usize, u64)> = graph
        .edges()
        .map(|(u, v)| (topo_index[u as usize], topo_index[v as usize], graph.mem[u as usize]))
        .collect();

    // var layout
    let mut nvars = 0usize;
    let mut r_base = Vec::with_capacity(n);
    for t in 1..=n {
        r_base.push(nvars);
        nvars += t;
    }
    let mut s_base = Vec::with_capacity(n);
    for t in 1..=n {
        s_base.push(nvars);
        nvars += t.saturating_sub(1);
    }
    let mut free_cols = std::collections::HashMap::new();
    for (e, &(_pa, pb, _)) in edges_pos.iter().enumerate() {
        for t in pb..=n {
            free_cols.insert((t, e), nvars);
            nvars += 1;
        }
    }
    if nvars > max_vars {
        return Err(CheckmateError::TooLarge { vars: nvars, terms: 0 });
    }
    let layout = Layout {
        n,
        order: order.to_vec(),
        topo_index,
        r_base,
        s_base,
        free_cols,
        edges_pos: edges_pos.clone(),
        nvars,
    };

    let mut rows: Vec<(Vec<(i64, u32)>, i64)> = Vec::new();
    let mut terms = 0usize;
    let mut push = |row: Vec<(i64, u32)>, rhs: i64, terms: &mut usize| {
        *terms += row.len();
        rows.push((row, rhs));
    };

    // R[t,t] = 1 → -R[t,t] ≤ -1
    for t in 1..=n {
        push(vec![(-1, layout.r(t, t))], -1, &mut terms);
    }
    // consumers of each producer position, per edge index
    // dependencies: per edge (a→b), per stage t ≥ pos(b):
    //   R[t,b] - R[t,a] - S[t,a] ≤ 0
    for &(pa, pb, _) in &edges_pos {
        for t in pb..=n {
            let mut row = vec![(1, layout.r(t, pb)), (-1, layout.r(t, pa))];
            if pa < t {
                row.push((-1, layout.s(t, pa)));
            }
            push(row, 0, &mut terms);
        }
    }
    // carry with deallocation: for t ≥ 1, tensor position k ≤ t:
    //   S[t+1,k] + Σ_{e: producer k, consumer in stage t} FREE[t,e]
    //     - R[t,k] - S[t,k] ≤ 0
    for t in 1..n {
        for k in 1..=t {
            let mut row = vec![(1, layout.s(t + 1, k)), (-1, layout.r(t, k))];
            if k < t {
                row.push((-1, layout.s(t, k)));
            }
            for (e, &(pa, _pb, _)) in edges_pos.iter().enumerate() {
                if pa == k {
                    if let Some(f) = layout.free(t, e) {
                        row.push((1, f));
                    }
                }
            }
            push(row, 0, &mut terms);
        }
    }
    // free validity: FREE[t,e] ≤ R[t, pos(consumer)], and no free before a
    // later consumer in the same stage: FREE[t,e] + R[t,pb'] ≤ 1 for
    // consumers pb' > pb of the same producer
    for (e, &(pa, pb, _)) in edges_pos.iter().enumerate() {
        for t in pb..=n {
            let Some(f) = layout.free(t, e) else {
                return Err(CheckmateError::Internal("free-column lookup missed in build"));
            };
            push(vec![(1, f), (-1, layout.r(t, pb))], 0, &mut terms);
            for (e2, &(pa2, pb2, _)) in edges_pos.iter().enumerate() {
                if e2 != e && pa2 == pa && pb2 > pb && pb2 <= t {
                    push(vec![(1, f), (1, layout.r(t, pb2))], 1, &mut terms);
                }
            }
        }
        if terms > max_terms {
            return Err(CheckmateError::TooLarge { vars: nvars, terms });
        }
    }
    // at most one free per tensor per stage, and only if present:
    //   Σ_e FREE[t,e] - R[t,k] - S[t,k] ≤ 0
    for t in 1..=n {
        for k in 1..=t {
            let mut row: Vec<(i64, u32)> = Vec::new();
            for (e, &(pa, _, _)) in edges_pos.iter().enumerate() {
                if pa == k {
                    if let Some(f) = layout.free(t, e) {
                        row.push((1, f));
                    }
                }
            }
            if row.is_empty() {
                continue;
            }
            row.push((-1, layout.r(t, k)));
            if k < t {
                row.push((-1, layout.s(t, k)));
            }
            push(row, 0, &mut terms);
        }
    }
    // memory recurrence: for each stage t, checkpoint after computing the
    // j-th scheduled slot k ≤ t:
    //   Σ_{i<t} m_i S[t,i] + Σ_{k'≤k} m_{k'} R[t,k']
    //     - Σ_{k'≤k} Σ_{e=(i → π(k'))} m_i FREE[t,e] ≤ M
    for t in 1..=n {
        // prefix rows reuse the previous row's terms
        let mut row: Vec<(i64, u32)> = Vec::new();
        for i in 1..t {
            row.push((graph.mem[order[i - 1] as usize] as i64, layout.s(t, i)));
        }
        for k in 1..=t {
            row.push((graph.mem[order[k - 1] as usize] as i64, layout.r(t, k)));
            // U[t,k] is the footprint *while* slot k computes: tensors
            // freed after slot k's own evaluation only relieve later
            // slots (Appendix A.3: "you cannot deallocate a node's
            // output until the next computation is complete"), so the
            // FREE terms of slot k are appended after this row is
            // emitted.
            push(row.clone(), budget as i64, &mut terms);
            for (e, &(pa, pb, pm)) in edges_pos.iter().enumerate() {
                let _ = pa;
                if pb == k {
                    if let Some(f) = layout.free(t, e) {
                        row.push((-(pm as i64), f));
                    }
                }
            }
            if terms > max_terms {
                return Err(CheckmateError::TooLarge { vars: nvars, terms });
            }
        }
    }

    let nrows = rows.len();
    let _ = nrows;
    Ok((layout, Rows { rows, nvars, terms }))
}

/// Extract the executable sequence from an R assignment.
fn sequence_from_r(layout: &Layout, r_val: impl Fn(usize, usize) -> bool) -> Vec<NodeId> {
    let mut seq = Vec::new();
    for t in 1..=layout.n {
        for k in 1..=t {
            if r_val(t, k) {
                seq.push(layout.order[k - 1]);
            }
        }
    }
    seq
}

/// Result of a CHECKMATE solve attempt.
pub struct CheckmateResult {
    /// Best validated schedule found.
    pub solution: RematSolution,
    /// Whether the branch & bound exhausted the space (under any shared
    /// incumbent pruning bound).
    pub proved_optimal: bool,
    /// CP kernel statistics (zero for the LP-rounding path, which never
    /// enters the branch & bound).
    pub stats: crate::cp::SearchStats,
}

/// Exact MILP via pseudo-Boolean branch & bound. `on_solution` receives
/// every improving (validated) solution for anytime traces.
///
/// The constraint matrix passes through the logical presolve
/// ([`reduce_rows`]) unless `pre` is disabled: the `R[t,t] = 1`
/// diagonal rows become root fixings, substitution then erases or
/// shrinks the dependency/free/memory rows they appear in, and further
/// forced fixings cascade to a fixpoint. Everything there is exact for
/// 0–1 programs, so optimality/infeasibility proofs survive; when the
/// reduction itself proves infeasibility, no search runs at all.
pub fn solve_milp(
    graph: &Graph,
    order: &[NodeId],
    budget: u64,
    deadline: Deadline,
    pre: &Presolve,
    search: SearchStrategy,
    mut on_solution: impl FnMut(&RematSolution),
) -> Result<CheckmateResult, CheckmateError> {
    // failpoint: a spurious timeout or error surfaces as `NoSolution`
    // (the natural "MILP gave nothing" path callers already handle); a
    // panic unwinds to the portfolio member's `catch_unwind`
    crate::fail_point!(
        "checkmate.milp",
        Err(CheckmateError::NoSolution { stats: crate::cp::SearchStats::default() })
    );
    let (layout, mut rows) = build(graph, order, budget, 400_000, 12_000_000)?;
    let mut pre_stats = crate::presolve::PresolveStats::default();
    let mut fixed: Vec<Option<i64>> = Vec::new();
    if pre.enabled() {
        pre_stats.props_before = rows.rows.len() as u64;
        pre_stats.domain_before = 2 * rows.nvars as u64;
        let red = reduce_rows(rows.nvars, &mut rows.rows);
        pre_stats.props_after = red.rows_after;
        pre_stats.vars_fixed = red.vars_fixed;
        pre_stats.domain_after = 2 * rows.nvars as u64 - red.vars_fixed;
        if red.infeasible {
            let mut stats = crate::cp::SearchStats::default();
            stats.presolve.add(&pre_stats);
            return Err(CheckmateError::NoSolution { stats });
        }
        fixed = red.fixed;
    }
    let mut model = Model::new();
    let vars: Vec<VarId> = (0..rows.nvars).map(|_| model.new_bool()).collect();
    for (v, f) in fixed.iter().enumerate() {
        if let Some(val) = f {
            model.fix(vars[v], *val);
        }
    }
    for (row, rhs) in &rows.rows {
        model.linear_le(row.iter().map(|&(c, v)| (c, vars[v as usize])).collect(), *rhs);
    }
    // objective: Σ w R
    let mut objective: Vec<(i64, VarId)> = Vec::new();
    for t in 1..=layout.n {
        for k in 1..=t {
            objective.push((
                graph.duration[layout.order[k - 1] as usize] as i64,
                vars[layout.r(t, k) as usize],
            ));
        }
    }
    // branch order: stage by stage, S then R; FREE last (propagation
    // forces them when memory binds)
    let mut bo: Vec<VarId> = Vec::new();
    for t in 1..=layout.n {
        for k in 1..t {
            bo.push(vars[layout.s(t, k) as usize]);
        }
        for k in 1..=t {
            bo.push(vars[layout.r(t, k) as usize]);
        }
    }
    for (&_key, &col) in layout.free_cols.iter() {
        bo.push(vars[col]);
    }

    // Publish validated improvements to the shared portfolio incumbent
    // (when one rides along on the deadline) so racing solvers prune;
    // as a full model this B&B may in turn prune against the global
    // best. Deadline-gap audit (PR 7): beyond the search loop's
    // iteration-cadence polls, the engine checks cancellation and the
    // hard stop inside every propagation fixpoint
    // (`PropagationEngine::watchdog_tick`), so a MILP wedged in one
    // pass over its large constraint rows is still cancellable.
    let incumbent = deadline.incumbent().cloned();
    let solver =
        Solver { deadline, bound: incumbent.clone(), strategy: search, ..Default::default() };
    let mut best: Option<RematSolution> = None;
    let r = solver.solve(&model, &objective, &bo, |a, _| {
        let seq = sequence_from_r(&layout, |t, k| a[vars[layout.r(t, k) as usize].0 as usize] == 1);
        if let Ok(sol) = RematSolution::from_seq(graph, seq) {
            let better = sol.feasible(budget)
                && best.as_ref().map(|b| sol.eval.duration < b.eval.duration).unwrap_or(true);
            if better {
                if let Some(inc) = &incumbent {
                    inc.record(sol.eval.duration);
                }
                on_solution(&sol);
                best = Some(sol);
            }
        }
    });
    let mut stats = r.stats;
    stats.presolve.add(&pre_stats);
    match best {
        Some(solution) => Ok(CheckmateResult {
            solution,
            proved_optimal: r.status == crate::cp::Status::Optimal,
            stats,
        }),
        None => Err(CheckmateError::NoSolution { stats }),
    }
}

/// LP relaxation + two-stage rounding (the paper's "CHECKMATE
/// LP+Rounding" column). The returned solution may exceed the budget —
/// callers must check `solution.eval.peak_mem` (Table 2 reports these
/// violations).
pub fn solve_lp_rounding(
    graph: &Graph,
    order: &[NodeId],
    budget: u64,
    max_iters: usize,
) -> Result<CheckmateResult, CheckmateError> {
    let (layout, rows) = build(graph, order, budget, 400_000, 12_000_000)?;
    // LP: min cᵀx s.t. rows, 0 ≤ x ≤ 1
    let mut c = vec![0.0f64; rows.nvars];
    for t in 1..=layout.n {
        for k in 1..=t {
            c[layout.r(t, k) as usize] =
                graph.duration[layout.order[k - 1] as usize] as f64;
        }
    }
    // normalize rows for PDHG conditioning (scale each row by max |coef|)
    let csr_rows: Vec<Vec<(u32, f64)>> = rows
        .rows
        .iter()
        .map(|(row, _)| {
            let scale = row.iter().map(|&(cf, _)| cf.abs() as f64).fold(1.0, f64::max);
            row.iter().map(|&(cf, v)| (v, cf as f64 / scale)).collect()
        })
        .collect();
    let b: Vec<f64> = rows
        .rows
        .iter()
        .map(|(row, rhs)| {
            let scale = row.iter().map(|&(cf, _)| cf.abs() as f64).fold(1.0, f64::max);
            *rhs as f64 / scale
        })
        .collect();
    let a = Csr::from_rows(rows.nvars, &csr_rows);
    let lp = pdhg_solve(&c, &a, &b, max_iters, 1e-4);

    // Stage 1: round S at 0.5, repaired forward for availability.
    let n = layout.n;
    let mut s01 = vec![vec![false; n + 1]; n + 1]; // s01[t][k]
    let mut r01 = vec![vec![false; n + 1]; n + 1];
    for t in 1..=n {
        r01[t][t] = true;
        for k in 1..t {
            let carried = lp.x[layout.s(t, k) as usize] >= 0.5;
            let avail_prev = r01[t - 1][k] || s01[t - 1][k];
            s01[t][k] = carried && avail_prev;
        }
        // Stage 2: minimal R completion — need π(t); recompute anything
        // needed and not carried (within-stage, topo desc).
        let mut need = vec![false; n + 1];
        need[t] = true;
        for k in (1..=t).rev() {
            if !need[k] {
                continue;
            }
            if k < t && s01[t][k] {
                continue; // satisfied from carry
            }
            r01[t][k] = true;
            // its preds become needed
            let node = layout.order[k - 1];
            for &u in &graph.preds[node as usize] {
                need[layout.topo_index[u as usize]] = true;
            }
        }
    }
    let seq = sequence_from_r(&layout, |t, k| r01[t][k]);
    let solution = RematSolution::from_seq(graph, seq)
        .map_err(|_| CheckmateError::NoSolution { stats: crate::cp::SearchStats::default() })?;
    Ok(CheckmateResult {
        solution,
        proved_optimal: false,
        stats: crate::cp::SearchStats::default(),
    })
}

/// Formulation sizes for Table 1 (Boolean vars, constraints) — built
/// without the size guard.
pub fn formulation_size(graph: &Graph, order: &[NodeId], budget: u64) -> (usize, usize) {
    match build(graph, order, budget, usize::MAX, usize::MAX) {
        Ok((_, rows)) => (rows.nvars, rows.rows.len()),
        Err(_) => (0, 0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::topological_order;
    use std::time::Duration;

    fn chain_graph() -> Graph {
        // see moccasin::greedy tests: no-remat peak 13, floor 10
        Graph::from_edges(
            "c",
            5,
            &[(0, 1), (1, 2), (2, 3), (3, 4), (0, 4)],
            vec![1, 1, 1, 1, 1],
            vec![5, 4, 4, 4, 1],
        )
        .unwrap()
    }

    #[test]
    fn milp_loose_budget_no_remat() {
        let g = chain_graph();
        let order = topological_order(&g).unwrap();
        let r = solve_milp(
            &g,
            &order,
            100,
            Deadline::after(Duration::from_secs(20)),
            &Presolve::new(&g, Default::default()),
            SearchStrategy::default(),
            |_| {},
        )
        .unwrap();
        assert_eq!(r.solution.eval.duration, 5);
        assert!(r.proved_optimal);
    }

    #[test]
    fn milp_tight_budget_matches_moccasin_optimum() {
        let g = chain_graph();
        let order = topological_order(&g).unwrap();
        let r = solve_milp(
            &g,
            &order,
            10,
            Deadline::after(Duration::from_secs(30)),
            &Presolve::new(&g, Default::default()),
            SearchStrategy::default(),
            |_| {},
        )
        .unwrap();
        // optimum: one remat of node 0 → duration 6 (equivalence of
        // solutions, paper §1.2 "demonstrate equivalence")
        assert_eq!(r.solution.eval.duration, 6);
        assert!(r.solution.eval.peak_mem <= 10);
    }

    #[test]
    fn milp_detects_infeasible() {
        let g = chain_graph();
        let order = topological_order(&g).unwrap();
        let r = solve_milp(
            &g,
            &order,
            9,
            Deadline::after(Duration::from_secs(10)),
            &Presolve::new(&g, Default::default()),
            SearchStrategy::default(),
            |_| {},
        );
        match r {
            Err(CheckmateError::NoSolution { stats }) => {
                assert!(stats.propagations > 0, "failed attempt must report kernel work");
            }
            other => panic!("expected NoSolution, got {:?}", other.map(|x| x.proved_optimal)),
        }
    }

    #[test]
    fn milp_presolve_reduces_rows_with_identical_optimum() {
        let g = chain_graph();
        let order = topological_order(&g).unwrap();
        let on = solve_milp(
            &g,
            &order,
            10,
            Deadline::after(Duration::from_secs(30)),
            &Presolve::new(&g, Default::default()),
            SearchStrategy::default(),
            |_| {},
        )
        .unwrap();
        let off = solve_milp(
            &g,
            &order,
            10,
            Deadline::after(Duration::from_secs(30)),
            &Presolve::off(),
            SearchStrategy::default(),
            |_| {},
        )
        .unwrap();
        assert_eq!(on.solution.eval.duration, off.solution.eval.duration);
        assert!(on.proved_optimal && off.proved_optimal);
        assert!(
            on.stats.presolve.props_after < on.stats.presolve.props_before,
            "row reduction must drop rows ({} -> {})",
            on.stats.presolve.props_before,
            on.stats.presolve.props_after
        );
        assert!(
            on.stats.presolve.vars_fixed >= g.n() as u64,
            "at least the R[t,t] diagonal must be fixed"
        );
        assert_eq!(off.stats.presolve.props_before, 0);
    }

    #[test]
    fn size_guard_trips_on_large_graphs() {
        let g = crate::generators::random_layered("t", 400, 1800, 1);
        let order = topological_order(&g).unwrap();
        let r = build(&g, &order, 1000, 50_000, 1_000_000);
        assert!(matches!(r, Err(CheckmateError::TooLarge { .. })));
    }

    #[test]
    fn lp_rounding_produces_valid_sequence() {
        let g = chain_graph();
        let order = topological_order(&g).unwrap();
        let r = solve_lp_rounding(&g, &order, 10, 4000).unwrap();
        // valid sequence (eval succeeded) — budget may be violated, as
        // the paper reports for this method
        assert!(r.solution.eval.duration >= 5);
    }

    #[test]
    fn formulation_size_is_quadratic() {
        let g = chain_graph();
        let order = topological_order(&g).unwrap();
        let (v5, _c5) = formulation_size(&g, &order, 10);
        let g2 = crate::generators::random_layered("t", 40, 90, 2);
        let order2 = topological_order(&g2).unwrap();
        let (v40, _c40) = formulation_size(&g2, &order2, 10_000);
        // 8x nodes → much more than 8x vars (quadratic growth)
        assert!(v40 > v5 * 16, "v5={v5} v40={v40}");
    }
}
