//! Sequence evaluation under the paper's Appendix-A.3 memory semantics.
//!
//! Given a rematerialization sequence `seq` (a list of nodes where
//! repetition is allowed), the memory footprint at step `i` is
//!
//! ```text
//! M_i = m_{seq[i]} + Σ_{v ∈ ors_{i-1}} m_v              (A.3, eq. 17)
//! ```
//!
//! where `ors` is the *output retention set*: the outputs that have been
//! computed but still have a pending "rematerialization successor" — a
//! consumer occurrence whose last preceding instance of the producer is
//! the one currently in memory (eq. 15–16). Operationally: the output
//! produced by the instance of `v` at position `p` must be retained until
//! the last consumer occurrence `q > p` of a successor `z` of `v` such
//! that `v` is not recomputed in `(p, q)`. This is the minimal-retention
//! rule ("retain the output only of the last occurring predecessor"),
//! which yields the lowest possible footprint for a given sequence.
//!
//! The implementation is O(L + Σ_p deg(seq[p])) for a sequence of length
//! L: one backward-free pass assigns every consumer occurrence to the
//! producer instance it reads from, giving each instance a release
//! position; a difference array then accumulates the memory profile.
//! This routine is the hot inner loop of the LNS solver, so it is
//! allocation-conscious: see [`Evaluator`] for the reusable-buffer form.

use super::{is_topological_with_remat, Graph, NodeId};

/// Result of evaluating a rematerialization sequence.
#[derive(Debug, Clone, PartialEq)]
pub struct SeqEval {
    /// Total execution duration: `Σ_p w_{seq[p]}`.
    pub duration: u64,
    /// Peak memory footprint `max_i M_i`.
    pub peak_mem: u64,
    /// Position (step index) at which the peak occurs (first occurrence).
    pub peak_pos: usize,
    /// Number of positions whose footprint equals the peak (plateau
    /// width — used by the Phase-1 planner's progress measure).
    pub peak_count: usize,
    /// Total duration increase relative to computing every node exactly
    /// once, in percent: `100 * (duration - Σ w_v) / Σ w_v`.
    pub tdi_percent: f64,
    /// Number of rematerializations (occurrences beyond the first).
    pub remat_count: usize,
}

/// Why a sequence is not a valid rematerialization sequence.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SeqError {
    /// Node at `pos` executed before one of its predecessors was ever
    /// computed.
    DependencyViolation { pos: usize, node: NodeId, missing_pred: NodeId },
    /// A node of the graph never appears in the sequence.
    MissingNode(NodeId),
    /// Sequence references a node id `>= n`.
    OutOfRange { pos: usize, node: NodeId },
    /// Sequence is empty but the graph is not.
    Empty,
}

impl std::fmt::Display for SeqError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SeqError::DependencyViolation { pos, node, missing_pred } => write!(
                f,
                "position {pos}: node {node} executed before predecessor {missing_pred}"
            ),
            SeqError::MissingNode(v) => write!(f, "node {v} never computed"),
            SeqError::OutOfRange { pos, node } => {
                write!(f, "position {pos}: node id {node} out of range")
            }
            SeqError::Empty => write!(f, "empty sequence"),
        }
    }
}

impl std::error::Error for SeqError {}

/// Evaluate a sequence. Convenience wrapper over [`Evaluator`] — prefer
/// the evaluator in hot loops to reuse buffers.
pub fn eval_sequence(g: &Graph, seq: &[NodeId]) -> Result<SeqEval, SeqError> {
    Evaluator::new(g).eval(seq)
}

/// Reusable-buffer sequence evaluator (the solver hot path).
pub struct Evaluator<'g> {
    g: &'g Graph,
    /// last occurrence position of each node during the scan (usize::MAX
    /// = not yet computed)
    last_occ: Vec<usize>,
    /// release position of each instance (indexed by sequence position)
    release: Vec<usize>,
    /// memory delta at each position boundary
    delta: Vec<i64>,
}

impl<'g> Evaluator<'g> {
    /// Evaluator for `g` with reusable scratch buffers.
    pub fn new(g: &'g Graph) -> Self {
        Evaluator {
            g,
            last_occ: vec![usize::MAX; g.n()],
            release: Vec::new(),
            delta: Vec::new(),
        }
    }

    /// Evaluate `seq`, validating dependencies and node coverage.
    pub fn eval(&mut self, seq: &[NodeId]) -> Result<SeqEval, SeqError> {
        let g = self.g;
        let n = g.n();
        let len = seq.len();
        if len == 0 {
            return if n == 0 {
                Ok(SeqEval {
                    duration: 0,
                    peak_mem: 0,
                    peak_pos: 0,
                    peak_count: 0,
                    tdi_percent: 0.0,
                    remat_count: 0,
                })
            } else {
                Err(SeqError::Empty)
            };
        }

        self.last_occ.clear();
        self.last_occ.resize(n, usize::MAX);
        self.release.clear();
        // release[p] = last position whose execution reads the output
        // produced at p; p itself if never consumed.
        self.release.resize(len, 0);
        self.delta.clear();
        self.delta.resize(len + 1, 0);

        let mut duration: u64 = 0;
        let mut seen_count = 0usize;

        // Forward scan: assign each consumer occurrence to the *latest*
        // instance of each predecessor (that is `last(v, z, seq)` of
        // eq. 16), extending that instance's release position.
        for (q, &z) in seq.iter().enumerate() {
            let zi = z as usize;
            if zi >= n {
                return Err(SeqError::OutOfRange { pos: q, node: z });
            }
            for &v in &g.preds[zi] {
                let p = self.last_occ[v as usize];
                if p == usize::MAX {
                    return Err(SeqError::DependencyViolation {
                        pos: q,
                        node: z,
                        missing_pred: v,
                    });
                }
                // output of instance p is read while executing position q
                if self.release[p] < q {
                    self.release[p] = q;
                }
            }
            if self.last_occ[zi] == usize::MAX {
                seen_count += 1;
            }
            self.last_occ[zi] = q;
            self.release[q] = q; // alive at least during its own compute
            duration += g.duration[zi];
        }
        if seen_count != n {
            let missing = (0..n).find(|&v| self.last_occ[v] == usize::MAX).unwrap();
            return Err(SeqError::MissingNode(missing as NodeId));
        }

        // Memory profile via difference array: instance at p occupies
        // m_{seq[p]} over positions [p, release[p]].
        for p in 0..len {
            let m = g.mem[seq[p] as usize] as i64;
            self.delta[p] += m;
            self.delta[self.release[p] + 1] -= m;
        }
        let mut cur: i64 = 0;
        let mut peak: i64 = 0;
        let mut peak_pos = 0usize;
        let mut peak_count = 0usize;
        for i in 0..len {
            cur += self.delta[i];
            if cur > peak {
                peak = cur;
                peak_pos = i;
                peak_count = 1;
            } else if cur == peak {
                peak_count += 1;
            }
        }
        debug_assert!(cur + self.delta[len] == 0 || len == 0);

        let base = g.total_duration();
        let tdi = if base == 0 {
            0.0
        } else {
            100.0 * (duration as f64 - base as f64) / base as f64
        };
        Ok(SeqEval {
            duration,
            peak_mem: peak as u64,
            peak_pos,
            peak_count,
            tdi_percent: tdi,
            remat_count: len - n,
        })
    }

    /// Fast validity check without the memory profile.
    pub fn is_valid(&self, seq: &[NodeId]) -> bool {
        is_topological_with_remat(self.g, seq)
    }

    /// Evaluate and additionally return the per-position memory profile
    /// `M_i` (used by the Phase-1 planner to target overflow regions).
    pub fn eval_profile(&mut self, seq: &[NodeId]) -> Result<(SeqEval, Vec<u64>), SeqError> {
        let ev = self.eval(seq)?;
        let mut profile = Vec::with_capacity(seq.len());
        let mut cur: i64 = 0;
        for i in 0..seq.len() {
            cur += self.delta[i];
            profile.push(cur as u64);
        }
        Ok((ev, profile))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond(mems: [u64; 4]) -> Graph {
        Graph::from_edges(
            "d",
            4,
            &[(0, 1), (0, 2), (1, 3), (2, 3)],
            vec![1, 2, 3, 4],
            mems.to_vec(),
        )
        .unwrap()
    }

    #[test]
    fn no_remat_diamond_unit_mem() {
        let g = diamond([1, 1, 1, 1]);
        let e = eval_sequence(&g, &[0, 1, 2, 3]).unwrap();
        assert_eq!(e.duration, 10);
        assert_eq!(e.tdi_percent, 0.0);
        assert_eq!(e.remat_count, 0);
        // step 0: {0}=1; step 1: {0,1}=2; step 2: {0,1,2}=3 (0 freed after
        // 2 computes? no: 0's release = position of 2 = step 2, so 0 is
        // live at step 2); step 3: {1,2,3}=3.
        assert_eq!(e.peak_mem, 3);
    }

    #[test]
    fn remat_reduces_peak() {
        // chain with big intermediate: 0 -> 1, 0 -> 3; 1 -> 2; 2 -> 3
        // keeping 0 alive across 1,2 costs; remat 0 before 3 instead.
        let g = Graph::from_edges(
            "c",
            4,
            &[(0, 1), (0, 3), (1, 2), (2, 3)],
            vec![1, 1, 1, 1],
            vec![10, 1, 1, 1],
        )
        .unwrap();
        let no_remat = eval_sequence(&g, &[0, 1, 2, 3]).unwrap();
        // 0 live through step 3 => at step 3: m0 + m2 + m3 = 12
        assert_eq!(no_remat.peak_mem, 12);
        let remat = eval_sequence(&g, &[0, 1, 2, 0, 3]).unwrap();
        // instance of 0 at p=0 consumed last by 1 (q=1) => freed after 1.
        // step 2: {1? no: 1's last consumer is 2 at q=2.. profile:
        // p0:0 lives [0,1] (consumed by 1 at q=1; 3 reads the p=3 instance)
        // p1:1 lives [1,2]; p2:2 lives [2,4]; p3:0 lives [3,4]; p4:3.
        // peaks: step0:10, step1:11, step2:2, step3:11, step4:12
        assert_eq!(remat.peak_mem, 12); // m0+m2+m3 at final step
        assert_eq!(remat.remat_count, 1);
        assert_eq!(remat.duration, 5);
        assert!((remat.tdi_percent - 25.0).abs() < 1e-9);
    }

    #[test]
    fn remat_frees_early_instance() {
        // 0 -> 1 -> 2, 0 -> 2 with huge m1: no way around, but check that
        // rematting 0 frees the early instance.
        let g = Graph::from_edges(
            "c2",
            3,
            &[(0, 1), (0, 2), (1, 2)],
            vec![1, 1, 1],
            vec![5, 1, 1],
        )
        .unwrap();
        let e = eval_sequence(&g, &[0, 1, 0, 2]).unwrap();
        // p0: 0 lives [0,1]; p1: 1 lives [1,3]; p2: 0 lives [2,3]; p3: 2.
        // profile: 5, 6, 6, 7
        assert_eq!(e.peak_mem, 7);
        let e2 = eval_sequence(&g, &[0, 1, 2]).unwrap();
        // 0 lives [0,2], 1 lives [1,2], 2 at 2 → 5,6,7
        assert_eq!(e2.peak_mem, 7);
    }

    #[test]
    fn sink_output_counted_at_compute() {
        let g = Graph::from_edges("s", 1, &[], vec![3], vec![9]).unwrap();
        let e = eval_sequence(&g, &[0]).unwrap();
        assert_eq!(e.peak_mem, 9);
        assert_eq!(e.duration, 3);
    }

    #[test]
    fn errors() {
        let g = diamond([1; 4]);
        assert!(matches!(
            eval_sequence(&g, &[1, 0, 2, 3]),
            Err(SeqError::DependencyViolation { pos: 0, node: 1, missing_pred: 0 })
        ));
        assert!(matches!(eval_sequence(&g, &[0, 1, 2]), Err(SeqError::MissingNode(3))));
        assert!(matches!(
            eval_sequence(&g, &[0, 7]),
            Err(SeqError::OutOfRange { pos: 1, node: 7 })
        ));
        assert!(matches!(eval_sequence(&g, &[]), Err(SeqError::Empty)));
    }

    #[test]
    fn paper_fig3_example() {
        // Figure 2 graph: 1→2, 1→3, 2→4, 3→4 (0-indexed 0→1,0→2,1→3,2→3),
        // unit sizes. Figure 3's solution recomputes node 1 (our 0):
        // seq = [0, 1, 2, 0, 3]? Fig 3: node1 ev1, node2 ev3, node3 ev5,
        // node1 again ev7, node4 ev10 — i.e. 1,2,3,1,4. Peak memory 3 at
        // event 10 (m2-out? outputs of 3 and recomputed 1 plus 4).
        let g = Graph::from_edges(
            "fig2",
            4,
            &[(0, 1), (0, 2), (1, 3), (2, 3)],
            vec![1; 4],
            vec![1; 4],
        )
        .unwrap();
        let e = eval_sequence(&g, &[0, 1, 2, 0, 3]).unwrap();
        // p0:0→[0,1], p1:1→[1,4], p2:2→[2,4], p3:0→[3,3](consumed by.. 2?
        //   succ of 0 = {1,2}; after p3 no occurrence of 1 or 2 reads it →
        //   release = p3 itself). Wait: 2 at p2 already computed; its
        //   *preds* read at p2 come from instance p0... so p3's output is
        //   never read — release [3,3].
        // Hmm — in the paper's Fig 3 the recompute of node 1 at event 7
        // feeds node 4? No: node 4's preds are 2 and 3. The recompute in
        // Fig 3 retains through event 10 by *solver choice*; minimal
        // retention gives a smaller profile. Here:
        // profile: step0:1, step1:2, step2:3(p0,p1,p2? p0 released at 2 —
        //   p0's consumers: 1 at q1, 2 at q2 → release 2 → live [0,2]),
        //   recount: p0:[0,2], p1:[1,4], p2:[2,4], p3:[3,3], p4:[4,4]
        // steps: 1, 2, 3, 3, 3 → peak 3 (matches paper's peak of 3).
        assert_eq!(e.peak_mem, 3);
        assert_eq!(e.duration, 5);
    }
}
