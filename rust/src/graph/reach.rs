//! Reachability and transitive reduction of the precedence DAG.
//!
//! The presolve layer needs two order-independent structural facts about
//! a compute graph: which edges are *transitively redundant* (a path of
//! other edges already implies the precedence), and how many
//! ancestors/descendants each node has (liveness-derived bounds for the
//! unstaged model). Both are computed from dense reachability bitsets in
//! `O(m · n / 64)` time and `O(n² / 64)` memory — cheap up to a few
//! thousand nodes, which covers every instance in the paper's grid.
//!
//! Note on semantics: a transitively redundant edge `(u, v)` is still a
//! *real data dependency* under the Appendix-A.3 memory model — `v`
//! reads `u`'s tensor, so `u` must be resident at `v`'s compute event
//! even when another path `u → … → v` exists. Dropping its Cover
//! constraint therefore *relaxes* the CP model (see
//! `presolve::PresolveLevel::Aggressive`); the redundancy flags computed
//! here are facts about the DAG, not a license to delete constraints.

use super::{Graph, NodeId};

/// Dense reachability bitsets: `bit(v, w)` = there is a directed path of
/// length ≥ 1 from `v` to `w`.
pub struct Reachability {
    n: usize,
    words: usize,
    bits: Vec<u64>,
}

impl Reachability {
    /// Descendant bitsets of `g`: `can_reach(v, w)` answers "is `w`
    /// reachable from `v` via ≥ 1 edge".
    pub fn descendants(g: &Graph) -> Reachability {
        Self::build(g.n(), |v| &g.succs[v], &topo_order_indices(g))
    }

    /// Ancestor bitsets of `g`: `can_reach(v, w)` answers "is `w` an
    /// ancestor of `v`" (reachability over reversed edges).
    pub fn ancestors(g: &Graph) -> Reachability {
        let mut rev = topo_order_indices(g);
        rev.reverse();
        Self::build(g.n(), |v| &g.preds[v], &rev)
    }

    /// Rows are assembled iterating `order` *in reverse*, so `order`
    /// must place every node before all of its `adj`-neighbours
    /// (topological for successors, reverse-topological for
    /// predecessors) — then each neighbour's row is complete when it is
    /// OR-ed into `v`'s.
    fn build<'g>(
        n: usize,
        adj: impl Fn(usize) -> &'g Vec<NodeId>,
        order: &[NodeId],
    ) -> Reachability {
        let words = n.div_ceil(64);
        let mut bits = vec![0u64; n * words];
        // iterate so neighbours' rows are complete before v's row is
        // assembled: reverse of `order`
        for &v in order.iter().rev() {
            let v = v as usize;
            for &w in adj(v) {
                let w = w as usize;
                // set bit w, then OR in w's row
                bits[v * words + w / 64] |= 1u64 << (w % 64);
                for k in 0..words {
                    let ww = bits[w * words + k];
                    bits[v * words + k] |= ww;
                }
            }
        }
        Reachability { n, words, bits }
    }

    /// Is `to` reachable from `from` via a path of length ≥ 1?
    #[inline]
    pub fn can_reach(&self, from: NodeId, to: NodeId) -> bool {
        let (f, t) = (from as usize, to as usize);
        debug_assert!(f < self.n && t < self.n);
        self.bits[f * self.words + t / 64] & (1u64 << (t % 64)) != 0
    }

    /// Number of nodes reachable from `v` (excluding `v` itself unless
    /// the graph has a cycle, which [`Graph`] construction forbids).
    pub fn count(&self, v: NodeId) -> u32 {
        let v = v as usize;
        self.bits[v * self.words..(v + 1) * self.words]
            .iter()
            .map(|w| w.count_ones())
            .sum()
    }
}

/// Deterministic topological order as node ids (panics on cycles, which
/// `Graph` construction already rejects).
fn topo_order_indices(g: &Graph) -> Vec<NodeId> {
    super::topo::topological_order(g).expect("Graph invariant: acyclic")
}

/// Transitive redundancy flags, parallel to `g.succs`: the edge
/// `(u, g.succs[u][i])` is redundant iff `redundant[u][i]` — some other
/// path `u → w → … → v` already implies the precedence.
///
/// Uses the descendant bitsets: `(u, v)` is redundant iff some *other*
/// successor `w` of `u` reaches `v`.
pub fn transitive_reduction(g: &Graph) -> Vec<Vec<bool>> {
    let reach = Reachability::descendants(g);
    let mut redundant: Vec<Vec<bool>> = Vec::with_capacity(g.n());
    for u in 0..g.n() {
        let ss = &g.succs[u];
        let flags = ss
            .iter()
            .map(|&v| ss.iter().any(|&w| w != v && reach.can_reach(w, v)))
            .collect();
        redundant.push(flags);
    }
    redundant
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Diamond plus a shortcut edge 0→3 (redundant: 0→1→3 exists).
    fn diamond_shortcut() -> Graph {
        Graph::from_edges(
            "ds",
            4,
            &[(0, 1), (0, 2), (1, 3), (2, 3), (0, 3)],
            vec![1; 4],
            vec![1; 4],
        )
        .unwrap()
    }

    #[test]
    fn reachability_descendants_and_ancestors() {
        let g = diamond_shortcut();
        let d = Reachability::descendants(&g);
        assert!(d.can_reach(0, 3));
        assert!(d.can_reach(0, 1));
        assert!(!d.can_reach(1, 2));
        assert!(!d.can_reach(3, 0));
        assert_eq!(d.count(0), 3);
        assert_eq!(d.count(3), 0);
        let a = Reachability::ancestors(&g);
        assert!(a.can_reach(3, 0));
        assert!(!a.can_reach(0, 3));
        assert_eq!(a.count(3), 3);
        assert_eq!(a.count(0), 0);
    }

    #[test]
    fn transitive_reduction_flags_shortcut_only() {
        let g = diamond_shortcut();
        let red = transitive_reduction(&g);
        // succs[0] = [1, 2, 3] (sorted): only (0,3) is redundant
        assert_eq!(red[0], vec![false, false, true]);
        assert_eq!(red[1], vec![false]);
        assert_eq!(red[2], vec![false]);
        assert!(red[3].is_empty());
    }

    #[test]
    fn chain_has_no_redundancy() {
        let g = Graph::from_edges("c", 3, &[(0, 1), (1, 2)], vec![1; 3], vec![1; 3]).unwrap();
        let red = transitive_reduction(&g);
        assert!(red.iter().flatten().all(|&r| !r));
    }

    #[test]
    fn long_shortcut_is_redundant() {
        // 0→1→2→3 with 0→2 and 0→3: both shortcuts redundant
        let g = Graph::from_edges(
            "ls",
            4,
            &[(0, 1), (1, 2), (2, 3), (0, 2), (0, 3)],
            vec![1; 4],
            vec![1; 4],
        )
        .unwrap();
        let red = transitive_reduction(&g);
        // succs[0] = [1, 2, 3]
        assert_eq!(red[0], vec![false, true, true]);
    }
}
