//! Compute-graph DAG core.
//!
//! Nodes are compute operations with a duration `w_v` (cost of executing
//! the op, in abstract time units) and an output size `m_v` (bytes the
//! op's output tensor occupies in local memory). Directed edges `(u, v)`
//! mean the output of `u` must be resident in local memory when `v`
//! executes.
//!
//! This module is the substrate every solver builds on: construction and
//! validation, topological orders (deterministic and randomized), and the
//! evaluation of rematerialization sequences under the paper's
//! Appendix-A.3 memory semantics (`eval`).

mod eval;
mod reach;
mod topo;

pub use eval::{eval_sequence, Evaluator, SeqEval, SeqError};
pub use reach::{transitive_reduction, Reachability};
pub use topo::{is_topological_with_remat, random_topological_order, topological_order};

/// Node index inside a [`Graph`] (dense `0..n`).
pub type NodeId = u32;

/// A directed acyclic compute graph.
///
/// Immutable after construction; all solvers treat it as shared input.
#[derive(Debug, Clone)]
pub struct Graph {
    /// Human-readable graph name (used in reports and caches).
    pub name: String,
    /// `w_v`: execution duration of each node.
    pub duration: Vec<u64>,
    /// `m_v`: output-tensor size of each node.
    pub mem: Vec<u64>,
    /// Predecessors of each node (sorted).
    pub preds: Vec<Vec<NodeId>>,
    /// Successors of each node (sorted).
    pub succs: Vec<Vec<NodeId>>,
}

impl Graph {
    /// Build a graph from an edge list. Edges must describe a DAG; node
    /// ids must be dense in `0..n`.
    pub fn from_edges(
        name: impl Into<String>,
        n: usize,
        edges: &[(NodeId, NodeId)],
        duration: Vec<u64>,
        mem: Vec<u64>,
    ) -> Result<Self, String> {
        assert_eq!(duration.len(), n, "duration.len() != n");
        assert_eq!(mem.len(), n, "mem.len() != n");
        let mut preds = vec![Vec::new(); n];
        let mut succs = vec![Vec::new(); n];
        for &(u, v) in edges {
            if u as usize >= n || v as usize >= n {
                return Err(format!("edge ({u},{v}) out of range for n={n}"));
            }
            if u == v {
                return Err(format!("self-loop at node {u}"));
            }
            succs[u as usize].push(v);
            preds[v as usize].push(u);
        }
        for l in preds.iter_mut().chain(succs.iter_mut()) {
            l.sort_unstable();
            l.dedup();
        }
        let g = Graph { name: name.into(), duration, mem, preds, succs };
        if topo::topological_order(&g).is_none() {
            return Err("graph contains a cycle".into());
        }
        Ok(g)
    }

    /// Number of nodes `n`.
    #[inline]
    pub fn n(&self) -> usize {
        self.duration.len()
    }

    /// Number of edges `m`.
    pub fn m(&self) -> usize {
        self.succs.iter().map(|s| s.len()).sum()
    }

    /// Iterator over all edges `(u, v)` in `u`-major order. Allocation
    /// free — callers that used to re-collect the edge list inside loops
    /// now iterate the adjacency in place (collect explicitly if a
    /// materialized list is really needed).
    pub fn edges(&self) -> impl Iterator<Item = (NodeId, NodeId)> + '_ {
        self.succs
            .iter()
            .enumerate()
            .flat_map(|(u, ss)| ss.iter().map(move |&v| (u as NodeId, v)))
    }

    /// Sum of all node durations: the duration of any sequence without
    /// rematerialization (the TDI-% baseline).
    pub fn total_duration(&self) -> u64 {
        self.duration.iter().sum()
    }

    /// Sum of all output sizes (a trivial upper bound on peak memory).
    pub fn total_mem(&self) -> u64 {
        self.mem.iter().sum()
    }

    /// Nodes with no predecessors.
    pub fn sources(&self) -> Vec<NodeId> {
        (0..self.n() as NodeId).filter(|&v| self.preds[v as usize].is_empty()).collect()
    }

    /// Nodes with no successors.
    pub fn sinks(&self) -> Vec<NodeId> {
        (0..self.n() as NodeId).filter(|&v| self.succs[v as usize].is_empty()).collect()
    }

    /// Peak memory of executing `order` once (no rematerialization) under
    /// the Appendix-A.3 semantics. `order` must be a valid topological
    /// order covering every node exactly once.
    pub fn peak_mem_no_remat(&self, order: &[NodeId]) -> Result<u64, SeqError> {
        Ok(eval::eval_sequence(self, order)?.peak_mem)
    }

    /// A structural lower bound on the peak memory of *any* valid
    /// sequence: every node must hold all its predecessors' outputs plus
    /// its own while computing (Appendix A.3, eq. 17). No budget below
    /// this is feasible, rematerialization or not.
    pub fn working_set_floor(&self) -> u64 {
        (0..self.n())
            .map(|v| {
                self.mem[v] + self.preds[v].iter().map(|&u| self.mem[u as usize]).sum::<u64>()
            })
            .max()
            .unwrap_or(0)
    }

    /// A stable 64-bit fingerprint of the graph structure + weights, used
    /// as the coordinator's solution-cache key.
    pub fn fingerprint(&self) -> u64 {
        // FNV-1a over a canonical serialization; no external deps.
        let mut h: u64 = 0xcbf29ce484222325;
        let mut eat = |x: u64| {
            for b in x.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x100000001b3);
            }
        };
        eat(self.n() as u64);
        for v in 0..self.n() {
            eat(self.duration[v]);
            eat(self.mem[v]);
            for &p in &self.preds[v] {
                eat(p as u64 + 1);
            }
            eat(u64::MAX); // separator
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The 4-node example graph of Figure 2: 1→2→4, 1→3→4 (0-indexed:
    /// 0→1→3, 0→2→3).
    pub fn fig2() -> Graph {
        Graph::from_edges(
            "fig2",
            4,
            &[(0, 1), (0, 2), (1, 3), (2, 3)],
            vec![1, 1, 1, 1],
            vec![1, 1, 1, 1],
        )
        .unwrap()
    }

    #[test]
    fn build_and_counts() {
        let g = fig2();
        assert_eq!(g.n(), 4);
        assert_eq!(g.m(), 4);
        assert_eq!(g.total_duration(), 4);
        assert_eq!(g.sources(), vec![0]);
        assert_eq!(g.sinks(), vec![3]);
    }

    #[test]
    fn rejects_cycle() {
        let r = Graph::from_edges("cyc", 2, &[(0, 1), (1, 0)], vec![1, 1], vec![1, 1]);
        assert!(r.is_err());
    }

    #[test]
    fn rejects_self_loop() {
        let r = Graph::from_edges("self", 1, &[(0, 0)], vec![1], vec![1]);
        assert!(r.is_err());
    }

    #[test]
    fn rejects_out_of_range() {
        let r = Graph::from_edges("oob", 2, &[(0, 5)], vec![1, 1], vec![1, 1]);
        assert!(r.is_err());
    }

    #[test]
    fn dedups_parallel_edges() {
        let g = Graph::from_edges("dup", 2, &[(0, 1), (0, 1)], vec![1, 1], vec![1, 1]).unwrap();
        assert_eq!(g.m(), 1);
    }

    #[test]
    fn fingerprint_changes_with_weights() {
        let a = fig2();
        let mut b = fig2();
        b.mem[2] = 7;
        assert_ne!(a.fingerprint(), b.fingerprint());
    }

    #[test]
    fn fingerprint_stable() {
        assert_eq!(fig2().fingerprint(), fig2().fingerprint());
    }
}
