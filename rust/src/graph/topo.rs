//! Topological orders: deterministic (Kahn, smallest-id-first) and
//! randomized (Kahn with uniformly random tie-breaking). The paper's
//! staged formulation (§2.3) takes an *input topological order* as a
//! parameter; the topo-order ablation (`bench ablation-topo`) measures
//! peak-memory variability across random orders, mirroring the paper's
//! observation in §1.1.

use super::{Graph, NodeId};
use crate::util::Rng;

/// Deterministic topological order (Kahn's algorithm, smallest node id
/// first). Returns `None` if the graph has a cycle.
pub fn topological_order(g: &Graph) -> Option<Vec<NodeId>> {
    let n = g.n();
    let mut indeg: Vec<u32> = (0..n).map(|v| g.preds[v].len() as u32).collect();
    // Min-heap behaviour via sorted ready list (n is small: <= a few k).
    let mut ready: Vec<NodeId> =
        (0..n as NodeId).filter(|&v| indeg[v as usize] == 0).collect();
    ready.sort_unstable_by(|a, b| b.cmp(a)); // pop from the back = smallest
    let mut order = Vec::with_capacity(n);
    while let Some(v) = ready.pop() {
        order.push(v);
        for &s in &g.succs[v as usize] {
            indeg[s as usize] -= 1;
            if indeg[s as usize] == 0 {
                // insert keeping descending order
                let pos = ready.partition_point(|&x| x > s);
                ready.insert(pos, s);
            }
        }
    }
    (order.len() == n).then_some(order)
}

/// Random topological order (Kahn with random tie-breaking).
pub fn random_topological_order(g: &Graph, rng: &mut Rng) -> Vec<NodeId> {
    let n = g.n();
    let mut indeg: Vec<u32> = (0..n).map(|v| g.preds[v].len() as u32).collect();
    let mut ready: Vec<NodeId> =
        (0..n as NodeId).filter(|&v| indeg[v as usize] == 0).collect();
    let mut order = Vec::with_capacity(n);
    while !ready.is_empty() {
        let i = rng.gen_range(ready.len());
        let v = ready.swap_remove(i);
        order.push(v);
        for &s in &g.succs[v as usize] {
            indeg[s as usize] -= 1;
            if indeg[s as usize] == 0 {
                ready.push(s);
            }
        }
    }
    debug_assert_eq!(order.len(), n, "graph must be acyclic");
    order
}

/// Check that `seq` (with possible node repetitions) respects all data
/// dependencies *as a rematerialization sequence*: every node appears at
/// least once, and at each position every predecessor of the executed
/// node has already been computed at least once earlier.
///
/// (Full memory-aware validity is checked by `eval_sequence`; under the
/// Appendix-A.3 minimal-retention semantics, "computed earlier" is
/// exactly the liveness requirement — the latest instance of a
/// predecessor is retained up to its last consumer.)
pub fn is_topological_with_remat(g: &Graph, seq: &[NodeId]) -> bool {
    let n = g.n();
    let mut seen = vec![false; n];
    let mut count = 0usize;
    for &v in seq {
        if v as usize >= n {
            return false;
        }
        if g.preds[v as usize].iter().any(|&p| !seen[p as usize]) {
            return false;
        }
        if !seen[v as usize] {
            seen[v as usize] = true;
            count += 1;
        }
    }
    count == n
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> Graph {
        Graph::from_edges(
            "d",
            4,
            &[(0, 1), (0, 2), (1, 3), (2, 3)],
            vec![1; 4],
            vec![1; 4],
        )
        .unwrap()
    }

    #[test]
    fn deterministic_topo_is_valid_and_stable() {
        let g = diamond();
        let t = topological_order(&g).unwrap();
        assert_eq!(t, vec![0, 1, 2, 3]);
        assert!(is_topological_with_remat(&g, &t));
    }

    #[test]
    fn random_topo_valid_many_seeds() {
        let g = diamond();
        for seed in 0..32 {
            let mut rng = Rng::seed_from_u64(seed);
            let t = random_topological_order(&g, &mut rng);
            assert!(is_topological_with_remat(&g, &t), "seed {seed}: {t:?}");
        }
    }

    #[test]
    fn remat_sequence_valid() {
        let g = diamond();
        // recompute 0 before 2 — still respects deps
        assert!(is_topological_with_remat(&g, &[0, 1, 0, 2, 3]));
        // 3 before 2 is invalid
        assert!(!is_topological_with_remat(&g, &[0, 1, 3, 2]));
        // missing node 3
        assert!(!is_topological_with_remat(&g, &[0, 1, 2]));
        // out-of-range node
        assert!(!is_topological_with_remat(&g, &[0, 9]));
    }
}
