//! Sparse LP substrate for the CHECKMATE baseline.
//!
//! Gurobi is unavailable in this environment, so the LP relaxation used
//! by CHECKMATE's two-stage rounding is solved with a matrix-free
//! first-order method: **PDHG** (primal-dual hybrid gradient, the core
//! of PDLP). It needs only sparse mat-vecs, handles the O(n² + nm)
//! variable counts of the CHECKMATE relaxation without factorization,
//! and produces solutions accurate enough for threshold rounding (the
//! paper's point — that rounded solutions are often infeasible — is a
//! property of rounding, not of the LP solver's last digits).
//!
//! The exact MILP itself is solved by pseudo-Boolean branch & bound on
//! the in-tree CP engine (see `checkmate::solve_milp`).

/// Compressed sparse row matrix.
#[derive(Debug, Clone, Default)]
pub struct Csr {
    /// Number of rows.
    pub nrows: usize,
    /// Number of columns.
    pub ncols: usize,
    /// Row pointers (`nrows + 1` entries).
    pub indptr: Vec<usize>,
    /// Column index of each stored entry.
    pub indices: Vec<u32>,
    /// Value of each stored entry.
    pub data: Vec<f64>,
}

impl Csr {
    /// Build from row-wise triplets.
    pub fn from_rows(ncols: usize, rows: &[Vec<(u32, f64)>]) -> Csr {
        let mut indptr = Vec::with_capacity(rows.len() + 1);
        let mut indices = Vec::new();
        let mut data = Vec::new();
        indptr.push(0);
        for r in rows {
            for &(c, v) in r {
                debug_assert!((c as usize) < ncols);
                indices.push(c);
                data.push(v);
            }
            indptr.push(indices.len());
        }
        Csr { nrows: rows.len(), ncols, indptr, indices, data }
    }

    /// y = A x
    pub fn mul(&self, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), self.ncols);
        debug_assert_eq!(y.len(), self.nrows);
        for r in 0..self.nrows {
            let mut acc = 0.0;
            for k in self.indptr[r]..self.indptr[r + 1] {
                acc += self.data[k] * x[self.indices[k] as usize];
            }
            y[r] = acc;
        }
    }

    /// y = Aᵀ x
    pub fn mul_t(&self, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), self.nrows);
        debug_assert_eq!(y.len(), self.ncols);
        y.iter_mut().for_each(|v| *v = 0.0);
        for r in 0..self.nrows {
            let xr = x[r];
            if xr == 0.0 {
                continue;
            }
            for k in self.indptr[r]..self.indptr[r + 1] {
                y[self.indices[k] as usize] += self.data[k] * xr;
            }
        }
    }

    /// Spectral-norm estimate by power iteration (for PDHG step sizes).
    pub fn norm_estimate(&self, iters: usize) -> f64 {
        let mut v = vec![1.0 / (self.ncols as f64).sqrt(); self.ncols];
        let mut av = vec![0.0; self.nrows];
        let mut atav = vec![0.0; self.ncols];
        let mut norm = 1.0f64;
        for _ in 0..iters {
            self.mul(&v, &mut av);
            self.mul_t(&av, &mut atav);
            norm = atav.iter().map(|x| x * x).sum::<f64>().sqrt().sqrt();
            let s: f64 = atav.iter().map(|x| x * x).sum::<f64>().sqrt();
            if s <= 1e-30 {
                return 1.0;
            }
            for i in 0..v.len() {
                v[i] = atav[i] / s;
            }
        }
        norm.max(1e-9)
    }
}

/// Result of an LP solve.
pub struct LpResult {
    /// Primal point (clipped to the box `[0, 1]^n`).
    pub x: Vec<f64>,
    /// Objective value `cᵀx` at the returned point.
    pub objective: f64,
    /// max violation of `Ax ≤ b` at the returned point
    pub max_violation: f64,
    /// PDHG iterations performed.
    pub iterations: usize,
}

/// Solve `min cᵀx  s.t.  A x ≤ b, 0 ≤ x ≤ 1` with PDHG.
pub fn pdhg_solve(c: &[f64], a: &Csr, b: &[f64], max_iters: usize, tol: f64) -> LpResult {
    let n = c.len();
    let m = a.nrows;
    assert_eq!(a.ncols, n);
    assert_eq!(b.len(), m);
    let norm = a.norm_estimate(20);
    let tau = 0.9 / norm;
    let sigma = 0.9 / norm;

    let mut x = vec![0.0f64; n];
    let mut y = vec![0.0f64; m];
    let mut aty = vec![0.0f64; n];
    let mut ax = vec![0.0f64; m];
    let mut x_prev = vec![0.0f64; n];
    let mut x_bar = vec![0.0f64; n];

    let mut iterations = 0;
    for it in 0..max_iters {
        iterations = it + 1;
        // primal step
        a.mul_t(&y, &mut aty);
        x_prev.copy_from_slice(&x);
        for i in 0..n {
            x[i] = (x[i] - tau * (c[i] + aty[i])).clamp(0.0, 1.0);
        }
        // extrapolate
        for i in 0..n {
            x_bar[i] = 2.0 * x[i] - x_prev[i];
        }
        // dual step
        a.mul(&x_bar, &mut ax);
        for r in 0..m {
            y[r] = (y[r] + sigma * (ax[r] - b[r])).max(0.0);
        }
        // periodic convergence check (primal feasibility + movement)
        if it % 100 == 99 {
            a.mul(&x, &mut ax);
            let viol = (0..m).map(|r| (ax[r] - b[r]).max(0.0)).fold(0.0f64, f64::max);
            let step: f64 =
                (0..n).map(|i| (x[i] - x_prev[i]).abs()).fold(0.0f64, f64::max);
            if viol < tol && step < tol * 0.1 {
                break;
            }
        }
    }
    a.mul(&x, &mut ax);
    let max_violation = (0..m).map(|r| (ax[r] - b[r]).max(0.0)).fold(0.0f64, f64::max);
    let objective = (0..n).map(|i| c[i] * x[i]).sum();
    LpResult { x, objective, max_violation, iterations }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csr_matvec() {
        // [[1, 2], [0, 3]]
        let a = Csr::from_rows(2, &[vec![(0, 1.0), (1, 2.0)], vec![(1, 3.0)]]);
        let mut y = vec![0.0; 2];
        a.mul(&[1.0, 1.0], &mut y);
        assert_eq!(y, vec![3.0, 3.0]);
        let mut yt = vec![0.0; 2];
        a.mul_t(&[1.0, 1.0], &mut yt);
        assert_eq!(yt, vec![1.0, 5.0]);
    }

    #[test]
    fn norm_estimate_positive() {
        let a = Csr::from_rows(2, &[vec![(0, 3.0)], vec![(1, 4.0)]]);
        let n = a.norm_estimate(30);
        assert!(n > 1.0 && n < 10.0, "{n}");
    }

    #[test]
    fn pdhg_tiny_lp() {
        // min -x1 - x2  s.t. x1 + x2 <= 1, box [0,1]² → optimum -1 on the
        // simplex face
        let a = Csr::from_rows(2, &[vec![(0, 1.0), (1, 1.0)]]);
        let r = pdhg_solve(&[-1.0, -1.0], &a, &[1.0], 20_000, 1e-6);
        assert!((r.objective + 1.0).abs() < 1e-2, "obj {}", r.objective);
        assert!(r.max_violation < 1e-3);
    }

    #[test]
    fn pdhg_respects_bounds() {
        // min -x s.t. (no constraints beyond box) → x = 1
        let a = Csr::from_rows(1, &[vec![(0, 0.0)]]);
        let r = pdhg_solve(&[-1.0], &a, &[0.0], 5_000, 1e-6);
        assert!((r.x[0] - 1.0).abs() < 1e-3);
    }

    #[test]
    fn pdhg_binding_constraint() {
        // min -2x1 - x2 s.t. x1 <= 0.3, x1 + x2 <= 1
        let a = Csr::from_rows(2, &[vec![(0, 1.0)], vec![(0, 1.0), (1, 1.0)]]);
        let r = pdhg_solve(&[-2.0, -1.0], &a, &[0.3, 1.0], 40_000, 1e-6);
        assert!((r.x[0] - 0.3).abs() < 2e-2, "x1 {}", r.x[0]);
        assert!((r.x[1] - 0.7).abs() < 3e-2, "x2 {}", r.x[1]);
    }
}
