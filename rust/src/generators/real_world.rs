//! Real-world-like inference graphs (stand-in for the paper's RW1–RW4).
//!
//! The paper's RW graphs are proprietary commercial inference graphs; it
//! reports only their sizes, budgets, and that they have "diverse
//! architectures", "complex edge connectivities" and higher edge density
//! than the CM training graphs. We synthesize structurally comparable
//! graphs: a backbone of *blocks* (each a small op pattern: elementwise
//! chains, branch/merge residuals, attention-like fan-outs) connected in
//! series, plus long-range skip connections across blocks and a few
//! auxiliary heads. Tensor sizes are heterogeneous across three orders of
//! magnitude — like real mobile-vision/NLP graphs where big feature maps
//! coexist with small vectors — which is what makes the memory landscape
//! spiky and the remat decisions non-uniform.
//!
//! `rw1..rw4` match the paper's reported (n, m) exactly; budgets in the
//! bench harness are derived as 80% / 90% of each graph's no-remat peak,
//! exactly as in Table 2.

use crate::graph::{Graph, NodeId};
use crate::util::Rng;

/// Generate a real-world-like inference DAG with exactly `n` nodes and
/// `m` edges. Node ids form a topological order.
pub fn real_world_like(name: &str, n: usize, m: usize, seed: u64) -> Graph {
    assert!(n >= 8, "too small for block structure");
    let mut rng = Rng::seed_from_u64(seed ^ 0x5257); // "RW"
    let mut edge_set = std::collections::HashSet::<(NodeId, NodeId)>::new();
    let mut edges: Vec<(NodeId, NodeId)> = Vec::new();
    let add = |edges: &mut Vec<(NodeId, NodeId)>,
                   edge_set: &mut std::collections::HashSet<(NodeId, NodeId)>,
                   u: usize,
                   v: usize|
     -> bool {
        debug_assert!(u < v);
        if edge_set.insert((u as NodeId, v as NodeId)) {
            edges.push((u as NodeId, v as NodeId));
            true
        } else {
            false
        }
    };

    // Backbone of blocks. Each block consumes the previous block's output
    // node and produces its own output node (the last node of the block).
    // Block patterns: chain (2-4 ops), residual branch-merge (4-6 ops),
    // fan-out head (3-5 ops).
    let mut block_outputs: Vec<usize> = Vec::new(); // output node of each block
    let mut v = 0usize;
    let mut prev_out: Option<usize> = None;
    while v < n {
        let remaining = n - v;
        let pat = rng.gen_range(3);
        let size = match pat {
            0 => 2 + rng.gen_range(3),          // chain
            1 => 4 + rng.gen_range(3),          // residual
            _ => 3 + rng.gen_range(3),          // fan-out
        }
        .min(remaining);
        let first = v;
        let last = v + size - 1;
        match pat {
            1 if size >= 4 => {
                // residual: first -> (two parallel chains) -> last, plus
                // identity edge first -> last.
                let mid = first + 1 + (size - 2) / 2;
                let mut prev = first;
                for x in first + 1..mid {
                    add(&mut edges, &mut edge_set, prev, x);
                    prev = x;
                }
                add(&mut edges, &mut edge_set, prev, last);
                let mut prev = first;
                for x in mid..last {
                    add(&mut edges, &mut edge_set, prev, x);
                    prev = x;
                }
                add(&mut edges, &mut edge_set, prev, last);
                add(&mut edges, &mut edge_set, first, last);
            }
            2 if size >= 3 => {
                // fan-out: first feeds every interior node; interiors
                // merge into last.
                for x in first + 1..last {
                    add(&mut edges, &mut edge_set, first, x);
                    add(&mut edges, &mut edge_set, x, last);
                }
            }
            _ => {
                for x in first..last {
                    add(&mut edges, &mut edge_set, x, x + 1);
                }
            }
        }
        if let Some(p) = prev_out {
            add(&mut edges, &mut edge_set, p, first);
        }
        prev_out = Some(last);
        block_outputs.push(last);
        v += size;
    }
    assert!(
        edges.len() <= m,
        "m={m} below backbone structure ({}) for n={n}",
        edges.len()
    );

    // Long skip connections between block outputs (geometric gap), then
    // random forward fill.
    let nb = block_outputs.len();
    let mut guard = 0usize;
    while edges.len() < m {
        guard += 1;
        assert!(guard < 200 * m + 10_000, "rw fill failed (n={n}, m={m})");
        if nb >= 3 && rng.gen_bool(0.6) {
            let i = rng.gen_range(nb - 2);
            let mut gap = 2usize;
            while i + gap < nb - 1 && rng.gen_bool(0.5) {
                gap += 1;
            }
            let (u, w) = (block_outputs[i], block_outputs[(i + gap).min(nb - 1)]);
            if u < w {
                add(&mut edges, &mut edge_set, u, w);
            }
        } else {
            let u = rng.gen_range(n - 1);
            let w = u + 1 + rng.gen_range(n - 1 - u);
            add(&mut edges, &mut edge_set, u, w);
        }
    }

    // Heterogeneous weights: log-uniform-ish sizes over ~3 decades, with
    // block outputs tending larger (feature maps crossing blocks).
    let mut duration = vec![0u64; n];
    let mut mem = vec![0u64; n];
    let is_block_out: std::collections::HashSet<usize> = block_outputs.into_iter().collect();
    for i in 0..n {
        let decade = rng.gen_range(3) as i32; // 0..2
        let base = 10u64.pow(3 + decade as u32); // 1e3 .. 1e5
        let size = (base as f64 * (0.3 + 1.4 * rng.gen_f64())) as u64 + 64;
        mem[i] = if is_block_out.contains(&i) { size * 2 } else { size };
        duration[i] = mem[i] / 64 + rng.gen_range_incl(1, 20);
    }

    Graph::from_edges(name, n, &edges, duration, mem).expect("rw builds a DAG")
}

/// RW1 (358, 947) — stand-in for the paper's first commercial graph.
pub fn rw1() -> Graph {
    real_world_like("RW1", 358, 947, 201)
}

/// RW2 (442, 1247) — the Figure-1 graph.
pub fn rw2() -> Graph {
    real_world_like("RW2", 442, 1247, 202)
}

/// RW3 (574, 1304).
pub fn rw3() -> Graph {
    real_world_like("RW3", 574, 1304, 203)
}

/// RW4 (698, 1436).
pub fn rw4() -> Graph {
    real_world_like("RW4", 698, 1436, 204)
}

/// Large-tier real-world-like instance (the `L` family's inference-
/// graph half, n ∈ {1000, 2500, 5000, 10000}): edge density follows the
/// RW family's ratio trend (RW1 m/n ≈ 2.65 declining to RW4 ≈ 2.06 as
/// n grows — real inference graphs get *sparser* per node at scale, not
/// denser), so the large instances remain block-structured DAGs with
/// long skips and three-decade tensor-size heterogeneity rather than
/// dense random graphs.
pub fn large_real_world(name: &str, n: usize, seed: u64) -> Graph {
    assert!(n >= 1000, "large tier starts at n = 1000 (use real_world_like below that)");
    let ratio = (2.6 - 0.25 * (n as f64 / 1000.0).log10()).max(1.8);
    let m = (n as f64 * ratio).round() as usize;
    real_world_like(name, n, m, seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{eval_sequence, topological_order};

    #[test]
    fn exact_counts() {
        for (n, m, s) in [(358, 947, 1), (442, 1247, 2), (64, 180, 3)] {
            let g = real_world_like("t", n, m, s);
            assert_eq!((g.n(), g.m()), (n, m));
            assert!(topological_order(&g).is_some());
        }
    }

    #[test]
    fn id_order_topological() {
        let g = rw2();
        let ids: Vec<u32> = (0..g.n() as u32).collect();
        assert!(eval_sequence(&g, &ids).is_ok());
    }

    #[test]
    fn heterogeneous_sizes() {
        let g = rw1();
        let mx = *g.mem.iter().max().unwrap();
        let mn = *g.mem.iter().min().unwrap();
        assert!(mx / mn >= 50, "sizes should span decades (max={mx}, min={mn})");
    }

    #[test]
    fn deterministic() {
        let (a, b) = (rw3(), rw3());
        assert!(a.edges().eq(b.edges()));
    }
}
