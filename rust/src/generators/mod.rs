//! Evaluation-graph generators (paper §3.1).
//!
//! The paper evaluates on three graph families:
//!
//! 1. **Random layered graphs** (`random_layered`) — the synthetic
//!    inference-like graphs of Gagrani et al. 2022, Appendix A: nodes are
//!    assigned to layers, every node is connected from the previous layer
//!    (connectivity), and additional forward edges — including long skip
//!    connections — are sampled until the target edge count is reached.
//!    These have the "complex interconnect topology" the paper identifies
//!    as what makes rematerialization hard (and profitable).
//! 2. **CHECKMATE-style training graphs** (`cm_style`) — single-batch
//!    training graphs: a forward chain (with occasional branch blocks)
//!    mirrored by a backward chain, with gradient cross-edges from
//!    forward activations into the backward path ("U-net-like", §1.1).
//! 3. **Real-world-like inference graphs** (`real_world_like`) — stand-in
//!    for the paper's proprietary commercial graphs (RW1–RW4): block-
//!    structured DAGs with branching, long skips and heterogeneous tensor
//!    sizes, matched to the paper's reported (n, m). See DESIGN.md
//!    "Substitutions".
//!
//! All generators are deterministic in the seed, and all return graphs
//! whose (n, m) exactly match the request (the paper reports exact counts
//! per graph, e.g. G2 = (250, 944)).

mod cm_style;
mod random_layered;
mod real_world;

pub use cm_style::{cm1, cm2, cm_style};
pub use random_layered::{large_layered, random_layered};
pub use real_world::{large_real_world, real_world_like, rw1, rw2, rw3, rw4};

use crate::graph::Graph;

/// The paper's named benchmark instances, reconstructed at the reported
/// (n, m) — `G1..G4` random layered, `RW1..RW4` real-world-like,
/// `CM1/CM2` CHECKMATE-style — plus the large-scale `L1..L4` tier
/// (n ∈ {1000, 2500, 5000, 10000}): the regime the paper's "especially
/// for large-scale graphs" claim targets, beyond what Fig. 5 measures.
/// `L1/L2` extend the layered family, `L3/L4` the real-world-like
/// family (see [`large_layered`] / [`large_real_world`] for the
/// density extrapolation).
pub fn paper_graph(name: &str) -> Option<Graph> {
    Some(match name {
        "G1" => random_layered("G1", 100, 236, 1),
        "G2" => random_layered("G2", 250, 944, 2),
        "G3" => random_layered("G3", 500, 2461, 3),
        "G4" => random_layered("G4", 1000, 5875, 4),
        "RW1" => rw1(),
        "RW2" => rw2(),
        "RW3" => rw3(),
        "RW4" => rw4(),
        "CM1" => cm1(),
        "CM2" => cm2(),
        "L1" => large_layered("L1", 1000, 41),
        "L2" => large_layered("L2", 2500, 42),
        "L3" => large_real_world("L3", 5000, 43),
        "L4" => large_real_world("L4", 10000, 44),
        _ => return None,
    })
}

/// Resolve a graph *spec* as accepted by the CLI and the serving wire
/// format: a named paper instance (see [`paper_graph`]) or
/// `rl:n:m:seed` for an ad-hoc random layered graph. `None` for
/// anything else.
pub fn graph_from_spec(spec: &str) -> Option<Graph> {
    if let Some(g) = paper_graph(spec) {
        return Some(g);
    }
    let parts: Vec<&str> = spec.split(':').collect();
    if parts.len() == 4 && parts[0] == "rl" {
        let n = parts[1].parse().ok()?;
        let m = parts[2].parse().ok()?;
        let s = parts[3].parse().ok()?;
        return Some(random_layered(spec, n, m, s));
    }
    None
}

/// All paper instance names in Table 2/3 order.
pub const PAPER_GRAPHS: [&str; 10] =
    ["G1", "G2", "G3", "G4", "RW1", "RW2", "RW3", "RW4", "CM1", "CM2"];

/// The large-scale tier (`bench large-json` order): n ∈ {1000, 2500,
/// 5000, 10000} at paper-style densities and memory-budget ratios.
pub const LARGE_GRAPHS: [&str; 4] = ["L1", "L2", "L3", "L4"];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::topological_order;

    #[test]
    fn paper_instances_match_reported_counts() {
        let expect = [
            ("G1", 100, 236),
            ("G2", 250, 944),
            ("G3", 500, 2461),
            ("G4", 1000, 5875),
            ("RW1", 358, 947),
            ("RW2", 442, 1247),
            ("RW3", 574, 1304),
            ("RW4", 698, 1436),
            ("CM1", 73, 149),
            ("CM2", 353, 751),
        ];
        for (name, n, m) in expect {
            let g = paper_graph(name).unwrap();
            assert_eq!(g.n(), n, "{name} node count");
            assert_eq!(g.m(), m, "{name} edge count");
            assert!(topological_order(&g).is_some(), "{name} must be a DAG");
        }
    }

    #[test]
    fn unknown_name_is_none() {
        assert!(paper_graph("nope").is_none());
    }

    #[test]
    fn large_tier_instances_are_dags_at_requested_n() {
        // L1 (layered) and L3 (real-world-like) cover both generator
        // halves; L2/L4 use the same constructors at other sizes and
        // are exercised by `bench large-json` (CI smoke runs L1).
        let l1 = paper_graph("L1").unwrap();
        assert_eq!(l1.n(), 1000);
        assert!(l1.m() >= 5875, "L1 density must not fall below G4's");
        assert!(topological_order(&l1).is_some());
        let l3 = paper_graph("L3").unwrap();
        assert_eq!(l3.n(), 5000);
        assert!(topological_order(&l3).is_some());
        // deterministic in the seed (CSV/JSON reproducibility)
        let again = paper_graph("L1").unwrap();
        assert!(l1.edges().eq(again.edges()));
    }
}
