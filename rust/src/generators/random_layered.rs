//! Random layered graphs (Gagrani et al. 2022, Appendix A).
//!
//! Construction: `n` nodes are partitioned into `L ≈ n / width` layers.
//! Each non-first-layer node receives one incoming edge from a uniformly
//! random node of the previous layer (guaranteeing connectivity and a
//! layered DAG). The remaining `m - (n - |layer 0|)` edges are sampled as
//! forward edges `(u, v)` with `layer(u) < layer(v)`, where the layer gap
//! is drawn from a geometric-like distribution so that both short links
//! and long skip connections occur — the skips are what give these graphs
//! the "complex interconnect topology" that makes rematerialization
//! non-trivial.
//!
//! Durations and output sizes are drawn uniformly from ranges chosen so
//! the paper's budget magnitudes are reproduced (e.g. G2 peak memory
//! ≈ 165k units at (250, 944); the paper's Table 2 budget for G2 is
//! 132,156 = 80% of the no-remat peak).

use crate::graph::{Graph, NodeId};
use crate::util::Rng;

/// Generate a random layered DAG with exactly `n` nodes and `m` edges.
///
/// Panics if `m` is too small to connect the layers or too large for a
/// layered DAG on `n` nodes.
pub fn random_layered(name: &str, n: usize, m: usize, seed: u64) -> Graph {
    let mut rng = Rng::seed_from_u64(seed ^ 0x6d6f_6363_6173_696e); // "moccasin"
    // Average layer width grows slowly with n (mirrors the generator the
    // paper borrows: deep graphs with moderate width).
    let width = ((n as f64).sqrt() * 0.7).max(2.0).round() as usize;
    let mut layers: Vec<Vec<NodeId>> = Vec::new();
    let mut layer_of: Vec<usize> = vec![0; n];
    {
        let mut v = 0usize;
        while v < n {
            let remaining = n - v;
            let w = if remaining <= 2 {
                remaining
            } else {
                (1 + rng.gen_range(width.min(remaining - 1))).min(remaining)
            };
            let l = layers.len();
            let mut layer = Vec::with_capacity(w);
            for _ in 0..w {
                layer_of[v] = l;
                layer.push(v as NodeId);
                v += 1;
            }
            layers.push(layer);
        }
    }
    let nl = layers.len();
    assert!(nl >= 2, "need at least two layers (n={n} too small?)");

    let mut edge_set = std::collections::HashSet::<(NodeId, NodeId)>::with_capacity(m * 2);
    let mut edges = Vec::with_capacity(m);
    // Connectivity: each node beyond layer 0 gets one parent in the
    // previous layer.
    for l in 1..nl {
        for i in 0..layers[l].len() {
            let v = layers[l][i];
            let u = *rng.choose(&layers[l - 1]);
            if edge_set.insert((u, v)) {
                edges.push((u, v));
            }
        }
    }
    assert!(
        edges.len() <= m,
        "m={m} too small for connectivity of n={n} (needs {})",
        edges.len()
    );

    // Remaining edges: forward edges with geometric-ish layer gap.
    // In-degree is capped (at 4, or ~2x the average degree for dense
    // graphs) — real tensor ops rarely take more inputs, and an
    // uncapped random graph concentrates edges on a few nodes,
    // inflating the structural working-set floor far above what the
    // paper's graphs exhibit (they reach 80% budgets with low
    // single-digit TDI).
    let cap = 4u32.max((2 * m / n) as u32);
    let mut indeg = vec![0u32; n];
    for &(_, v) in &edges {
        indeg[v as usize] += 1;
    }
    let mut guard = 0usize;
    while edges.len() < m {
        guard += 1;
        assert!(guard < 200 * m + 10_000, "edge sampling failed to reach m={m} for n={n}");
        let lu = rng.gen_range(nl - 1);
        // gap >= 1, geometric with p=0.55 capped at remaining depth
        let mut gap = 1usize;
        while gap < nl - 1 - lu && rng.gen_bool(0.45) {
            gap += 1;
        }
        let lv = lu + gap;
        let u = *rng.choose(&layers[lu]);
        let v = *rng.choose(&layers[lv]);
        if indeg[v as usize] >= cap {
            continue;
        }
        if edge_set.insert((u, v)) {
            edges.push((u, v));
            indeg[v as usize] += 1;
        }
    }

    // Weights: durations ~ U[5, 50]; output sizes ~ U[200, 1400] with a
    // small fraction of large tensors (feature-map-like heavy hitters).
    let duration: Vec<u64> = (0..n).map(|_| rng.gen_range_incl(5, 50)).collect();
    // Sizes are moderately heterogeneous but without an extreme heavy
    // tail: the paper's RL graphs exhibit low single-digit TDI at an 80%
    // budget, which requires the peak to be made of *many* mid-size
    // retained tensors (each a remat opportunity) rather than a couple
    // of giant ones.
    let mem: Vec<u64> = (0..n).map(|_| rng.gen_range_incl(200, 1400)).collect();

    Graph::from_edges(name, n, &edges, duration, mem).expect("layered construction is a DAG")
}

/// Large-tier layered instance (the `L` family, paper-scale-and-beyond:
/// n ∈ {1000, 2500, 5000, 10000}): edge density extrapolates the
/// G-family trend (G1 m/n ≈ 2.36 → G4 m/n ≈ 5.875, roughly linear in
/// log n) gently past G4, so the large instances keep the "complex
/// interconnect topology" that makes rematerialization non-trivial
/// without degenerating into an unrealistically dense random graph.
/// Memory-budget ratios in the bench harness stay the paper's 80/90%
/// of the no-remat peak.
pub fn large_layered(name: &str, n: usize, seed: u64) -> Graph {
    assert!(n >= 1000, "large tier starts at n = 1000 (use random_layered below that)");
    let ratio = 5.875 + (n as f64 / 1000.0).log10();
    let m = (n as f64 * ratio).round() as usize;
    random_layered(name, n, m, seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{topological_order, Graph};

    fn degrees(g: &Graph) -> (usize, usize) {
        (g.n(), g.m())
    }

    #[test]
    fn exact_counts() {
        for (n, m, s) in [(100, 236, 1), (250, 944, 2), (50, 120, 9)] {
            let g = random_layered("t", n, m, s);
            assert_eq!(degrees(&g), (n, m));
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let a = random_layered("a", 100, 236, 7);
        let b = random_layered("b", 100, 236, 7);
        assert!(a.edges().eq(b.edges()));
        assert_eq!(a.mem, b.mem);
        let c = random_layered("c", 100, 236, 8);
        assert!(!a.edges().eq(c.edges()));
    }

    #[test]
    fn is_dag_and_connected_forward() {
        let g = random_layered("t", 200, 800, 3);
        assert!(topological_order(&g).is_some());
        // every non-source node has a predecessor
        let srcs = g.sources();
        for v in 0..g.n() {
            assert!(
                !g.preds[v].is_empty() || srcs.contains(&(v as u32)),
                "node {v} disconnected"
            );
        }
    }

    #[test]
    fn has_skip_connections() {
        // at least one edge should span more than one "position" widely —
        // proxy: some node has an edge to a node with id gap > 3*width.
        let g = random_layered("t", 250, 944, 2);
        let has_long = g.edges().any(|(u, v)| v as i64 - u as i64 > 40);
        assert!(has_long, "expected long skip connections");
    }
}
