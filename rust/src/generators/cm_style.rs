//! CHECKMATE-style training graphs.
//!
//! The CHECKMATE evaluation graphs (Jain et al. 2020) are single-batch
//! *training* graphs of image networks: a forward chain of layers, a loss
//! node, and a mirrored backward chain, with cross-edges carrying saved
//! forward activations into the gradient computations. The paper (§1.1)
//! calls this the "U-net-like" structure: long edges crossing from the
//! forward to the backward path are exactly what makes rematerialization
//! profitable.
//!
//! We reconstruct this family synthetically (the original graphs were
//! traced from Keras models; see DESIGN.md "Substitutions"): `cm_style`
//! builds a k-layer forward chain + loss + backward chain with
//! activation cross-edges, then adds deterministic skip/branch edges
//! until the requested edge count is met exactly. `cm1`/`cm2` match the
//! paper's reported sizes: CM1 = FCN-VGG at (73, 149), CM2 = ResNet50 at
//! (353, 751).

use crate::graph::{Graph, NodeId};
use crate::util::Rng;

/// Build a training graph with exactly `n` nodes and `m` edges.
///
/// Layout (node ids are a topological order):
/// `f_0 .. f_{k-1}` (forward), `L = k` (loss), `b_{k-1} .. b_0`
/// (backward, stored as ids `k+1 .. 2k`), with `n = 2k + 1`.
/// `n` must be odd and ≥ 5.
pub fn cm_style(name: &str, n: usize, m: usize, seed: u64, mem_scale: u64) -> Graph {
    assert!(n >= 5 && n % 2 == 1, "cm_style needs odd n >= 5 (got {n})");
    let k = (n - 1) / 2;
    let loss = k as NodeId;
    let fwd = |i: usize| i as NodeId; // i in 0..k
    let bwd = |i: usize| (2 * k - i) as NodeId; // grad of layer i; ids k+1..=2k

    let mut edge_set = std::collections::HashSet::<(NodeId, NodeId)>::new();
    let mut edges: Vec<(NodeId, NodeId)> = Vec::new();
    let add = |edges: &mut Vec<(NodeId, NodeId)>,
                   edge_set: &mut std::collections::HashSet<(NodeId, NodeId)>,
                   u: NodeId,
                   v: NodeId|
     -> bool {
        debug_assert!(u < v, "edges must go forward in id order ({u} -> {v})");
        if edge_set.insert((u, v)) {
            edges.push((u, v));
            true
        } else {
            false
        }
    };

    // Forward chain f_0 -> f_1 -> ... -> f_{k-1} -> L.
    for i in 1..k {
        add(&mut edges, &mut edge_set, fwd(i - 1), fwd(i));
    }
    add(&mut edges, &mut edge_set, fwd(k - 1), loss);
    // Backward chain L -> b_{k-1} -> ... -> b_0.
    add(&mut edges, &mut edge_set, loss, bwd(k - 1));
    for i in (0..k - 1).rev() {
        add(&mut edges, &mut edge_set, bwd(i + 1), bwd(i));
    }
    // Gradient cross-edges: b_i needs the activation input of layer i,
    // i.e. the output of f_{i-1} (and the op's own output f_i for the
    // local Jacobian — added below as fill if the budget allows).
    for i in 1..k {
        add(&mut edges, &mut edge_set, fwd(i - 1), bwd(i));
    }
    assert!(
        edges.len() <= m,
        "m={m} below base training-graph structure ({} edges) for n={n}",
        edges.len()
    );

    // Fill to exactly m with deterministic extras, in priority order:
    // (1) f_i -> b_i own-activation edges, (2) forward skip connections
    // f_i -> f_{i+g} with the mirrored backward cross-edge, (3) random
    // forward-in-id-order edges.
    let mut rng = Rng::seed_from_u64(seed ^ 0x434d5f). // "CM_"
        clone();
    'fill: {
        for i in 1..k {
            if edges.len() >= m {
                break 'fill;
            }
            add(&mut edges, &mut edge_set, fwd(i), bwd(i));
        }
        let mut gap = 2usize;
        while gap < k && edges.len() < m {
            let mut i = 0;
            while i + gap < k && edges.len() < m {
                add(&mut edges, &mut edge_set, fwd(i), fwd(i + gap));
                if edges.len() < m && i > 0 {
                    add(&mut edges, &mut edge_set, fwd(i), bwd(i + gap));
                }
                i += gap + 1;
            }
            gap += 1;
        }
        let mut guard = 0;
        while edges.len() < m {
            guard += 1;
            assert!(guard < 100 * m + 10_000, "cm_style fill failed (n={n}, m={m})");
            let u = rng.gen_range(n - 1) as NodeId;
            let v = (u as usize + 1 + rng.gen_range(n - 1 - u as usize)) as NodeId;
            add(&mut edges, &mut edge_set, u, v);
        }
    }

    // Weights. Activation sizes shrink with depth (conv pyramids);
    // gradient outputs mirror their layer's input size. Durations are
    // roughly proportional to sizes (compute-heavy early layers), with
    // backward ops ~2x forward cost.
    let mut duration = vec![0u64; n];
    let mut mem = vec![0u64; n];
    let mut rng2 = Rng::seed_from_u64(seed ^ 0x77);
    for i in 0..k {
        let depth_frac = i as f64 / k as f64;
        let size = (mem_scale as f64 * (1.0 - 0.75 * depth_frac)
            * (0.6 + 0.8 * rng2.gen_f64())) as u64
            + 1;
        mem[fwd(i) as usize] = size;
        duration[fwd(i) as usize] = size / 8 + rng2.gen_range_incl(1, 10);
        mem[bwd(i) as usize] = size;
        duration[bwd(i) as usize] = size / 4 + rng2.gen_range_incl(1, 10);
    }
    mem[loss as usize] = 1;
    duration[loss as usize] = 1;

    Graph::from_edges(name, n, &edges, duration, mem).expect("cm_style builds a DAG")
}

/// CM1: the paper's "FCN with VGG layers" instance, (n, m) = (73, 149).
pub fn cm1() -> Graph {
    cm_style("CM1", 73, 149, 101, 4096)
}

/// CM2: the paper's ResNet50 instance, (n, m) = (353, 751).
pub fn cm2() -> Graph {
    cm_style("CM2", 353, 751, 102, 2048)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{eval_sequence, topological_order};

    #[test]
    fn exact_counts_and_dag() {
        for (n, m) in [(73, 149), (353, 751), (21, 45)] {
            let g = cm_style("t", n, m, 5, 1024);
            assert_eq!(g.n(), n);
            assert_eq!(g.m(), m);
            assert!(topological_order(&g).is_some());
        }
    }

    #[test]
    fn id_order_is_topological() {
        let g = cm1();
        let ids: Vec<u32> = (0..g.n() as u32).collect();
        assert!(eval_sequence(&g, &ids).is_ok());
    }

    #[test]
    fn has_fwd_bwd_cross_edges() {
        let g = cm1();
        let k = (g.n() - 1) / 2;
        // some edge from forward part (id < k) into backward part (> k)
        let crosses =
            g.edges().filter(|&(u, v)| (u as usize) < k && (v as usize) > k).count();
        assert!(crosses >= k / 2, "training graph needs activation cross-edges");
    }

    #[test]
    fn deterministic() {
        let (a, b) = (cm1(), cm1());
        assert!(a.edges().eq(b.edges()));
        assert_eq!(cm2().mem, cm2().mem);
    }
}
