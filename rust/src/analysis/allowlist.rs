//! The lint allowlist: checked-in, justified exemptions.
//!
//! Format (one entry per line, `#` comments and blank lines ignored):
//!
//! ```text
//! <rule-key> <file> <atom-or-fn> — <one-line justification>
//! ```
//!
//! * `rule-key` — `relaxed` (atomic-ordering rule, keyed by receiver
//!   atom), `panic` or `lock` (panic-safety rules, keyed by enclosing
//!   function name).
//! * `file` — path relative to the scanned source root.
//! * `atom-or-fn` — the receiver atomic's field/static name
//!   (case-insensitive) for `relaxed`, the enclosing function name for
//!   `panic`/`lock`.
//! * justification — required free text; the lint prints it whenever
//!   the entry is involved in drift, so it must say *why* the exemption
//!   is sound, not just that it exists.
//!
//! Every entry must match at least one site: unmatched entries are
//! reported as `MC-ALLOW-STALE`, so deleting the code that justified an
//! exemption also forces deleting the exemption.

/// One parsed allowlist entry.
#[derive(Debug, Clone)]
pub struct AllowEntry {
    /// Rule key: `relaxed`, `panic`, or `lock`.
    pub rule: String,
    /// File the exemption applies to (relative to the source root).
    pub file: String,
    /// Receiver atom (for `relaxed`) or enclosing fn (for `panic`/`lock`).
    pub atom: String,
    /// Why the exemption is sound.
    pub why: String,
    /// 1-based line in `allowlist.txt` (for reporting).
    pub line: u32,
}

/// The parsed allowlist.
#[derive(Debug, Clone, Default)]
pub struct Allowlist {
    entries: Vec<AllowEntry>,
}

impl Allowlist {
    /// Parse allowlist text. Malformed lines (fewer than three fields)
    /// are kept as entries with an empty justification and will be
    /// reported stale unless they match — the lint never panics on its
    /// own configuration.
    pub fn parse(text: &str) -> Allowlist {
        let mut entries = Vec::new();
        for (i, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut it = line.split_whitespace();
            let rule = it.next().unwrap_or_default().to_string();
            let file = it.next().unwrap_or_default().to_string();
            let atom = it.next().unwrap_or_default().to_string();
            let why = it.collect::<Vec<_>>().join(" ");
            if rule.is_empty() || file.is_empty() || atom.is_empty() {
                continue;
            }
            entries.push(AllowEntry { rule, file, atom, why, line: (i + 1) as u32 });
        }
        Allowlist { entries }
    }

    /// Number of entries (used to size the per-run usage bitmap).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when the allowlist has no entries.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// The entries, in file order.
    pub fn entries(&self) -> &[AllowEntry] {
        &self.entries
    }

    /// Find the entry exempting (`rule`, `file`, `atom`), if any.
    /// Atom/fn comparison is case-insensitive (statics vs fields).
    pub fn lookup(&self, rule: &str, file: &str, atom: &str) -> Option<usize> {
        self.entries.iter().position(|e| {
            e.rule == rule && e.file == file && e.atom.eq_ignore_ascii_case(atom)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_and_looks_up() {
        let a = Allowlist::parse(
            "# comment\n\
             relaxed util/events.rs lock_recoveries — monotone diagnostic counter\n\
             panic cp/domain.rs assign — caller-proven containment\n\
             \n# another comment\n",
        );
        assert_eq!(a.len(), 2);
        assert!(a.lookup("relaxed", "util/events.rs", "lock_recoveries").is_some());
        assert!(a.lookup("relaxed", "util/events.rs", "LOCK_RECOVERIES").is_some());
        assert!(a.lookup("panic", "cp/domain.rs", "assign").is_some());
        assert!(a.lookup("panic", "cp/domain.rs", "value").is_none());
        assert!(a.lookup("lock", "cp/domain.rs", "assign").is_none());
        let e = &a.entries()[0];
        assert!(e.why.contains("monotone"));
        assert_eq!(e.line, 2);
    }

    #[test]
    fn malformed_lines_are_skipped_not_fatal() {
        let a = Allowlist::parse("relaxed\nonly two\n");
        assert_eq!(a.len(), 0);
    }
}
