//! A minimal hand-rolled Rust lexer for the in-tree lint.
//!
//! Same philosophy as the in-tree JSON parser ([`crate::serve::json`]):
//! no `syn`, no proc-macro machinery, zero dependencies — the build
//! stays fully offline. The lexer does not need to be a complete Rust
//! front end; it needs exactly enough fidelity for the rules in
//! [`super::rules`]: identifiers (including raw identifiers), string /
//! byte-string / raw-string literals (so tokens inside them are never
//! misread as code), char literals vs lifetimes, nested block comments,
//! line comments, numbers, and single-character punctuation — each with
//! a 1-based line number for reporting.

/// Token kind. Only the distinctions the rules need are kept.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    /// Identifier or keyword (`fn`, `unwrap`, `Ordering`, …).
    Ident,
    /// Single punctuation character (`.`, `(`, `{`, `#`, …).
    Punct,
    /// Numeric literal.
    Num,
    /// String literal of any flavour (`"…"`, `b"…"`, `r#"…"#`).
    Str,
    /// Lifetime (`'a`, `'static`).
    Lifetime,
    /// Char or byte-char literal (`'x'`, `b'\n'`).
    Char,
}

/// One lexed token: kind, source text, and 1-based line number.
#[derive(Debug, Clone)]
pub struct Tok {
    /// What the token is.
    pub kind: Kind,
    /// Its source text (quotes included for literals).
    pub text: String,
    /// 1-based line the token starts on.
    pub line: u32,
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Lex `src` into a token stream. Unrecognized bytes become single
/// `Punct` tokens — the rules ignore punctuation they don't care about,
/// so the lexer never fails.
pub fn lex(src: &str) -> Vec<Tok> {
    let s = src.as_bytes();
    let n = s.len();
    let mut toks: Vec<Tok> = Vec::new();
    let mut i = 0usize;
    let mut line: u32 = 1;
    let text = |a: usize, b: usize| String::from_utf8_lossy(&s[a..b.min(n)]).into_owned();
    while i < n {
        let c = s[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == b' ' || c == b'\t' || c == b'\r' {
            i += 1;
            continue;
        }
        // line comment
        if c == b'/' && i + 1 < n && s[i + 1] == b'/' {
            while i < n && s[i] != b'\n' {
                i += 1;
            }
            continue;
        }
        // block comment (nesting, like rustc)
        if c == b'/' && i + 1 < n && s[i + 1] == b'*' {
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if s[i] == b'\n' {
                    line += 1;
                    i += 1;
                } else if s[i] == b'/' && i + 1 < n && s[i + 1] == b'*' {
                    depth += 1;
                    i += 2;
                } else if s[i] == b'*' && i + 1 < n && s[i + 1] == b'/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            continue;
        }
        // raw strings r"…" / r#"…"#, raw byte strings br"…", raw idents r#id
        if c == b'r' || (c == b'b' && i + 1 < n && s[i + 1] == b'r') {
            let j = if c == b'b' { i + 1 } else { i }; // position of the `r`
            let mut k = j + 1;
            let mut hashes = 0usize;
            while k < n && s[k] == b'#' {
                hashes += 1;
                k += 1;
            }
            if k < n && s[k] == b'"' {
                // raw (byte) string: scan to `"###…` with the same hash count
                k += 1;
                let start = i;
                loop {
                    if k >= n {
                        break;
                    }
                    if s[k] == b'\n' {
                        line += 1;
                        k += 1;
                        continue;
                    }
                    if s[k] == b'"' {
                        let mut h = 0usize;
                        while k + 1 + h < n && h < hashes && s[k + 1 + h] == b'#' {
                            h += 1;
                        }
                        if h == hashes {
                            k += 1 + hashes;
                            break;
                        }
                    }
                    k += 1;
                }
                toks.push(Tok { kind: Kind::Str, text: text(start, k), line });
                i = k;
                continue;
            }
            if c == b'r' && hashes >= 1 && k < n && is_ident_byte(s[k]) {
                // raw identifier r#ident: token text is the bare ident
                let start = k;
                while k < n && is_ident_byte(s[k]) {
                    k += 1;
                }
                toks.push(Tok { kind: Kind::Ident, text: text(start, k), line });
                i = k;
                continue;
            }
            // plain ident starting with r/b: fall through below
        }
        // byte string b"…" / byte char b'…'
        let (c, i0) = if c == b'b' && i + 1 < n && (s[i + 1] == b'"' || s[i + 1] == b'\'') {
            (s[i + 1], i + 1)
        } else {
            (c, i)
        };
        if c == b'"' {
            let start = i;
            let mut j = i0 + 1;
            while j < n {
                if s[j] == b'\\' {
                    j += 2;
                    continue;
                }
                if s[j] == b'"' {
                    break;
                }
                if s[j] == b'\n' {
                    line += 1;
                }
                j += 1;
            }
            toks.push(Tok { kind: Kind::Str, text: text(start, j + 1), line });
            i = j + 1;
            continue;
        }
        if c == b'\'' {
            // lifetime: 'ident not followed by a closing quote
            let j = i0 + 1;
            if j < n
                && (s[j].is_ascii_alphabetic() || s[j] == b'_')
                && !(j + 1 < n && s[j + 1] == b'\'')
            {
                let start = i0;
                let mut k = j;
                while k < n && is_ident_byte(s[k]) {
                    k += 1;
                }
                toks.push(Tok { kind: Kind::Lifetime, text: text(start, k), line });
                i = k;
                continue;
            }
            // char literal (possibly escaped, possibly \u{…})
            let start = i;
            let mut j = i0 + 1;
            if j < n && s[j] == b'\\' {
                j += 2;
                if j <= n && j >= 1 && (s[j - 1] == b'u' || s[j - 1] == b'U') {
                    if j < n && s[j] == b'{' {
                        while j < n && s[j] != b'}' {
                            j += 1;
                        }
                        j += 1;
                    }
                }
            } else {
                // skip one (possibly multi-byte) char
                j += 1;
                while j < n && (s[j] & 0xC0) == 0x80 {
                    j += 1;
                }
            }
            toks.push(Tok { kind: Kind::Char, text: text(start, j + 1), line });
            i = j + 1;
            continue;
        }
        if c.is_ascii_alphabetic() || c == b'_' {
            let start = i;
            let mut j = i;
            while j < n && is_ident_byte(s[j]) {
                j += 1;
            }
            toks.push(Tok { kind: Kind::Ident, text: text(start, j), line });
            i = j;
            continue;
        }
        if c.is_ascii_digit() {
            let start = i;
            let mut j = i;
            while j < n && (is_ident_byte(s[j]) || s[j] == b'.') {
                // keep `0..n` from being eaten as one number
                if s[j] == b'.' && j + 1 < n && s[j + 1] == b'.' {
                    break;
                }
                j += 1;
            }
            toks.push(Tok { kind: Kind::Num, text: text(start, j), line });
            i = j;
            continue;
        }
        toks.push(Tok { kind: Kind::Punct, text: (c as char).to_string(), line });
        i += 1;
    }
    toks
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(Kind, String)> {
        lex(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn idents_and_puncts() {
        let k = kinds("fn foo(x: u32) {}");
        assert_eq!(k[0], (Kind::Ident, "fn".to_string()));
        assert_eq!(k[1], (Kind::Ident, "foo".to_string()));
        assert!(k.iter().any(|(kd, t)| *kd == Kind::Punct && t == "{"));
    }

    #[test]
    fn comments_are_skipped() {
        assert!(kinds("// unwrap() here\nx").iter().all(|(_, t)| t != "unwrap"));
        assert!(kinds("/* outer /* nested unwrap() */ still */ y")
            .iter()
            .all(|(_, t)| t != "unwrap"));
    }

    #[test]
    fn strings_hide_code() {
        let k = kinds(r#"let s = "a.unwrap()"; t"#);
        assert!(k.iter().all(|(_, t)| t != "unwrap"));
        let k = kinds("let s = r#\"x.lock()\"#; t");
        assert!(k.iter().all(|(_, t)| t != "lock"));
        let k = kinds("let s = b\"x.lock()\"; t");
        assert!(k.iter().all(|(_, t)| t != "lock"));
    }

    #[test]
    fn lifetimes_vs_chars() {
        let k = kinds("fn f<'a>(x: &'a str) { let c = 'x'; let e = '\\n'; }");
        assert!(k.iter().any(|(kd, t)| *kd == Kind::Lifetime && t == "'a"));
        assert!(k.iter().any(|(kd, t)| *kd == Kind::Char && t == "'x'"));
        assert!(k.iter().any(|(kd, t)| *kd == Kind::Char && t == "'\\n'"));
    }

    #[test]
    fn raw_ident() {
        let k = kinds("let r#fn = 1;");
        assert!(k.iter().any(|(kd, t)| *kd == Kind::Ident && t == "fn"));
    }

    #[test]
    fn line_numbers_track_newlines() {
        let toks = lex("a\nb\n\nc");
        let lines: Vec<u32> = toks.iter().map(|t| t.line).collect();
        assert_eq!(lines, vec![1, 2, 4]);
    }

    #[test]
    fn multiline_string_advances_lines() {
        let toks = lex("let s = \"a\nb\";\nz");
        let z = toks.iter().find(|t| t.text == "z").map(|t| t.line);
        assert_eq!(z, Some(3));
    }
}
