//! In-tree static analysis: the `moccasin lint` subcommand.
//!
//! A dependency-free lint pass (hand-rolled lexer, no `syn` — the build
//! stays fully offline, same philosophy as [`crate::serve::json`]) that
//! scans `rust/src/**` and enforces the repo-specific concurrency and
//! panic-safety contracts that `clippy` cannot express:
//!
//! * **Atomic-ordering contract** (`MC-ORD1`/`MC-ORD2`) — accesses to
//!   cross-thread control flags must use `Acquire`/`Release`/`AcqRel`;
//!   `Ordering::Relaxed` is permitted only for sites justified in
//!   `analysis/allowlist.txt` (stat counters, the work-stealing index).
//! * **Panic-safety contract** (`MC-PANIC`, `MC-LOCK`) — no bare
//!   `unwrap()`/`expect()`/`panic!`/`unreachable!` in non-test code of
//!   the solve-path modules, and every `Mutex::lock()` outside tests
//!   routes through [`crate::util::lock_recover`].
//! * **Gate hygiene** (`MC-GATE-FP`, `MC-GATE-AUDIT`, `MC-CLOCK`) —
//!   failpoint and prop-audit machinery stays under its cfg gates, and
//!   the CP kernel's hot path never reads the OS clock outside the
//!   watchdog tick.
//!
//! Exit codes mirror `bench compare`: 0 clean, 1 violations, 2 usage
//! error. See `docs/CONCURRENCY.md` for the full contract tables and
//! how to extend the rules.

mod allowlist;
mod lexer;
mod rules;

pub use allowlist::{AllowEntry, Allowlist};
pub use rules::Violation;

use std::path::{Path, PathBuf};

/// Result of linting a source tree.
#[derive(Debug, Default)]
pub struct LintReport {
    /// Findings, sorted by (file, line, rule).
    pub violations: Vec<Violation>,
    /// Number of `.rs` files scanned.
    pub files_scanned: usize,
}

impl LintReport {
    /// True when no rule fired.
    pub fn is_clean(&self) -> bool {
        self.violations.is_empty()
    }
}

/// Collect every `.rs` file under `dir` (recursively), as paths
/// relative to `root`, sorted for deterministic reports. I/O errors on
/// individual entries are skipped — a lint must degrade, not crash.
fn collect_rs(root: &Path, dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    let mut paths: Vec<PathBuf> = entries.filter_map(|e| e.ok()).map(|e| e.path()).collect();
    paths.sort();
    for p in paths {
        if p.is_dir() {
            collect_rs(root, &p, out);
        } else if p.extension().is_some_and(|e| e == "rs") {
            if let Ok(rel) = p.strip_prefix(root) {
                out.push(rel.to_path_buf());
            }
        }
    }
}

/// Lint the tree rooted at `root` (typically `rust/src`) against
/// `allow`. Stale allowlist entries (matching no site in the tree) are
/// reported as `MC-ALLOW-STALE` violations so every exemption stays
/// load-bearing.
pub fn lint_tree(root: &Path, allow: &Allowlist) -> LintReport {
    let mut files: Vec<PathBuf> = Vec::new();
    collect_rs(root, root, &mut files);
    let mut used = vec![false; allow.len()];
    let mut violations: Vec<Violation> = Vec::new();
    let mut scanned = 0usize;
    for rel in &files {
        let Ok(src) = std::fs::read_to_string(root.join(rel)) else { continue };
        scanned += 1;
        // normalize separators so allowlist entries are portable
        let rel = rel.to_string_lossy().replace('\\', "/");
        let toks = lexer::lex(&src);
        violations.extend(rules::lint_file(&rel, &toks, allow, &mut used));
    }
    for (idx, entry) in allow.entries().iter().enumerate() {
        if !used[idx] {
            violations.push(Violation {
                rule: "MC-ALLOW-STALE",
                file: "analysis/allowlist.txt".to_string(),
                line: entry.line,
                msg: format!(
                    "allowlist entry matches no site: `{} {} {}` — {}",
                    entry.rule, entry.file, entry.atom, entry.why
                ),
                hint: "the code this exemption justified is gone; delete the entry",
                allow_key: None,
            });
        }
    }
    violations.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    LintReport { violations, files_scanned: scanned }
}

/// Resolve the source root: an explicit `--root`, else `rust/src` or
/// `src` relative to the working directory, else the build-time
/// manifest location (so `cargo run -- lint` works from anywhere).
pub fn resolve_root(explicit: Option<&str>) -> Option<PathBuf> {
    if let Some(r) = explicit {
        let p = PathBuf::from(r);
        return p.is_dir().then_some(p);
    }
    for cand in ["rust/src", "src"] {
        let p = PathBuf::from(cand);
        if p.join("lib.rs").is_file() || p.join("main.rs").is_file() {
            return Some(p);
        }
    }
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/src");
    p.is_dir().then_some(p)
}

/// Load `analysis/allowlist.txt` from under `root` (empty if absent —
/// the lint then simply reports every `Relaxed` site).
pub fn load_allowlist(root: &Path) -> Allowlist {
    match std::fs::read_to_string(root.join("analysis/allowlist.txt")) {
        Ok(text) => Allowlist::parse(&text),
        Err(_) => Allowlist::default(),
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render a report as a JSON object (uploaded as a CI artifact).
pub fn report_json(root: &Path, report: &LintReport) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{{\"root\": \"{}\", \"files_scanned\": {}, \"violations\": [",
        json_escape(&root.to_string_lossy()),
        report.files_scanned
    ));
    for (i, v) in report.violations.iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        out.push_str(&format!(
            "{{\"rule\": \"{}\", \"file\": \"{}\", \"line\": {}, \"msg\": \"{}\", \"hint\": \"{}\"}}",
            v.rule,
            json_escape(&v.file),
            v.line,
            json_escape(&v.msg),
            json_escape(v.hint)
        ));
    }
    out.push_str(&format!("], \"count\": {}}}", report.violations.len()));
    out
}

/// The `moccasin lint` entry point. Returns the process exit code:
/// 0 clean, 1 violations found, 2 usage/configuration error.
pub fn lint_main(args: &[String]) -> i32 {
    let mut json = false;
    let mut fix = false;
    let mut root_arg: Option<String> = None;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--json" => json = true,
            "--fix-allowlist" => fix = true,
            "--root" => match it.next() {
                Some(r) => root_arg = Some(r.clone()),
                None => {
                    eprintln!("lint: --root needs a directory argument");
                    return 2;
                }
            },
            other => {
                eprintln!("lint: unknown flag `{other}` (usage: moccasin lint [--json] [--fix-allowlist] [--root DIR])");
                return 2;
            }
        }
    }
    let Some(root) = resolve_root(root_arg.as_deref()) else {
        eprintln!("lint: could not locate the source tree (tried --root, rust/src, src)");
        return 2;
    };
    let allow = load_allowlist(&root);
    let report = lint_tree(&root, &allow);
    if fix && !report.is_clean() {
        return fix_allowlist(&root, &report);
    }
    if json {
        println!("{}", report_json(&root, &report));
    } else {
        for v in &report.violations {
            println!("{}:{}: [{}] {}", v.file, v.line, v.rule, v.msg);
            println!("    fix: {}", v.hint);
        }
        println!(
            "lint: {} file(s), {} allowlist entr(ies), {} violation(s)",
            report.files_scanned,
            allow.len(),
            report.violations.len()
        );
    }
    i32::from(!report.is_clean())
}

/// Append suggested allowlist entries (with TODO justifications) for
/// every exemptible violation, so a developer can fill in the *why*
/// rather than re-type the keys. Non-exemptible rules (gate hygiene,
/// hot-path clock, stale entries) still have to be fixed in code.
fn fix_allowlist(root: &Path, report: &LintReport) -> i32 {
    let path = root.join("analysis/allowlist.txt");
    let mut existing = std::fs::read_to_string(&path).unwrap_or_default();
    let mut added = 0usize;
    let mut seen: Vec<&str> = Vec::new();
    let mut remaining = 0usize;
    for v in &report.violations {
        match v.allow_key.as_deref() {
            Some(key) if !seen.contains(&key) => {
                seen.push(key);
                if !existing.ends_with('\n') && !existing.is_empty() {
                    existing.push('\n');
                }
                existing.push_str(key);
                existing.push_str(" — TODO: justify this exemption\n");
                added += 1;
            }
            Some(_) => {}
            None => remaining += 1,
        }
    }
    if let Err(e) = std::fs::write(&path, existing) {
        eprintln!("lint: cannot write {}: {e}", path.display());
        return 2;
    }
    println!(
        "lint: appended {added} suggested entr(ies) to {} — fill in the justifications; \
         {remaining} violation(s) are not exemptible and need code fixes",
        path.display()
    );
    1
}

#[cfg(test)]
mod tests {
    use super::*;

    fn repo_src() -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/src")
    }

    /// The tentpole acceptance test: the shipped tree is clean under
    /// the shipped allowlist.
    #[test]
    fn self_check_repo_tree_is_clean() {
        let root = repo_src();
        let allow = load_allowlist(&root);
        assert!(!allow.is_empty(), "allowlist must exist and be non-empty");
        let report = lint_tree(&root, &allow);
        let rendered: Vec<String> = report
            .violations
            .iter()
            .map(|v| format!("{}:{}: [{}] {}", v.file, v.line, v.rule, v.msg))
            .collect();
        assert!(report.is_clean(), "repo tree must lint clean:\n{}", rendered.join("\n"));
        assert!(report.files_scanned > 30, "expected to scan the full tree");
    }

    /// Deleting any single allowlist line flips the tree to dirty:
    /// either the exempted site fires, or (for a hypothetical unused
    /// entry) staleness would have fired *before* deletion — both ways,
    /// every line is load-bearing.
    #[test]
    fn every_allowlist_line_is_load_bearing() {
        let root = repo_src();
        let full = load_allowlist(&root);
        let text = std::fs::read_to_string(root.join("analysis/allowlist.txt"))
            .expect("allowlist readable");
        let entry_count = full.len();
        for drop_idx in 0..entry_count {
            let mut kept = 0usize;
            let reduced: String = text
                .lines()
                .filter(|l| {
                    let is_entry = !l.trim().is_empty() && !l.trim().starts_with('#');
                    if is_entry {
                        kept += 1;
                        kept - 1 != drop_idx
                    } else {
                        true
                    }
                })
                .map(|l| format!("{l}\n"))
                .collect();
            let allow = Allowlist::parse(&reduced);
            assert_eq!(allow.len(), entry_count - 1);
            let report = lint_tree(&root, &allow);
            assert!(
                !report.is_clean(),
                "deleting allowlist entry #{drop_idx} ({:?}) left the tree clean — stale entry?",
                full.entries()[drop_idx]
            );
        }
    }

    /// Injecting a fixture violation into a scanned copy of a file is
    /// reported with the exact file, line, and rule id.
    #[test]
    fn injected_violation_names_exact_site() {
        let tmp = std::env::temp_dir().join(format!("moccasin-lint-{}", std::process::id()));
        let serve = tmp.join("serve");
        std::fs::create_dir_all(&serve).expect("mkdir");
        std::fs::write(
            serve.join("bad.rs"),
            "fn ok() -> u32 { 1 }\nfn f(x: Option<u32>) -> u32 { x.unwrap() }\nfn g(a: &A) { a.shutdown.store(true, Ordering::Relaxed); }\n",
        )
        .expect("write fixture");
        let report = lint_tree(&tmp, &Allowlist::default());
        let have: Vec<(String, u32, &str)> = report
            .violations
            .iter()
            .map(|v| (v.file.clone(), v.line, v.rule))
            .collect();
        assert!(
            have.contains(&("serve/bad.rs".to_string(), 2, "MC-PANIC")),
            "expected serve/bad.rs:2 MC-PANIC, got {have:?}"
        );
        assert!(
            have.contains(&("serve/bad.rs".to_string(), 3, "MC-ORD2")),
            "expected serve/bad.rs:3 MC-ORD2, got {have:?}"
        );
        let _ = std::fs::remove_dir_all(&tmp);
    }

    /// Stale entries are themselves violations.
    #[test]
    fn stale_allowlist_entry_is_flagged() {
        let tmp = std::env::temp_dir().join(format!("moccasin-lint-stale-{}", std::process::id()));
        std::fs::create_dir_all(&tmp).expect("mkdir");
        std::fs::write(tmp.join("clean.rs"), "fn ok() -> u32 { 1 }\n").expect("write");
        let allow = Allowlist::parse("relaxed clean.rs nothing — entry with no matching site\n");
        let report = lint_tree(&tmp, &allow);
        assert_eq!(report.violations.len(), 1);
        assert_eq!(report.violations[0].rule, "MC-ALLOW-STALE");
        assert_eq!(report.violations[0].line, 1);
        let _ = std::fs::remove_dir_all(&tmp);
    }

    #[test]
    fn json_output_is_parseable_by_the_in_tree_parser() {
        let root = repo_src();
        let allow = load_allowlist(&root);
        let report = lint_tree(&root, &allow);
        let js = report_json(&root, &report);
        let parsed = crate::serve::json::parse(&js).expect("lint --json must be valid JSON");
        let crate::serve::json::Json::Obj(members) = parsed else {
            panic!("expected an object")
        };
        assert!(members.iter().any(|(k, _)| k == "violations"));
        assert!(members.iter().any(|(k, _)| k == "count"));
    }

    #[test]
    fn exit_code_semantics() {
        // unknown flag → usage
        assert_eq!(lint_main(&["--bogus".to_string()]), 2);
        // missing --root argument → usage
        assert_eq!(lint_main(&["--root".to_string()]), 2);
    }
}
