//! The lint rules: scope annotation (what is test-gated, what function
//! encloses a token) and the per-file rule pass.
//!
//! Rules enforced (see `docs/CONCURRENCY.md` for the contracts):
//!
//! | id              | contract |
//! |-----------------|----------|
//! | `MC-ORD1`       | `Ordering::Relaxed` on a non-control atomic must be justified in the allowlist |
//! | `MC-ORD2`       | `Ordering::Relaxed` on a cross-thread control flag (`cancelled`, `shutdown`, …) |
//! | `MC-PANIC`      | bare `unwrap()` / `expect()` / `panic!` / `unreachable!` in solve-path non-test code |
//! | `MC-LOCK`       | raw `Mutex::lock()` outside `lock_recover` in non-test code |
//! | `MC-GATE-FP`    | `failpoint` API call outside its cfg gate |
//! | `MC-GATE-AUDIT` | prop-audit identifier in unguarded `cp/` code |
//! | `MC-CLOCK`      | `Instant::now()` in `cp/` hot-path code outside `watchdog_tick` |

use super::allowlist::Allowlist;
use super::lexer::{Kind, Tok};

/// Atomic-access method names that take an `Ordering` argument.
const ATOMIC_METHODS: &[&str] = &[
    "load",
    "store",
    "swap",
    "compare_exchange",
    "compare_exchange_weak",
    "fetch_add",
    "fetch_sub",
    "fetch_and",
    "fetch_or",
    "fetch_xor",
    "fetch_min",
    "fetch_max",
    "fetch_update",
    "fetch_nand",
];

/// Cross-thread control flags: `Relaxed` on these is a correctness bug
/// (`MC-ORD2`), not a stat-counter judgement call (`MC-ORD1`).
const CONTROL_FLAGS: &[&str] = &[
    "cancelled",
    "preempted",
    "finished",
    "shutdown",
    "stop",
    "proved",
    "client_cancel",
    "armed",
    "joined",
    "progress",
    "beat",
    "epoch",
];

/// Cfg feature names that count as test gates for rule exemption.
const GATE_FEATURES: &[&str] = &["failpoints", "prop-audit"];

/// One lint finding.
#[derive(Debug, Clone)]
pub struct Violation {
    /// Rule id (`MC-ORD1`, `MC-PANIC`, …).
    pub rule: &'static str,
    /// Path relative to the scanned source root.
    pub file: String,
    /// 1-based line.
    pub line: u32,
    /// What was found.
    pub msg: String,
    /// How to fix it.
    pub hint: &'static str,
    /// Ready-made allowlist key (`<rule-key> <file> <atom-or-fn>`) for
    /// `--fix-allowlist`, when the rule supports exemptions.
    pub allow_key: Option<String>,
}

/// Does the token stream of `#[ … ]` describe a test/feature gate?
///
/// `#[test]`, `#[cfg(test)]`, `#[cfg(miri)]`, and `#[cfg(any(test,
/// feature = "failpoints"))]`-style attributes gate their item;
/// `#[cfg(not(…))]` does not (that is the *non*-test branch even when
/// the ident `test` appears inside).
fn attr_is_test_gate(attr: &[Tok]) -> bool {
    let idents: Vec<&str> =
        attr.iter().filter(|t| t.kind == Kind::Ident).map(|t| t.text.as_str()).collect();
    let strs: Vec<&str> = attr
        .iter()
        .filter(|t| t.kind == Kind::Str)
        .map(|t| t.text.trim_matches('"'))
        .collect();
    let Some(&first) = idents.first() else { return false };
    if first == "cfg" || first == "cfg_attr" {
        if idents.get(1) == Some(&"not") {
            return false;
        }
        return idents.contains(&"test")
            || idents.contains(&"miri")
            || strs.iter().any(|s| GATE_FEATURES.contains(s));
    }
    first == "test"
}

/// Per-token scope: is it inside test-gated code, and which named
/// function encloses it (closures inherit the nearest named fn)?
struct Scopes {
    /// Parallel to the token stream: (test_gated, index into `names`).
    ann: Vec<(bool, Option<usize>)>,
    /// Interned enclosing-function names.
    names: Vec<String>,
}

impl Scopes {
    fn fn_name(&self, tok_idx: usize) -> Option<&str> {
        self.ann.get(tok_idx).and_then(|&(_, f)| f).map(|i| self.names[i].as_str())
    }
    fn gated(&self, tok_idx: usize) -> bool {
        self.ann.get(tok_idx).is_some_and(|&(g, _)| g)
    }
}

/// Annotate every token with its scope. Brace-depth driven: a `{`
/// pushes (pending attribute gate, pending `fn` name); `}` pops; a `;`
/// before any `{` clears pending state (gated `use` items, bodyless
/// `fn` declarations). Tokens *between* a gating attribute and its `{`
/// are treated as gated too, which covers attributes on statements
/// (`#[cfg(…)] if failpoint::hit(…) { … }`).
fn annotate(toks: &[Tok]) -> Scopes {
    let n = toks.len();
    let mut ann = Vec::with_capacity(n);
    let mut names: Vec<String> = Vec::new();
    let mut stack: Vec<(bool, Option<usize>)> = Vec::new();
    let mut pending_test = false;
    let mut pending_fn: Option<usize> = None;
    let mut i = 0usize;
    while i < n {
        let t = &toks[i];
        // attribute: collect tokens inside #[ … ] at matching depth
        if t.kind == Kind::Punct && t.text == "#" && toks.get(i + 1).is_some_and(|b| b.text == "[")
        {
            let mut j = i + 2;
            let mut depth = 1usize;
            let mut attr: Vec<Tok> = Vec::new();
            while j < n && depth > 0 {
                if toks[j].kind == Kind::Punct && toks[j].text == "[" {
                    depth += 1;
                } else if toks[j].kind == Kind::Punct && toks[j].text == "]" {
                    depth -= 1;
                }
                if depth > 0 {
                    attr.push(toks[j].clone());
                }
                j += 1;
            }
            if attr_is_test_gate(&attr) {
                pending_test = true;
            }
            let gated = stack.iter().any(|&(g, _)| g) || pending_test;
            let f = stack.iter().rev().find_map(|&(_, f)| f);
            for _ in i..j {
                ann.push((gated, f));
            }
            i = j;
            continue;
        }
        let gated = stack.iter().any(|&(g, _)| g) || pending_test;
        let f = stack.iter().rev().find_map(|&(_, f)| f);
        ann.push((gated, f));
        if t.kind == Kind::Ident && t.text == "fn" {
            if let Some(next) = toks.get(i + 1) {
                if next.kind == Kind::Ident {
                    names.push(next.text.clone());
                    pending_fn = Some(names.len() - 1);
                }
            }
        } else if t.kind == Kind::Punct && t.text == "{" {
            stack.push((pending_test, pending_fn));
            pending_test = false;
            pending_fn = None;
        } else if t.kind == Kind::Punct && t.text == "}" {
            stack.pop();
        } else if t.kind == Kind::Punct && t.text == ";" {
            // item-level `;` with no body: the pending gate/fn is spent
            pending_test = false;
            pending_fn = None;
        }
        i += 1;
    }
    Scopes { ann, names }
}

/// For the `Ordering` ident of an `Ordering::Relaxed` argument, walk
/// back to the enclosing call and return `(method, receiver_atom)` —
/// e.g. `self.stats.cancelled.load(Ordering::Relaxed)` yields
/// `("load", "cancelled")`. The atom is lowercased so `SHUTDOWN` /
/// `shutdown` match the same contract entry.
fn receiver_atom(toks: &[Tok], ord_idx: usize) -> (Option<String>, Option<String>) {
    // back to the call's `(` at depth 0
    let mut i = ord_idx;
    let mut depth = 0i32;
    let mut open: Option<usize> = None;
    while i > 0 {
        i -= 1;
        let t = &toks[i];
        if t.kind == Kind::Punct {
            match t.text.as_str() {
                ")" | "]" | "}" => depth += 1,
                "(" | "[" | "{" => {
                    if depth == 0 {
                        open = Some(i);
                        break;
                    }
                    depth -= 1;
                }
                _ => {}
            }
        }
    }
    let Some(open) = open else { return (None, None) };
    if toks[open].text != "(" || open == 0 {
        return (None, None);
    }
    let mi = open - 1;
    if toks[mi].kind != Kind::Ident {
        return (None, None);
    }
    let method = toks[mi].text.clone();
    if mi == 0 || toks[mi - 1].text != "." {
        return (Some(method), None);
    }
    // receiver: last ident before the `.` at depth 0 (skipping any
    // bracketed index/call expressions)
    let mut ri = mi - 1;
    let mut depth = 0i32;
    while ri > 0 {
        ri -= 1;
        let t = &toks[ri];
        if t.kind == Kind::Punct {
            match t.text.as_str() {
                ")" | "]" | "}" => depth += 1,
                "(" | "[" | "{" => {
                    depth -= 1;
                    if depth < 0 {
                        return (Some(method), None);
                    }
                }
                _ => {}
            }
        } else if depth == 0 && t.kind == Kind::Ident {
            return (Some(method), Some(t.text.to_lowercase()));
        }
    }
    (Some(method), None)
}

/// Is `rel` inside a solve-path module (where the panic-safety rule
/// applies)? The lint's own tree is included — the lint lints the lint.
fn solve_path(rel: &str) -> bool {
    ["cp/", "coordinator/", "serve/", "moccasin/", "checkmate/", "analysis/"]
        .iter()
        .any(|p| rel.starts_with(p))
}

/// Run every rule over one file. `used` collects the indices of
/// allowlist entries that matched a site (for staleness reporting).
pub fn lint_file(
    rel: &str,
    toks: &[Tok],
    allow: &Allowlist,
    used: &mut Vec<bool>,
) -> Vec<Violation> {
    let scopes = annotate(toks);
    let mut out: Vec<Violation> = Vec::new();
    let n = toks.len();
    let txt = |i: usize| toks.get(i).map(|t| t.text.as_str()).unwrap_or("");
    for i in 0..n {
        if scopes.gated(i) {
            continue;
        }
        let t = &toks[i];
        if t.kind != Kind::Ident {
            continue;
        }
        let fname = scopes.fn_name(i).unwrap_or("<item>");
        let prev = if i > 0 { txt(i - 1) } else { "" };
        let (nxt, nxt2) = (txt(i + 1), txt(i + 2));

        // ---- atomic ordering contract ----
        if t.text == "Relaxed" && prev == ":" && i >= 3 && txt(i - 3) == "Ordering" {
            let (method, atom) = receiver_atom(toks, i - 3);
            let method = method.unwrap_or_default();
            // Only `Ordering`-taking atomic ops are in scope; anything
            // else named `Relaxed` (none in-tree) would be noise.
            if ATOMIC_METHODS.contains(&method.as_str()) {
                let atom = atom.unwrap_or_else(|| fname.to_lowercase());
                match allow.lookup("relaxed", rel, &atom) {
                    Some(idx) => used[idx] = true,
                    None => {
                        let control = CONTROL_FLAGS.contains(&atom.as_str());
                        out.push(Violation {
                            rule: if control { "MC-ORD2" } else { "MC-ORD1" },
                            file: rel.to_string(),
                            line: t.line,
                            msg: format!(
                                "Ordering::Relaxed on `{atom}` (via `{method}`) in fn {fname}"
                            ),
                            hint: if control {
                                "control flag: use Acquire (load) / Release (store) / AcqRel (RMW)"
                            } else {
                                "upgrade the ordering, or justify the site in \
                                 analysis/allowlist.txt (`relaxed <file> <atom> — why`)"
                            },
                            allow_key: Some(format!("relaxed {rel} {atom}")),
                        });
                    }
                }
            }
        }

        // ---- panic safety (solve-path modules only) ----
        if solve_path(rel) {
            let bare_unwrap = t.text == "unwrap" && prev == "." && nxt == "(" && nxt2 == ")";
            let bare_expect = t.text == "expect" && prev == "." && nxt == "(";
            let panic_macro = matches!(
                t.text.as_str(),
                "panic" | "unreachable" | "todo" | "unimplemented"
            ) && nxt == "!";
            if bare_unwrap || bare_expect || panic_macro {
                match allow.lookup("panic", rel, fname) {
                    Some(idx) => used[idx] = true,
                    None => out.push(Violation {
                        rule: "MC-PANIC",
                        file: rel.to_string(),
                        line: t.line,
                        msg: format!("`{}` in non-test fn {fname}", t.text),
                        hint: "return a structured error / restructure the guard away, or \
                               justify the fn in analysis/allowlist.txt (`panic <file> <fn> — why`)",
                        allow_key: Some(format!("panic {rel} {fname}")),
                    }),
                }
            }
        }

        // ---- mutex discipline ----
        if t.text == "lock"
            && prev == "."
            && nxt == "("
            && nxt2 == ")"
            && fname != "lock_recover"
        {
            match allow.lookup("lock", rel, fname) {
                Some(idx) => used[idx] = true,
                None => out.push(Violation {
                    rule: "MC-LOCK",
                    file: rel.to_string(),
                    line: t.line,
                    msg: format!("raw Mutex::lock() in fn {fname}"),
                    hint: "route through util::lock_recover (poison-recovering, counted)",
                    allow_key: Some(format!("lock {rel} {fname}")),
                }),
            }
        }

        // ---- failpoint gate hygiene ----
        if t.text == "failpoint"
            && nxt == ":"
            && nxt2 == ":"
            && rel != "util/failpoint.rs"
            && matches!(txt(i + 3), "hit" | "arm" | "disarm" | "reset" | "fired")
        {
            out.push(Violation {
                rule: "MC-GATE-FP",
                file: rel.to_string(),
                line: t.line,
                msg: format!("ungated failpoint::{} call in fn {fname}", txt(i + 3)),
                hint: "wrap the site in #[cfg(any(test, feature = \"failpoints\"))]",
                allow_key: None,
            });
        }

        // ---- prop-audit gate hygiene ----
        if rel.starts_with("cp/") && (t.text.starts_with("audit_") || t.text.starts_with("AUDIT_"))
        {
            out.push(Violation {
                rule: "MC-GATE-AUDIT",
                file: rel.to_string(),
                line: t.line,
                msg: format!("ungated audit ident `{}` in fn {fname}", t.text),
                hint: "the explanation audit must sit under cfg(any(test, \
                       feature = \"prop-audit\"))",
                allow_key: None,
            });
        }

        // ---- hot-path clock ----
        if rel.starts_with("cp/")
            && t.text == "Instant"
            && nxt == ":"
            && nxt2 == ":"
            && txt(i + 3) == "now"
            && fname != "watchdog_tick"
        {
            out.push(Violation {
                rule: "MC-CLOCK",
                file: rel.to_string(),
                line: t.line,
                msg: format!("Instant::now() in cp/ fn {fname}"),
                hint: "hot loops poll the watchdog's cached tick instead of the OS clock",
                allow_key: None,
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::allowlist::Allowlist;
    use super::super::lexer::lex;
    use super::*;

    fn run(rel: &str, src: &str, allow: &Allowlist) -> Vec<Violation> {
        let mut used = vec![false; allow.len()];
        lint_file(rel, &lex(src), allow, &mut used)
    }

    fn empty() -> Allowlist {
        Allowlist::parse("")
    }

    // ---- MC-ORD1 / MC-ORD2 ----

    #[test]
    fn relaxed_on_control_flag_violates() {
        let src = "fn f(a: &AtomicBool) { a.shutdown.load(Ordering::Relaxed); }";
        let v = run("serve/mod.rs", src, &empty());
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "MC-ORD2");
        assert_eq!(v[0].line, 1);
        assert!(v[0].msg.contains("shutdown"));
    }

    #[test]
    fn acquire_on_control_flag_conforms() {
        let src = "fn f(a: &Inner) { a.shutdown.load(Ordering::Acquire); }";
        assert!(run("serve/mod.rs", src, &empty()).is_empty());
    }

    #[test]
    fn relaxed_counter_is_ord1_and_allowlistable() {
        let src = "fn f(s: &Stats) { s.cache_hits.fetch_add(1, Ordering::Relaxed); }";
        let v = run("serve/mod.rs", src, &empty());
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "MC-ORD1");
        let allow =
            Allowlist::parse("relaxed serve/mod.rs cache_hits — monotone stat counter\n");
        assert!(run("serve/mod.rs", src, &allow).is_empty());
    }

    #[test]
    fn relaxed_in_test_code_is_exempt() {
        let src = "#[cfg(test)]\nmod tests {\n fn f(a: &A) { a.stop.store(true, Ordering::Relaxed); }\n}";
        assert!(run("serve/mod.rs", src, &empty()).is_empty());
    }

    #[test]
    fn cfg_not_is_not_a_gate() {
        let src = "#[cfg(not(any(test, feature = \"failpoints\")))]\nfn f(a: &A) { a.stop.load(Ordering::Relaxed); }";
        let v = run("serve/mod.rs", src, &empty());
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "MC-ORD2");
    }

    // ---- MC-PANIC ----

    #[test]
    fn bare_unwrap_in_solve_path_violates() {
        let src = "fn f(x: Option<u32>) -> u32 { x.unwrap() }";
        let v = run("serve/queue.rs", src, &empty());
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "MC-PANIC");
        assert_eq!(v[0].line, 1);
        assert!(v[0].msg.contains("fn f"));
    }

    #[test]
    fn unwrap_outside_solve_path_conforms() {
        let src = "fn f(x: Option<u32>) -> u32 { x.unwrap() }";
        assert!(run("bench/mod.rs", src, &empty()).is_empty());
    }

    #[test]
    fn unwrap_in_test_fn_conforms() {
        let src = "#[test]\nfn t() { Some(1).unwrap(); }";
        assert!(run("serve/queue.rs", src, &empty()).is_empty());
    }

    #[test]
    fn expect_and_panic_macros_violate() {
        let src = "fn f(x: Option<u32>) { x.expect(\"boom\"); }\nfn g() { panic!(\"no\"); }\nfn h() { unreachable!() }";
        let v = run("cp/engine.rs", src, &empty());
        assert_eq!(v.len(), 3, "{v:?}");
        assert!(v.iter().all(|v| v.rule == "MC-PANIC"));
        assert_eq!(v[1].line, 2);
    }

    #[test]
    fn unwrap_or_variants_conform() {
        let src = "fn f(x: Option<u32>) -> u32 { x.unwrap_or(0) + x.unwrap_or_default() }";
        assert!(run("cp/engine.rs", src, &empty()).is_empty());
    }

    #[test]
    fn panic_allowlisted_by_fn_name() {
        let src = "fn assign(&mut self, v: i64) { self.x.expect(\"in domain\"); }";
        let allow = Allowlist::parse("panic cp/domain.rs assign — caller-proven invariant\n");
        assert!(run("cp/domain.rs", src, &allow).is_empty());
    }

    // ---- MC-LOCK ----

    #[test]
    fn raw_lock_violates_and_lock_recover_body_is_exempt() {
        let bad = "fn f(m: &Mutex<u32>) { let g = m.lock().unwrap(); }";
        let v = run("coordinator/mod.rs", bad, &empty());
        assert!(v.iter().any(|v| v.rule == "MC-LOCK"), "{v:?}");
        let good = "pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> { m.lock().unwrap_or_else(|p| p.into_inner()) }";
        assert!(run("util/mod.rs", good, &empty()).is_empty());
    }

    // ---- MC-GATE-FP ----

    #[test]
    fn ungated_failpoint_call_violates_and_gated_conforms() {
        let bad = "fn f() { crate::util::failpoint::reset(); }";
        let v = run("bench/serve.rs", bad, &empty());
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "MC-GATE-FP");
        let good = "fn f() {\n #[cfg(any(test, feature = \"failpoints\"))]\n crate::util::failpoint::reset();\n}";
        assert!(run("bench/serve.rs", good, &empty()).is_empty());
    }

    // ---- MC-CLOCK ----

    #[test]
    fn instant_now_in_cp_violates_outside_watchdog_tick() {
        let bad = "fn hot() { let t = Instant::now(); }";
        let v = run("cp/engine.rs", bad, &empty());
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "MC-CLOCK");
        let good = "fn watchdog_tick() { let t = Instant::now(); }";
        assert!(run("cp/engine.rs", good, &empty()).is_empty());
        // outside cp/ the clock is free
        assert!(run("serve/mod.rs", bad, &empty()).is_empty());
    }

    // ---- scope tracking corner cases ----

    #[test]
    fn attribute_on_statement_gates_its_tokens() {
        let src = "fn f() {\n #[cfg(any(test, feature = \"failpoints\"))]\n if crate::util::failpoint::hit(\"x\").is_some() { return; }\n}";
        assert!(run("coordinator/mod.rs", src, &empty()).is_empty());
    }

    #[test]
    fn closure_inherits_enclosing_fn_name() {
        let src = "fn lock_recover(m: &M) { m.with(|| { m.lock() }); }";
        // `.lock()` inside the closure is still inside fn lock_recover
        assert!(run("util/mod.rs", src, &empty()).is_empty());
    }
}
