//! Logical presolve for 0–1 linear constraint matrices (the CHECKMATE
//! baseline): fixed-variable substitution, forced fixings from
//! singleton and forcing rows, and vacuous-row elimination — iterated
//! to a fixpoint. Everything here is *exact* for binary variables, so
//! the reduced MILP has the same feasible set over the unfixed
//! variables and the same optimum; CHECKMATE's optimality and
//! infeasibility proofs remain valid.

/// Result of [`reduce_rows`].
#[derive(Debug, Default)]
pub struct RowReduction {
    /// Per-variable root fixing (`None` = still free).
    pub fixed: Vec<Option<i64>>,
    /// Rows before reduction.
    pub rows_before: u64,
    /// Rows remaining after reduction.
    pub rows_after: u64,
    /// Number of variables fixed.
    pub vars_fixed: u64,
    /// The reduction proved the system infeasible (conflicting forced
    /// fixings or a row whose minimum activity exceeds its rhs).
    pub infeasible: bool,
}

/// Reduce `rows` (each `Σ cᵢ·xᵢ ≤ rhs` over binary `xᵢ`) in place.
///
/// Per pass, for every row: substitute already-fixed variables into the
/// rhs; drop the row if its maximum activity can no longer exceed the
/// rhs (vacuous); flag infeasibility if its minimum activity already
/// does; fix the variable of a binding singleton row; and when the
/// minimum activity *equals* the rhs, fix every remaining variable at
/// its minimizing value (forcing row). Passes repeat until no new
/// variable gets fixed.
pub fn reduce_rows(nvars: usize, rows: &mut Vec<(Vec<(i64, u32)>, i64)>) -> RowReduction {
    let mut red = RowReduction {
        fixed: vec![None; nvars],
        rows_before: rows.len() as u64,
        ..Default::default()
    };
    // set a fixing, detecting conflicts with earlier fixings
    fn fix(fixed: &mut [Option<i64>], v: u32, val: i64, infeasible: &mut bool) -> bool {
        match fixed[v as usize] {
            Some(old) if old != val => {
                *infeasible = true;
                false
            }
            Some(_) => false,
            None => {
                fixed[v as usize] = Some(val);
                true
            }
        }
    }
    loop {
        let mut progressed = false;
        let mut out: Vec<(Vec<(i64, u32)>, i64)> = Vec::with_capacity(rows.len());
        for (row, mut rhs) in rows.drain(..) {
            // substitute fixed variables; zero-coefficient terms are
            // dropped outright (a forcing row must never "fix" a
            // variable the row does not actually constrain)
            let mut kept: Vec<(i64, u32)> = Vec::with_capacity(row.len());
            for (c, v) in row {
                if c == 0 {
                    continue;
                }
                match red.fixed[v as usize] {
                    Some(val) => rhs -= c * val,
                    None => kept.push((c, v)),
                }
            }
            let max_act: i64 = kept.iter().map(|&(c, _)| c.max(0)).sum();
            let min_act: i64 = kept.iter().map(|&(c, _)| c.min(0)).sum();
            if min_act > rhs {
                red.infeasible = true;
                break; // remaining drained rows are irrelevant now
            }
            if max_act <= rhs {
                continue; // vacuous under the box [0,1]^n
            }
            if kept.len() == 1 {
                // singleton c·x ≤ rhs that is not vacuous: it binds
                let (c, v) = kept[0];
                let val = if c > 0 { 0 } else { 1 };
                progressed |= fix(&mut red.fixed, v, val, &mut red.infeasible);
                continue;
            }
            if min_act == rhs {
                // forcing row: every variable must sit at its minimizer
                for &(c, v) in &kept {
                    let val = if c > 0 { 0 } else { 1 };
                    progressed |= fix(&mut red.fixed, v, val, &mut red.infeasible);
                }
                continue;
            }
            out.push((kept, rhs));
        }
        *rows = out;
        if !progressed || red.infeasible {
            break;
        }
    }
    red.rows_after = rows.len() as u64;
    red.vars_fixed = red.fixed.iter().flatten().count() as u64;
    red
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixes_checkmate_style_diagonal_rows() {
        // -x0 ≤ -1 (forces x0 = 1), then x1 - x0 ≤ 0 becomes x1 ≤ 1:
        // vacuous
        let mut rows = vec![
            (vec![(-1, 0)], -1),
            (vec![(1, 1), (-1, 0)], 0),
        ];
        let r = reduce_rows(2, &mut rows);
        assert!(!r.infeasible);
        assert_eq!(r.fixed[0], Some(1));
        assert_eq!(r.fixed[1], None);
        assert_eq!(r.vars_fixed, 1);
        assert_eq!(r.rows_before, 2);
        assert_eq!(r.rows_after, 0);
        assert!(rows.is_empty());
    }

    #[test]
    fn cascaded_fixings_reach_fixpoint() {
        // x0 = 1 forces x1 = 1 (x0 - x1 ≤ 0 with x0 = 1 → -x1 ≤ -1),
        // which forces x2 = 0 (x1 + x2 ≤ 1)
        let mut rows = vec![
            (vec![(-1, 0)], -1),
            (vec![(1, 0), (-1, 1)], 0),
            (vec![(1, 1), (1, 2)], 1),
        ];
        let r = reduce_rows(3, &mut rows);
        assert!(!r.infeasible);
        assert_eq!(r.fixed, vec![Some(1), Some(1), Some(0)]);
        assert!(rows.is_empty());
    }

    #[test]
    fn forcing_row_fixes_all_terms() {
        // -x0 - x1 ≤ -2 → both must be 1
        let mut rows = vec![(vec![(-1, 0), (-1, 1)], -2)];
        let r = reduce_rows(2, &mut rows);
        assert!(!r.infeasible);
        assert_eq!(r.fixed, vec![Some(1), Some(1)]);
    }

    #[test]
    fn detects_infeasibility() {
        // x0 = 1 and x0 = 0 conflict
        let mut rows = vec![(vec![(-1, 0)], -1), (vec![(1, 0)], 0)];
        let r = reduce_rows(1, &mut rows);
        assert!(r.infeasible);
    }

    #[test]
    fn min_activity_conflict_is_infeasible() {
        // -x0 ≤ -2 can never hold for binary x0
        let mut rows = vec![(vec![(-1, 0)], -2)];
        let r = reduce_rows(1, &mut rows);
        assert!(r.infeasible);
    }

    #[test]
    fn zero_coefficient_terms_are_never_forced() {
        // 0·x0 - x1 ≤ -1 forces x1 = 1 but must not touch x0, and
        // x0 ≤ 0 then fixes x0 = 0 without any conflict
        let mut rows = vec![(vec![(0, 0), (-1, 1)], -1), (vec![(1, 0)], 0)];
        let r = reduce_rows(2, &mut rows);
        assert!(!r.infeasible, "feasible system (x0=0, x1=1) flagged infeasible");
        assert_eq!(r.fixed, vec![Some(0), Some(1)]);
    }

    #[test]
    fn keeps_genuinely_binding_rows() {
        // x0 + x1 ≤ 1 is neither vacuous nor forcing: kept as-is
        let mut rows = vec![(vec![(1, 0), (1, 1)], 1)];
        let r = reduce_rows(2, &mut rows);
        assert!(!r.infeasible);
        assert_eq!(rows.len(), 1);
        assert_eq!(r.rows_after, 1);
        assert_eq!(r.vars_fixed, 0);
    }
}
