//! Root presolve and model compaction: shrink the CP problem before the
//! engine ever sees it.
//!
//! PR 2 made *propagation* fast; this layer makes the *problem* small.
//! Every solve path — exact B&B, LNS window re-solves, portfolio
//! members, the CHECKMATE MILP — runs the presolve at the root, before
//! any propagator is constructed:
//!
//! * **Structural constraint elimination** (always exact). The staged
//!   formulation fixes copy 0's start, so its interval-validity
//!   constraint (2) is implied by the end variable's domain lower
//!   bound, the copy-ordering implication `a¹ → a⁰` is vacuous
//!   (`a⁰ ≡ 1`), and the pair of ordering constraints (3) collapses
//!   into one strict constraint `aⁱ⁺¹ → eⁱ + 1 ≤ sⁱ⁺¹` (exact because
//!   a minimal-end solution always separates consecutive copies; see
//!   `StagedModel::build_with` for the argument).
//! * **Cover compaction** (always exact). One multi-target [`Cover`]
//!   propagator per precedence edge replaces the per-consumer-copy
//!   clones, and candidate lists are shared slices — the propagator
//!   count drops from `Σ_edges C_v` to `m`.
//! * **Liveness-derived bounds tightening** (always exact). Reverse
//!   reachability over the input order yields, per node, the latest
//!   event at which any consumer copy can still start
//!   ([`StagedCaps::latest_use`]); retention-interval ends are capped
//!   there, recompute-copy start domains are capped at the last stage
//!   that can still cover a use, and sink intervals are pinned to their
//!   compute event. For the unstaged model, ancestor/descendant counts
//!   give topological-depth lower bounds and reverse-reachability upper
//!   bounds on starts.
//! * **Dominance fixing** (always exact). Copies whose earliest
//!   possible start lies at or beyond every possible use of the node can
//!   never pay for themselves — they are never built (a solution using
//!   such a copy maps to a strictly cheaper one without it, shifting
//!   later copies down; see `StagedModel::build_with`).
//! * **Transitive reduction** ([`PresolveLevel::Aggressive`] only).
//!   Covers for transitively redundant edges are dropped. This is a
//!   *relaxation* under the Appendix-A.3 memory semantics — a redundant
//!   edge is still a real data dependency, so the cumulative may
//!   undercount — and therefore never part of the default: emitted
//!   solutions are still eval-validated, but optimality/infeasibility
//!   proofs no longer transfer ([`Presolve::exactness_preserving`]).
//! * **Retention-length cap** (`--max-interval-len`, opt-in). The
//!   paper's §3 search-space reduction `e − s ≤ L`; near-optimal in the
//!   paper's experiments but not exactness-preserving, so off by
//!   default.
//! * **Disjunctive (heavy-clique) detection** (always exact — the
//!   emitted constraint is *redundant*). Cumulative items whose demand
//!   exceeds half the budget (`2·demand > cap`) pairwise overload it,
//!   so their active intervals must be pairwise disjoint; when at least
//!   two such items exist, [`detect_serialized_clique`] yields a
//!   [`Disjunctive`] constraint over them, giving the engine pairwise
//!   order filtering the timetable cannot see. Characteristic of the
//!   paper's tight-budget regimes, where the largest tensors
//!   effectively serialize.
//! * **MILP row reduction** ([`reduce_rows`], always exact). Fixed-
//!   variable substitution, forced singleton/forcing-row fixings and
//!   vacuous-row elimination on the CHECKMATE constraint matrix.
//!
//! The expensive, order-independent graph analysis (reachability
//! bitsets, transitive reduction, ancestor/descendant counts) is
//! computed once per graph and shared across racing portfolio members
//! and every LNS window re-solve via `Arc<GraphAnalysis>`.
//!
//! [`Cover`]: crate::cp::Propagator
//! [`Disjunctive`]: crate::cp::Propagator

mod analysis;
mod milp;

pub use analysis::{staged_caps, GraphAnalysis, StagedCaps};
pub use milp::{reduce_rows, RowReduction};

use crate::cp::{CumItem, DisjItem};
use crate::graph::Graph;
use std::sync::Arc;

/// Detect the "heavy clique" of a cumulative constraint: the items
/// whose demand alone exceeds half the capacity, so any two of them
/// overloaded it together — their active intervals must be pairwise
/// disjoint. Returns the clique as [`DisjItem`]s when it has at least
/// two members (a single heavy item serializes with nothing), empty
/// otherwise. Zero-demand items never qualify, and with `cap ≤ 0` the
/// test `2·demand > cap` admits every positive-demand item — which is
/// still correct: any two of them exceed a non-positive budget.
///
/// The emitted constraint is redundant with the cumulative it was
/// detected in, so posting it is exactness-preserving at any
/// [`PresolveLevel`]; it exists purely to give the engine pairwise
/// order filtering (see `cp::disjunctive`).
pub fn detect_serialized_clique(items: &[CumItem], cap: i64) -> Vec<DisjItem> {
    let heavy: Vec<DisjItem> = items
        .iter()
        .filter(|it| it.demand > 0 && 2 * it.demand > cap)
        .map(|it| DisjItem { active: it.active, start: it.start, end: it.end })
        .collect();
    if heavy.len() >= 2 {
        heavy
    } else {
        Vec::new()
    }
}

/// How aggressively presolve may transform the model.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PresolveLevel {
    /// No presolve: the raw paper formulation.
    Off,
    /// Exactness-preserving reductions only — identical status and
    /// optimum to the raw model, guaranteed (the default).
    #[default]
    Exact,
    /// Additionally drops Cover constraints for transitively redundant
    /// precedence edges. A *relaxation*: solutions are still validated
    /// against the Appendix-A.3 evaluator before being reported, but
    /// optimality and infeasibility proofs no longer transfer to the
    /// original problem.
    Aggressive,
}

/// Presolve configuration carried by every solve request.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct PresolveConfig {
    /// Reduction level (default: [`PresolveLevel::Exact`]).
    pub level: PresolveLevel,
    /// The paper's §3 retention-interval length cap `e − s ≤ L`
    /// (`--max-interval-len`). `None` (default) leaves interval lengths
    /// unbounded — the exactness-preserving choice.
    pub max_interval_len: Option<i64>,
}

impl PresolveConfig {
    /// Config with presolve disabled entirely.
    pub fn off() -> PresolveConfig {
        PresolveConfig { level: PresolveLevel::Off, max_interval_len: None }
    }
}

/// A presolve context: configuration plus the (shareable) graph
/// analysis. Build one per graph with [`Presolve::new`], or share the
/// analysis across solvers with [`Presolve::with_shared`].
#[derive(Debug, Clone)]
pub struct Presolve {
    /// The reduction configuration.
    pub config: PresolveConfig,
    /// Order-independent graph analysis; `None` when the level is
    /// [`PresolveLevel::Off`] (never computed) or the graph exceeds the
    /// dense-bitset guard.
    pub analysis: Option<Arc<GraphAnalysis>>,
}

impl Presolve {
    /// A disabled presolve (raw model).
    pub fn off() -> Presolve {
        Presolve { config: PresolveConfig::off(), analysis: None }
    }

    /// Analyze `graph` under `config` (no analysis when disabled).
    pub fn new(graph: &Graph, config: PresolveConfig) -> Presolve {
        let analysis = (config.level != PresolveLevel::Off)
            .then(|| Arc::new(GraphAnalysis::analyze(graph)));
        Presolve { config, analysis }
    }

    /// Reuse an analysis computed elsewhere (portfolio members, LNS
    /// window re-solves).
    pub fn with_shared(analysis: Arc<GraphAnalysis>, config: PresolveConfig) -> Presolve {
        Presolve { config, analysis: Some(analysis) }
    }

    /// Config only, no graph analysis — for solve paths that never read
    /// it (the CHECKMATE row reduction is purely logical), skipping the
    /// quadratic reachability build.
    pub fn config_only(config: PresolveConfig) -> Presolve {
        Presolve { config, analysis: None }
    }

    /// Whether any presolve runs at all.
    pub fn enabled(&self) -> bool {
        self.config.level != PresolveLevel::Off
    }

    /// Whether redundant-edge Cover dropping is on.
    pub fn aggressive(&self) -> bool {
        self.config.level == PresolveLevel::Aggressive
    }

    /// Whether every applied reduction preserves the exact status and
    /// optimum — when false, solvers must not report optimality or
    /// infeasibility proofs for the original problem.
    pub fn exactness_preserving(&self) -> bool {
        self.config.level != PresolveLevel::Aggressive
            && self.config.max_interval_len.is_none()
    }
}

/// Counters describing what one presolved model build achieved,
/// threaded through [`SearchStats`] into `BENCH_solver.json` and
/// `solve --verbose`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PresolveStats {
    /// Precedence edges detected as transitively redundant.
    pub edges_redundant: u64,
    /// Cover constraints dropped for redundant edges (aggressive only).
    pub edges_removed: u64,
    /// Interval copies proven useless and never built.
    pub copies_deactivated: u64,
    /// Variables fixed at the root beyond structural fixings.
    pub vars_fixed: u64,
    /// Propagators the raw formulation would have constructed.
    pub props_before: u64,
    /// Propagators actually constructed.
    pub props_after: u64,
    /// Summed domain size of the raw formulation.
    pub domain_before: u64,
    /// Summed domain size after tightening/compaction.
    pub domain_after: u64,
}

impl PresolveStats {
    /// Domain shrink in percent (0 when nothing was measured).
    pub fn domain_shrink_pct(&self) -> f64 {
        if self.domain_before == 0 {
            return 0.0;
        }
        100.0 * (1.0 - self.domain_after as f64 / self.domain_before as f64)
    }

    /// Propagator reduction in percent (0 when nothing was measured).
    pub fn props_reduction_pct(&self) -> f64 {
        if self.props_before == 0 {
            return 0.0;
        }
        100.0 * (1.0 - self.props_after as f64 / self.props_before as f64)
    }

    /// Accumulate another build's counters into this one (used by
    /// `SearchStats::merge` and by the per-window folding in LNS).
    pub fn add(&mut self, o: &PresolveStats) {
        self.edges_redundant += o.edges_redundant;
        self.edges_removed += o.edges_removed;
        self.copies_deactivated += o.copies_deactivated;
        self.vars_fixed += o.vars_fixed;
        self.props_before += o.props_before;
        self.props_after += o.props_after;
        self.domain_before += o.domain_before;
        self.domain_after += o.domain_after;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cp::VarId;

    fn cum_item(base: u32, demand: i64) -> CumItem {
        CumItem {
            active: VarId(base),
            start: VarId(base + 1),
            end: VarId(base + 2),
            demand,
        }
    }

    #[test]
    fn heavy_clique_detection() {
        // cap 10: demands 6 and 7 are heavy (2d > 10), 5 and 0 are not
        let items =
            [cum_item(0, 6), cum_item(3, 5), cum_item(6, 7), cum_item(9, 0)];
        let clique = detect_serialized_clique(&items, 10);
        assert_eq!(clique.len(), 2);
        assert_eq!(clique[0].active, VarId(0));
        assert_eq!(clique[1].active, VarId(6));
    }

    #[test]
    fn single_heavy_item_is_no_clique() {
        let items = [cum_item(0, 9), cum_item(3, 2)];
        assert!(detect_serialized_clique(&items, 10).is_empty());
    }

    #[test]
    fn loose_budget_detects_nothing() {
        let items = [cum_item(0, 3), cum_item(3, 4), cum_item(6, 5)];
        assert!(detect_serialized_clique(&items, 100).is_empty());
    }

    #[test]
    fn non_positive_cap_serializes_all_positive_demands() {
        let items = [cum_item(0, 1), cum_item(3, 1), cum_item(6, 0)];
        assert_eq!(detect_serialized_clique(&items, 0).len(), 2);
    }

    fn diamond_shortcut() -> Graph {
        Graph::from_edges(
            "ds",
            4,
            &[(0, 1), (0, 2), (1, 3), (2, 3), (0, 3)],
            vec![1; 4],
            vec![1; 4],
        )
        .unwrap()
    }

    #[test]
    fn default_level_is_exact() {
        let cfg = PresolveConfig::default();
        assert_eq!(cfg.level, PresolveLevel::Exact);
        assert_eq!(cfg.max_interval_len, None);
        let pre = Presolve::new(&diamond_shortcut(), cfg);
        assert!(pre.enabled());
        assert!(!pre.aggressive());
        assert!(pre.exactness_preserving());
        assert!(pre.analysis.is_some());
    }

    #[test]
    fn off_skips_analysis() {
        let pre = Presolve::new(&diamond_shortcut(), PresolveConfig::off());
        assert!(!pre.enabled());
        assert!(pre.analysis.is_none());
        assert!(pre.exactness_preserving());
    }

    #[test]
    fn non_exact_modes_lose_proofs() {
        let g = diamond_shortcut();
        let agg = Presolve::new(
            &g,
            PresolveConfig { level: PresolveLevel::Aggressive, max_interval_len: None },
        );
        assert!(!agg.exactness_preserving());
        let capped = Presolve::new(
            &g,
            PresolveConfig { level: PresolveLevel::Exact, max_interval_len: Some(5) },
        );
        assert!(!capped.exactness_preserving());
    }

    #[test]
    fn stats_percentages() {
        let st = PresolveStats {
            props_before: 100,
            props_after: 60,
            domain_before: 1000,
            domain_after: 250,
            ..Default::default()
        };
        assert!((st.props_reduction_pct() - 40.0).abs() < 1e-9);
        assert!((st.domain_shrink_pct() - 75.0).abs() < 1e-9);
        assert_eq!(PresolveStats::default().domain_shrink_pct(), 0.0);
        let mut acc = PresolveStats::default();
        acc.add(&st);
        acc.add(&st);
        assert_eq!(acc.props_before, 200);
        assert_eq!(acc.domain_after, 500);
        assert!((acc.domain_shrink_pct() - 75.0).abs() < 1e-9);
    }
}
