//! Graph analysis backing the presolve reductions.
//!
//! Split in two because the costs differ:
//!
//! * [`GraphAnalysis`] — order-independent facts (transitive-reduction
//!   flags, ancestor/descendant counts) from dense reachability
//!   bitsets. `O(m · n / 64)` — computed once per graph and shared via
//!   `Arc` across portfolio members and LNS window re-solves.
//! * [`staged_caps`] — order-*dependent* liveness bounds over the
//!   staged event grid (§2.3): one reverse sweep over the input
//!   topological order, `O(n + m)` — recomputed per model build (LNS
//!   windows vary the per-node copy counts).

use crate::graph::{transitive_reduction, Graph, NodeId, Reachability};
use crate::moccasin::model::event_id;

/// Node-count guard for the dense reachability bitsets: above this the
/// quadratic bitset analysis is skipped and only the O(n + m)
/// reductions (structural elimination, cover compaction, staged caps)
/// apply.
pub const DENSE_ANALYSIS_LIMIT: usize = 4096;

/// Order-independent structural analysis of a compute graph.
#[derive(Debug, Default)]
pub struct GraphAnalysis {
    /// Redundancy flags parallel to `graph.succs` (empty when the graph
    /// exceeded [`DENSE_ANALYSIS_LIMIT`]).
    redundant: Vec<Vec<bool>>,
    /// Number of transitively redundant edges.
    pub edges_redundant: u64,
    /// Per node: number of descendants (0 when analysis was skipped).
    pub desc_count: Vec<u32>,
    /// Per node: number of ancestors (0 when analysis was skipped).
    pub anc_count: Vec<u32>,
}

impl GraphAnalysis {
    /// Run the full analysis (or the cheap fallback above the size
    /// guard).
    pub fn analyze(g: &Graph) -> GraphAnalysis {
        let n = g.n();
        if n > DENSE_ANALYSIS_LIMIT {
            return GraphAnalysis {
                redundant: Vec::new(),
                edges_redundant: 0,
                desc_count: vec![0; n],
                anc_count: vec![0; n],
            };
        }
        let redundant = transitive_reduction(g);
        let edges_redundant =
            redundant.iter().flatten().filter(|&&r| r).count() as u64;
        let desc = Reachability::descendants(g);
        let anc = Reachability::ancestors(g);
        GraphAnalysis {
            redundant,
            edges_redundant,
            desc_count: (0..n).map(|v| desc.count(v as NodeId)).collect(),
            anc_count: (0..n).map(|v| anc.count(v as NodeId)).collect(),
        }
    }

    /// Is the edge `(u, v)` transitively redundant? (`false` when the
    /// analysis was skipped or the edge does not exist.)
    pub fn edge_redundant(&self, g: &Graph, u: NodeId, v: NodeId) -> bool {
        let Some(flags) = self.redundant.get(u as usize) else {
            return false;
        };
        match g.succs[u as usize].binary_search(&v) {
            Ok(i) => flags[i],
            Err(_) => false,
        }
    }
}

/// Order-dependent liveness bounds over the staged event grid.
#[derive(Debug)]
pub struct StagedCaps {
    /// Per node `v`: the latest event at which any consumer copy can
    /// still start — the exact upper bound on every retention-interval
    /// end `e_v` (covers only require `e ≥` covered consumer starts).
    /// `0` for sinks (no uses at all).
    pub latest_use: Vec<i64>,
    /// Per node `v` (topo index `k`): the largest stage `j` at which a
    /// recompute copy of `v` can still cover some use
    /// (`event_id(j, k) < latest_use[v]`). `k` when no recompute can
    /// ever pay (dominance: such copies are not built).
    pub max_stage: Vec<usize>,
}

/// One reverse sweep over the input order computing [`StagedCaps`].
///
/// Processing nodes in decreasing topological index, every consumer's
/// own cap is already known, so the bound cascades: a consumer whose
/// recompute copies were capped (or deactivated) tightens its
/// producers' caps in turn. `c_v` is the per-node copy allowance the
/// model will be built with (recompute copies exist only when
/// `c_v[v] ≥ 2`).
pub fn staged_caps(g: &Graph, order: &[NodeId], c_v: &[usize]) -> StagedCaps {
    let n = g.n();
    debug_assert_eq!(order.len(), n);
    let mut latest_use = vec![0i64; n];
    let mut max_stage = vec![0usize; n];
    // latest possible start event of any active copy, per node
    let mut latest_start = vec![0i64; n];
    for idx in (1..=n).rev() {
        let v = order[idx - 1] as usize;
        let k = idx;
        let lu = g.succs[v]
            .iter()
            .map(|&w| latest_start[w as usize])
            .max()
            .unwrap_or(0);
        debug_assert!(
            g.succs[v].is_empty() || lu > event_id(k, k),
            "consumers sit at higher topological indices"
        );
        latest_use[v] = lu;
        // largest stage j ∈ [k+1, n] with event_id(j, k) < lu (monotone
        // in j → binary search); k when none qualifies
        let mut j_cap = k;
        if lu > 0 && k + 1 <= n && event_id(k + 1, k) < lu {
            let (mut lo, mut hi) = (k + 1, n);
            while lo < hi {
                let mid = (lo + hi + 1) / 2;
                if event_id(mid, k) < lu {
                    lo = mid;
                } else {
                    hi = mid - 1;
                }
            }
            j_cap = lo;
        }
        max_stage[v] = j_cap;
        latest_start[v] = if c_v[v].max(1) >= 2 && j_cap > k {
            event_id(j_cap, k)
        } else {
            event_id(k, k)
        };
    }
    StagedCaps { latest_use, max_stage }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::topological_order;

    #[test]
    fn analysis_counts_redundancy_and_reach() {
        // 0→1→2→3 with shortcut 0→3
        let g = Graph::from_edges(
            "c",
            4,
            &[(0, 1), (1, 2), (2, 3), (0, 3)],
            vec![1; 4],
            vec![1; 4],
        )
        .unwrap();
        let a = GraphAnalysis::analyze(&g);
        assert_eq!(a.edges_redundant, 1);
        assert!(a.edge_redundant(&g, 0, 3));
        assert!(!a.edge_redundant(&g, 0, 1));
        assert!(!a.edge_redundant(&g, 2, 3));
        assert!(!a.edge_redundant(&g, 1, 0), "non-edges are never redundant");
        assert_eq!(a.desc_count, vec![3, 2, 1, 0]);
        assert_eq!(a.anc_count, vec![0, 1, 2, 3]);
    }

    #[test]
    fn caps_pin_sinks_and_cascade() {
        // chain 0→1→2 (order [0,1,2]; k = 1,2,3; C = 2)
        let g =
            Graph::from_edges("ch", 3, &[(0, 1), (1, 2)], vec![1; 3], vec![1; 3]).unwrap();
        let order = topological_order(&g).unwrap();
        let caps = staged_caps(&g, &order, &[2, 2, 2]);
        // node 2 (k=3) is a sink: no uses, no recompute stage
        assert_eq!(caps.latest_use[2], 0);
        assert_eq!(caps.max_stage[2], 3);
        // node 1 (k=2): sole consumer is node 2, whose only start is
        // event id(3,3) = 6 → latest_use = 6; recompute of node 1 can
        // start at stage 3 (event id(3,2) = 5 < 6)
        assert_eq!(caps.latest_use[1], 6);
        assert_eq!(caps.max_stage[1], 3);
        // node 0 (k=1): consumer node 1 can start as late as id(3,2)=5
        // → latest_use = 5; recompute of 0 allowed at stages 2..3
        // (id(2,1)=2, id(3,1)=4, both < 5)
        assert_eq!(caps.latest_use[0], 5);
        assert_eq!(caps.max_stage[0], 3);
    }

    #[test]
    fn caps_with_single_copy_consumers() {
        // same chain but C = 1 everywhere: consumers only start at
        // their fixed first-compute event, so caps tighten hard
        let g =
            Graph::from_edges("ch", 3, &[(0, 1), (1, 2)], vec![1; 3], vec![1; 3]).unwrap();
        let order = topological_order(&g).unwrap();
        let caps = staged_caps(&g, &order, &[1, 1, 1]);
        // node 1's only start is id(2,2) = 3 → latest_use[0] = 3;
        // a recompute of 0 would need a stage j with id(j,1) < 3:
        // id(2,1) = 2 qualifies → max_stage[0] = 2
        assert_eq!(caps.latest_use[0], 3);
        assert_eq!(caps.max_stage[0], 2);
    }

    #[test]
    fn oversized_graph_falls_back_cheaply() {
        // synthetic n over the guard via a long chain: analysis skipped
        let n = DENSE_ANALYSIS_LIMIT + 1;
        let edges: Vec<(NodeId, NodeId)> =
            (0..n - 1).map(|i| (i as NodeId, (i + 1) as NodeId)).collect();
        let g = Graph::from_edges("big", n, &edges, vec![1; n], vec![1; n]).unwrap();
        let a = GraphAnalysis::analyze(&g);
        assert_eq!(a.edges_redundant, 0);
        assert!(!a.edge_redundant(&g, 0, 1));
        assert_eq!(a.desc_count[0], 0, "counts zeroed above the guard");
    }
}
