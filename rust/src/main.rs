//! MOCCASIN CLI — the L3 entrypoint.
//!
//! Subcommands:
//!   solve   --graph <name|rl:n:m:seed> --budget-frac F [--backend B] [--portfolio]
//!           [--threads N] [--time-limit S] [--presolve off|exact|aggressive]
//!           [--max-interval-len L] [--search chronological|learned]
//!           [--profile segtree|linear] [--filtering timetable|edge-finding]
//!           [--disjunctive on|off] [--stall-ms MS] [--rss-limit-kb KB] [--verbose]
//!   sweep   --graph <name|rl:n:m:seed> [--fracs 95,90,...] [--threads N]
//!           [--time-limit S] [--compare-serial]
//!   bench   <fig1|fig5|fig6|table1|table2|sweep|solver-json|large-json|serve-json|
//!           ablation-c|ablation-topo|all> [--time-limit S] [--quick] [--xl]
//!           [--socket PATH]
//!   bench   compare --baseline A.json --current B.json [--threshold-pct P]
//!           [--warn-only] [--report PATH]   (CI perf ratchet; exit 1 on
//!           regression, 2 when not comparable)
//!   serve   [--socket PATH] [--workers N] [--queue-cap N] [--cache-cap N]
//!           [--deadline-ms MS] [--stall-ms MS]   (NDJSON over a Unix socket)
//!   train   [--steps N] [--budget-frac F]   (requires `make artifacts`
//!           and a build with `--features pjrt`)
//!   lint    [--json] [--fix-allowlist] [--root DIR]   (in-tree static
//!           analysis: atomic-ordering / panic-safety / gate-hygiene
//!           contracts; exit 1 on violations, see docs/CONCURRENCY.md)
//!
//! Std-only argument parsing (the build is fully offline).

use moccasin::bench;
use moccasin::coordinator::{Backend, Coordinator, SolveRequest};
use moccasin::executor::{train_with_remat, TrainConfig};
use moccasin::generators::graph_from_spec;
use moccasin::graph::{topological_order, Graph};
use moccasin::cp::{FilteringMode, ProfileMode, SearchStrategy};
use moccasin::presolve::{PresolveConfig, PresolveLevel};
use moccasin::util::fmt_u64;
use std::time::{Duration, Instant};

fn flag_val(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).cloned()
}

fn parse_graph(spec: &str) -> Option<Graph> {
    graph_from_spec(spec)
}

fn graph_or_exit(args: &[String]) -> (String, Graph) {
    let spec = flag_val(args, "--graph").unwrap_or_else(|| "G1".into());
    let g = parse_graph(&spec).unwrap_or_else(|| {
        eprintln!(
            "unknown graph {spec} (use G1..G4, RW1..RW4, CM1, CM2, L1..L4, rl:n:m:seed)"
        );
        std::process::exit(2);
    });
    (spec, g)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let time_limit = Duration::from_secs_f64(
        flag_val(&args, "--time-limit").and_then(|s| s.parse().ok()).unwrap_or(30.0),
    );
    let quick = args.iter().any(|a| a == "--quick");
    let threads: usize =
        flag_val(&args, "--threads").and_then(|s| s.parse().ok()).unwrap_or(0);
    let presolve = PresolveConfig {
        level: match flag_val(&args, "--presolve").as_deref() {
            Some("off") => PresolveLevel::Off,
            Some("aggressive") => PresolveLevel::Aggressive,
            Some("exact") | None => PresolveLevel::Exact,
            Some(other) => {
                eprintln!("unknown presolve level {other} (use off|exact|aggressive)");
                std::process::exit(2);
            }
        },
        max_interval_len: match flag_val(&args, "--max-interval-len") {
            None => None,
            Some(s) => match s.parse::<i64>() {
                Ok(l) if l >= 0 => Some(l),
                _ => {
                    eprintln!("invalid --max-interval-len {s} (use a nonnegative integer)");
                    std::process::exit(2);
                }
            },
        },
    };

    let search = match flag_val(&args, "--search") {
        None => SearchStrategy::default(),
        Some(name) => SearchStrategy::parse(&name).unwrap_or_else(|| {
            eprintln!("unknown search strategy {name} (use chronological|learned)");
            std::process::exit(2);
        }),
    };
    // cumulative timetable-profile A/B knob (both modes are exact and
    // walk the same tree; segtree is the large-graph default)
    let search = match flag_val(&args, "--profile") {
        None => search,
        Some(name) => match ProfileMode::parse(&name) {
            Some(p) => search.with_profile(p),
            None => {
                eprintln!("unknown profile mode {name} (use segtree|linear)");
                std::process::exit(2);
            }
        },
    };
    // cumulative filtering-strength A/B knob (both modes are exact;
    // edge-finding adds energy-based start/end filtering)
    let search = match flag_val(&args, "--filtering") {
        None => search,
        Some(name) => match FilteringMode::parse(&name) {
            Some(f) => search.with_filtering(f),
            None => {
                eprintln!("unknown filtering mode {name} (use timetable|edge-finding)");
                std::process::exit(2);
            }
        },
    };
    // disjunctive (heavy-clique serialization) propagation knob
    let search = match flag_val(&args, "--disjunctive").as_deref() {
        None => search,
        Some("on") => search.with_disjunctive(true),
        Some("off") => search.with_disjunctive(false),
        Some(other) => {
            eprintln!("invalid --disjunctive {other} (use on|off)");
            std::process::exit(2);
        }
    };

    match args.first().map(|s| s.as_str()) {
        Some("solve") => {
            let (spec, g) = graph_or_exit(&args);
            let frac: f64 =
                flag_val(&args, "--budget-frac").and_then(|s| s.parse().ok()).unwrap_or(0.8);
            let backend = if args.iter().any(|a| a == "--portfolio") {
                Backend::Portfolio
            } else {
                match flag_val(&args, "--backend").as_deref() {
                    Some("checkmate") => Backend::CheckmateMilp,
                    Some("lp-rounding") => Backend::CheckmateLpRounding,
                    Some("portfolio") => Backend::Portfolio,
                    _ => Backend::Moccasin,
                }
            };
            let order = topological_order(&g).unwrap();
            let peak = g.peak_mem_no_remat(&order).unwrap();
            let budget = (peak as f64 * frac) as u64;
            println!(
                "{spec}: n={} m={} no-remat peak={} budget={} ({frac:.0}%)",
                g.n(), g.m(), fmt_u64(peak), fmt_u64(budget), frac = frac * 100.0
            );
            let stall_ms = flag_val(&args, "--stall-ms").and_then(|s| s.parse().ok());
            let rss_limit_kb = flag_val(&args, "--rss-limit-kb").and_then(|s| s.parse().ok());
            let mut coord = Coordinator::new();
            coord.threads = threads;
            let resp = coord.solve(
                &g,
                &SolveRequest {
                    budget,
                    time_limit,
                    backend,
                    presolve,
                    search,
                    stall_ms,
                    rss_limit_kb,
                    ..Default::default()
                },
            );
            match resp.solution {
                Some(sol) => println!(
                    "best: duration={} (TDI {:.2}%), peak={}, remats={}, optimal={}",
                    sol.eval.duration,
                    sol.eval.tdi_percent,
                    fmt_u64(sol.eval.peak_mem),
                    sol.eval.remat_count,
                    resp.proved_optimal
                ),
                None => println!("no solution within {time_limit:?} ({:?})", resp.error),
            }
            if args.iter().any(|a| a == "--verbose") {
                let st = resp.stats;
                println!(
                    "kernel: nodes={} conflicts={} solutions={} propagations={}",
                    st.nodes, st.conflicts, st.solutions, st.propagations
                );
                println!(
                    "engine: profile={} events={} wakeups-skipped={} cum-resyncs={} \
                     cum-rebuilds={}",
                    search.profile.name(),
                    st.events_posted,
                    st.wakeups_skipped,
                    st.cum_resyncs,
                    st.cum_rebuilds
                );
                println!(
                    "filtering: mode={} ef-prunes={} disjunctive={} disj-pairs={} \
                     disj-prunes={}",
                    search.filtering.name(),
                    st.ef_prunes,
                    if search.disjunctive { "on" } else { "off" },
                    st.disj_pairs_detected,
                    st.disj_prunes
                );
                println!(
                    "search: strategy={} restarts={} nogoods-learned={} nogoods-pruned={} \
                     db-reductions={}",
                    search.name(),
                    st.restarts,
                    st.nogoods_learned,
                    st.nogoods_pruned,
                    st.db_reductions
                );
                let ps = st.presolve;
                if ps.props_before > 0 {
                    println!(
                        "presolve: propagators {} -> {} ({:.1}% fewer), domains {} -> {} \
                         ({:.1}% smaller), copies-deactivated={} vars-fixed={} \
                         redundant-edges={} covers-dropped={}",
                        ps.props_before,
                        ps.props_after,
                        ps.props_reduction_pct(),
                        ps.domain_before,
                        ps.domain_after,
                        ps.domain_shrink_pct(),
                        ps.copies_deactivated,
                        ps.vars_fixed,
                        ps.edges_redundant,
                        ps.edges_removed
                    );
                } else {
                    println!("presolve: off");
                }
                println!(
                    "resilience: lock-recoveries={} watchdog-kills={} member-panics={} \
                     member-retries={}",
                    st.lock_recoveries, st.watchdog_kills, st.member_panics, st.member_retries
                );
                match &resp.degradation {
                    Some(deg) => {
                        println!(
                            "degradation: rung={} clean={} retries={} spend-ms: presolve={} \
                             search={} polish={}",
                            deg.rung.as_str(),
                            deg.is_clean(),
                            deg.retries,
                            deg.spend.presolve_ms,
                            deg.spend.search_ms,
                            deg.spend.polish_ms
                        );
                        for f in &deg.failures {
                            println!("  absorbed failure: {f}");
                        }
                    }
                    None => println!("degradation: (not reported by this backend)"),
                }
            }
        }
        Some("lint") => {
            // static-analysis pass over rust/src/** (see docs/CONCURRENCY.md);
            // exit 0 clean / 1 violations / 2 usage, like `bench compare`
            std::process::exit(moccasin::analysis::lint_main(&args[1..]));
        }
        Some("sweep") => {
            let (spec, g) = graph_or_exit(&args);
            let fracs: Vec<f64> = flag_val(&args, "--fracs")
                .map(|s| {
                    s.split(',')
                        .filter_map(|p| p.trim().parse::<f64>().ok())
                        .map(|pct| pct / 100.0)
                        .collect()
                })
                .unwrap_or_else(|| vec![0.95, 0.90, 0.85, 0.80, 0.75, 0.70, 0.65, 0.60]);
            let order = topological_order(&g).unwrap();
            let peak = g.peak_mem_no_remat(&order).unwrap();
            let floor = g.working_set_floor();
            println!(
                "{spec}: n={} m={}, no-remat peak={}, working-set floor={}",
                g.n(), g.m(), fmt_u64(peak), fmt_u64(floor)
            );
            let base = g.total_duration() as f64;
            let requests: Vec<(&Graph, SolveRequest)> = fracs
                .iter()
                .map(|&f| {
                    (
                        &g,
                        SolveRequest {
                            budget: (peak as f64 * f) as u64,
                            time_limit,
                            presolve,
                            search,
                            ..Default::default()
                        },
                    )
                })
                .collect();
            let mut coord = Coordinator::new();
            coord.threads = threads;
            let t0 = Instant::now();
            let responses = coord.solve_many(&requests);
            let wall = t0.elapsed();
            println!(
                "{:>8} {:>12} {:>8} {:>8} {:>8}",
                "budget%", "budget", "TDI%", "remats", "optimal"
            );
            for (i, resp) in responses.iter().enumerate() {
                let budget = requests[i].1.budget;
                match &resp.solution {
                    Some(sol) => {
                        let tdi = 100.0 * (sol.eval.duration as f64 - base) / base;
                        println!(
                            "{:>7.0}% {:>12} {tdi:>8.2} {:>8} {:>8}",
                            fracs[i] * 100.0,
                            fmt_u64(budget),
                            sol.eval.remat_count,
                            resp.proved_optimal
                        );
                    }
                    None => println!(
                        "{:>7.0}% {:>12} {:>8} {:>8} {:>8}",
                        fracs[i] * 100.0,
                        fmt_u64(budget),
                        "-",
                        "-",
                        "-"
                    ),
                }
            }
            println!(
                "sweep: {} budgets in {:.2}s wall ({} solved, {} deduped/cached)",
                fracs.len(),
                wall.as_secs_f64(),
                coord.misses,
                coord.hits
            );
            if args.iter().any(|a| a == "--compare-serial") {
                let mut serial = Coordinator::new();
                let t1 = Instant::now();
                for (graph, req) in &requests {
                    let _ = serial.solve(graph, req);
                }
                let serial_wall = t1.elapsed();
                println!(
                    "serial: {:.2}s wall — parallel speedup {:.2}x",
                    serial_wall.as_secs_f64(),
                    serial_wall.as_secs_f64() / wall.as_secs_f64().max(1e-9)
                );
            }
        }
        Some("bench") => {
            let xl = args.iter().any(|a| a == "--xl");
            let r = match args.get(1).map(|s| s.as_str()) {
                Some("fig1") => bench::fig1(time_limit),
                Some("fig5") => bench::fig5(time_limit, quick),
                Some("fig6") => bench::fig6(time_limit, quick),
                Some("table1") => {
                    bench::table1();
                    Ok(())
                }
                Some("table2") => bench::table2(time_limit, quick),
                Some("sweep") => bench::sweep_parallel(time_limit, quick),
                Some("solver-json") => bench::bench_solver_json(time_limit, quick, search),
                Some("large-json") => bench::bench_large_json(time_limit, quick, xl),
                Some("serve-json") => {
                    let socket = flag_val(&args, "--socket").map(std::path::PathBuf::from);
                    bench::bench_serve_json(quick, socket.as_deref())
                }
                Some("compare") => {
                    let need = |name: &str| {
                        flag_val(&args, name).unwrap_or_else(|| {
                            eprintln!(
                                "bench compare requires {name} PATH (plus optionally \
                                 --threshold-pct P, --warn-only, --report PATH)"
                            );
                            std::process::exit(2);
                        })
                    };
                    let baseline = std::path::PathBuf::from(need("--baseline"));
                    let current = std::path::PathBuf::from(need("--current"));
                    let threshold: f64 = flag_val(&args, "--threshold-pct")
                        .and_then(|s| s.parse().ok())
                        .unwrap_or(10.0);
                    let warn_only = args.iter().any(|a| a == "--warn-only");
                    let report = std::path::PathBuf::from(
                        flag_val(&args, "--report").unwrap_or_else(|| "BENCH_compare.txt".into()),
                    );
                    std::process::exit(bench::bench_compare(
                        &baseline, &current, threshold, warn_only, &report,
                    ));
                }
                Some("ablation-c") => bench::ablation_c(time_limit),
                Some("ablation-topo") => bench::ablation_topo(),
                Some("all") | None => bench::run_all(time_limit, quick, search),
                Some(other) => {
                    eprintln!("unknown bench target {other}");
                    std::process::exit(2);
                }
            };
            if let Err(e) = r {
                eprintln!("bench failed: {e}");
                std::process::exit(1);
            }
        }
        Some("serve") => {
            #[cfg(unix)]
            {
                let socket = std::path::PathBuf::from(
                    flag_val(&args, "--socket").unwrap_or_else(|| "moccasin.sock".into()),
                );
                let cfg = moccasin::serve::ServeConfig {
                    workers: flag_val(&args, "--workers")
                        .and_then(|s| s.parse().ok())
                        .unwrap_or(0),
                    queue_cap: flag_val(&args, "--queue-cap")
                        .and_then(|s| s.parse().ok())
                        .unwrap_or(64),
                    cache_cap: flag_val(&args, "--cache-cap")
                        .and_then(|s| s.parse().ok())
                        .unwrap_or(moccasin::coordinator::DEFAULT_CACHE_CAP),
                    default_deadline: Duration::from_millis(
                        flag_val(&args, "--deadline-ms")
                            .and_then(|s| s.parse().ok())
                            .unwrap_or(30_000),
                    ),
                    stall_ms: flag_val(&args, "--stall-ms").and_then(|s| s.parse().ok()),
                };
                let workers = cfg.effective_workers();
                match moccasin::serve::server::Server::bind(&socket, cfg) {
                    Ok(server) => {
                        println!(
                            "serving on {} ({workers} workers); NDJSON submits like \
                             {{\"graph\":\"G1\",\"budget_frac\":0.9}} — try `nc -U {}`",
                            socket.display(),
                            socket.display()
                        );
                        if let Err(e) = server.serve() {
                            eprintln!("serve failed: {e}");
                            std::process::exit(1);
                        }
                    }
                    Err(e) => {
                        eprintln!("could not bind {}: {e}", socket.display());
                        std::process::exit(1);
                    }
                }
            }
            #[cfg(not(unix))]
            {
                eprintln!("serve requires a unix platform (unix-domain socket transport)");
                std::process::exit(2);
            }
        }
        Some("train") => {
            let steps =
                flag_val(&args, "--steps").and_then(|s| s.parse().ok()).unwrap_or(200);
            let budget_frac =
                flag_val(&args, "--budget-frac").and_then(|s| s.parse().ok()).unwrap_or(0.6);
            let cfg = TrainConfig { steps, budget_frac, ..Default::default() };
            match train_with_remat("artifacts", 256, 128, 512, 64, 8, &cfg) {
                Ok(r) => {
                    println!(
                        "trained {steps} steps under budget {} (pool peak {}), {} remats, \
                         loss {:.3} -> {:.3}",
                        fmt_u64(r.budget_bytes),
                        fmt_u64(r.peak_pool_bytes),
                        r.remat_count,
                        r.losses.first().unwrap(),
                        r.losses.last().unwrap()
                    );
                }
                Err(e) => {
                    eprintln!("train failed: {e:#} (did you run `make artifacts`?)");
                    std::process::exit(1);
                }
            }
        }
        _ => {
            eprintln!(
                "usage: moccasin <solve|sweep|bench|serve|train|lint> [options]\n\
                   solve --graph <G1..G4|RW1..RW4|CM1|CM2|L1..L4|rl:n:m:seed> \
                 [--budget-frac F] \
                 [--backend moccasin|checkmate|lp-rounding|portfolio] [--portfolio] \
                 [--threads N] [--time-limit S] [--presolve off|exact|aggressive] \
                 [--max-interval-len L] [--search chronological|learned] \
                 [--profile segtree|linear] [--filtering timetable|edge-finding] \
                 [--disjunctive on|off] [--stall-ms MS] [--rss-limit-kb KB] [--verbose]\n\
                   sweep --graph <spec> [--fracs 95,90,...] [--threads N] [--time-limit S] \
                 [--search chronological|learned] [--compare-serial]\n\
                   bench <fig1|fig5|fig6|table1|table2|sweep|solver-json|large-json|\
                 serve-json|ablation-c|ablation-topo|all> [--time-limit S] [--quick] \
                 [--xl] [--socket PATH]\n\
                   bench compare --baseline A.json --current B.json \
                 [--threshold-pct P] [--warn-only] [--report PATH]\n\
                   serve [--socket PATH] [--workers N] [--queue-cap N] [--cache-cap N] \
                 [--deadline-ms MS] [--stall-ms MS]\n\
                   train [--steps N] [--budget-frac F]\n\
                   lint [--json] [--fix-allowlist] [--root DIR]   \
                 (in-tree static analysis; exit 1 on violations)"
            );
            std::process::exit(2);
        }
    }
}
