//! Shared incumbent bound + cooperative cancellation for portfolio
//! solves.
//!
//! Every solver in a portfolio race holds an `Arc<Incumbent>`: improving
//! solutions are published with [`Incumbent::record`] (an atomic
//! fetch-min), and every branch-and-bound loop reads [`Incumbent::best`]
//! to tighten its objective bound against the best duration found
//! *anywhere* — the cross-solver pruning that makes a portfolio more
//! than N independent solves. When one member proves optimality (or
//! infeasibility) it calls [`Incumbent::cancel`], which every
//! [`Deadline`](super::Deadline) carrying the incumbent observes on its
//! next `exceeded()` poll, so the rest of the portfolio stops within one
//! node-batch.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

/// Sentinel meaning "no solution recorded yet".
const NONE: u64 = u64::MAX;

/// Atomic best-duration bound + cancellation flag shared by all members
/// of a portfolio solve (and, in serial solves, between the greedy
/// warm-start and the exact/LNS phases).
#[derive(Debug, Default)]
pub struct Incumbent {
    /// Best (smallest) validated solution duration seen so far;
    /// `u64::MAX` = none.
    best: AtomicU64,
    /// Set once a member proves optimality/infeasibility; observed by
    /// every deadline carrying this incumbent.
    cancelled: AtomicBool,
    /// Liveness heartbeat: solver inner loops bump this epoch as they
    /// make progress. A watchdog that sees the epoch stand still past
    /// its stall threshold concludes the solve is wedged (stuck inside
    /// one propagation fixpoint, blocked on I/O, ...) and cancels it.
    progress: AtomicU64,
    /// Set when a serving-tier controller asks the solve to *yield*:
    /// stop at the next cooperative poll and return the best incumbent
    /// found so far. Unlike [`Incumbent::cancel`] — which means "the
    /// caller no longer wants any result" — a preempted solve's answer
    /// is still wanted; the two flags share the same stopping machinery
    /// ([`Incumbent::should_stop`]) but let the caller label the
    /// outcome differently.
    preempted: AtomicBool,
}

impl Incumbent {
    /// Fresh incumbent: no bound, not cancelled.
    pub fn new() -> Self {
        Incumbent {
            best: AtomicU64::new(NONE),
            cancelled: AtomicBool::new(false),
            progress: AtomicU64::new(0),
            preempted: AtomicBool::new(false),
        }
    }

    /// Publish one unit of liveness (called from solver inner loops at a
    /// coarse cadence; a relaxed fetch-add, cheap enough for hot paths).
    #[inline]
    pub fn beat(&self) {
        self.progress.fetch_add(1, Ordering::Relaxed);
    }

    /// Current heartbeat epoch (monotone; watchdogs compare successive
    /// readings to detect a wedged solve).
    #[inline]
    pub fn epoch(&self) -> u64 {
        self.progress.load(Ordering::Relaxed)
    }

    /// The best duration recorded so far, if any.
    pub fn best(&self) -> Option<u64> {
        let b = self.best.load(Ordering::Acquire);
        (b != NONE).then_some(b)
    }

    /// Publish a validated solution duration. Returns `true` if this
    /// strictly improved the shared bound (i.e. the caller is the first
    /// to reach a duration this small).
    pub fn record(&self, duration: u64) -> bool {
        debug_assert_ne!(duration, NONE, "duration sentinel collision");
        self.best.fetch_min(duration, Ordering::AcqRel) > duration
    }

    /// Signal every cooperating solver to stop (first optimality proof
    /// wins the race).
    pub fn cancel(&self) {
        self.cancelled.store(true, Ordering::Release);
    }

    /// Has some member requested cancellation?
    pub fn is_cancelled(&self) -> bool {
        self.cancelled.load(Ordering::Acquire)
    }

    /// Ask the solve to yield: stop at the next cooperative poll and
    /// return its best-so-far incumbent. Sticky, like cancellation.
    pub fn preempt(&self) {
        self.preempted.store(true, Ordering::Release);
    }

    /// Has a controller requested preemption?
    pub fn is_preempted(&self) -> bool {
        self.preempted.load(Ordering::Acquire)
    }

    /// Should the solve stop at its next cooperative poll — either
    /// because the race was cancelled or because a controller preempted
    /// it? This is what [`Deadline`](super::Deadline) polls and what
    /// the propagation engine's in-fixpoint heartbeat tick checks, so
    /// both signals interrupt a solve within one node batch.
    #[inline]
    pub fn should_stop(&self) -> bool {
        self.is_cancelled() || self.is_preempted()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn record_keeps_minimum() {
        let inc = Incumbent::new();
        assert_eq!(inc.best(), None);
        assert!(inc.record(10));
        assert!(!inc.record(12), "worse duration must not improve");
        assert_eq!(inc.best(), Some(10));
        assert!(inc.record(7));
        assert_eq!(inc.best(), Some(7));
        assert!(!inc.record(7), "equal duration is not an improvement");
    }

    #[test]
    fn cancel_is_sticky_and_shared() {
        let inc = Arc::new(Incumbent::new());
        assert!(!inc.is_cancelled());
        let other = Arc::clone(&inc);
        other.cancel();
        assert!(inc.is_cancelled());
    }

    #[test]
    fn heartbeat_epoch_is_monotone() {
        let inc = Incumbent::new();
        assert_eq!(inc.epoch(), 0);
        inc.beat();
        inc.beat();
        assert_eq!(inc.epoch(), 2);
    }

    #[test]
    fn preempt_is_distinct_from_cancel_but_both_stop() {
        let inc = Incumbent::new();
        assert!(!inc.should_stop());
        inc.preempt();
        assert!(inc.is_preempted());
        assert!(!inc.is_cancelled(), "preemption must not read as cancellation");
        assert!(inc.should_stop());
        let inc2 = Incumbent::new();
        inc2.cancel();
        assert!(inc2.should_stop());
        assert!(!inc2.is_preempted());
    }

    #[test]
    fn concurrent_record_converges_to_min() {
        let inc = Arc::new(Incumbent::new());
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let inc = Arc::clone(&inc);
                s.spawn(move || {
                    for d in (1 + t..100).rev() {
                        inc.record(d);
                    }
                });
            }
        });
        assert_eq!(inc.best(), Some(1));
    }
}
