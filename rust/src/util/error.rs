//! Minimal error plumbing for the runtime/executor layers.
//!
//! The build is fully offline, so instead of `anyhow` we carry a tiny
//! string-backed error with an `anyhow`-style [`Context`] extension
//! trait. It deliberately mirrors the subset of the `anyhow` API the
//! codebase uses (`context`, `with_context`, `Error::msg`), so the
//! executor/runtime code reads the same as it would with the external
//! crate.

use std::fmt;

/// A human-readable error with accumulated context.
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    /// Create an error from any printable message.
    pub fn msg(m: impl fmt::Display) -> Self {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

/// Result alias defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow`-style context attachment for `Result` and `Option`.
pub trait Context<T> {
    /// Attach a context message to the failure case.
    fn context(self, msg: impl fmt::Display) -> Result<T>;
    /// Attach a lazily-built context message to the failure case.
    fn with_context(self, f: impl FnOnce() -> String) -> Result<T>;
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg))
    }

    fn with_context(self, f: impl FnOnce() -> String) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{msg}: {e}")))
    }

    fn with_context(self, f: impl FnOnce() -> String) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn option_context() {
        let x: Option<u32> = None;
        let e = x.context("missing tensor").unwrap_err();
        assert_eq!(e.to_string(), "missing tensor");
        assert_eq!(Some(3).context("unused").unwrap(), 3);
    }

    #[test]
    fn result_context_chains() {
        let r: std::result::Result<(), String> = Err("inner".into());
        let e = r.with_context(|| "outer".to_string()).unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
    }
}
