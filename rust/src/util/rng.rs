//! Deterministic PRNG: xoshiro256++ seeded via SplitMix64.
//!
//! All stochastic components of the library (graph generators, random
//! topological orders, LNS neighbourhood selection, property tests) draw
//! from this generator so every experiment is reproducible from a seed.

/// xoshiro256++ (Blackman & Vigna). Not cryptographic; fast and
/// well-distributed, which is all the generators and LNS need.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that small/sequential seeds give unrelated
    /// streams.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s }
    }

    /// Next raw 64-bit output of the generator.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let res = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        res
    }

    /// Uniform in `[0, bound)` (Lemire's method). `bound` must be > 0.
    #[inline]
    pub fn gen_range(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        // 128-bit multiply rejection-free-enough for non-crypto use.
        ((self.next_u64() as u128 * bound as u128) >> 64) as usize
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn gen_range_incl(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + ((self.next_u64() as u128 * (hi - lo + 1) as u128) >> 64) as u64
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose one element (panics on empty slice).
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.gen_range(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::seed_from_u64(7);
        for _ in 0..10_000 {
            let x = r.gen_range(13);
            assert!(x < 13);
            let y = r.gen_range_incl(5, 9);
            assert!((5..=9).contains(&y));
            let f = r.gen_f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn range_covers_all_values() {
        let mut r = Rng::seed_from_u64(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.gen_range(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from_u64(11);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }
}
