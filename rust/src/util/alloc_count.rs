//! A counting global allocator for allocation-regression tests.
//!
//! The data-oriented kernel memory pass promises *zero* steady-state
//! heap allocation across re-solves on a reused `SolveCtx` — a claim a
//! profiler can only eyeball. This module makes it a unit-testable
//! equality: the crate's test build installs [`CountingAlloc`] as the
//! global allocator (see the `#[global_allocator]` item in `lib.rs`),
//! and the regression test asserts that the per-thread allocation
//! counter does not move across a warmed-up solve.
//!
//! Counters are per-thread (`thread_local`), so concurrently running
//! tests cannot contaminate each other's deltas. Deallocations are not
//! counted — the ratchet is on acquiring heap memory, and a free in the
//! steady state implies a matching earlier allocation anyway. The
//! allocator itself is compiled unconditionally (it is trivially thin
//! over [`System`]) but only *installed* under `cfg(test)`; release and
//! bench builds run the system allocator untouched.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

thread_local! {
    // const-initialized: no lazy-init allocation, and usable during
    // thread teardown via try_with (an allocation after TLS destruction
    // is silently uncounted rather than a panic in the allocator)
    static THREAD_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

/// Heap allocations made by the calling thread since it started
/// (meaningful only in builds where [`CountingAlloc`] is installed;
/// always 0 otherwise). Take a delta around the region under test.
pub fn thread_allocations() -> u64 {
    THREAD_ALLOCS.try_with(Cell::get).unwrap_or(0)
}

#[inline]
fn bump() {
    let _ = THREAD_ALLOCS.try_with(|c| c.set(c.get() + 1));
}

/// [`System`] plus a per-thread allocation counter. Installed as the
/// global allocator in test builds only.
pub struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        // a realloc acquires memory (even in-place growth is a new
        // capacity commitment) — counted like an alloc
        bump();
        System.realloc(ptr, layout, new_size)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_observes_vec_growth() {
        let before = thread_allocations();
        let mut v: Vec<u64> = Vec::with_capacity(4);
        v.extend([1, 2, 3, 4]);
        let mid = thread_allocations();
        assert!(mid > before, "with_capacity must allocate");
        // pushing within capacity allocates nothing
        v.clear();
        v.extend([5, 6, 7, 8]);
        assert_eq!(thread_allocations(), mid, "in-capacity reuse must not allocate");
    }
}
