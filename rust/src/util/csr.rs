//! Compressed-sparse-row (CSR) arenas for per-row adjacency data.
//!
//! The CP kernel keeps several "row per variable" tables that the inner
//! loop walks on every event: propagator watcher lists, cumulative
//! item indices, branch-position maps. Stored as `Vec<Vec<T>>` each row
//! is its own heap allocation, so a scan pays a pointer chase (and a
//! cache miss) per variable — measurable at large n. [`Csr`] flattens
//! the rows into one arena with `u32` offsets: row lookup is two
//! adjacent offset reads and the data is contiguous.

/// Rows of `T` flattened into a single arena with `u32` offsets
/// (row `i` occupies `dat[off[i] .. off[i + 1]]`).
#[derive(Debug, Clone)]
pub struct Csr<T> {
    off: Vec<u32>,
    dat: Vec<T>,
}

impl<T> Default for Csr<T> {
    /// Zero-row arena (valid: `off` holds the single sentinel offset).
    fn default() -> Self {
        Csr { off: vec![0u32], dat: Vec::new() }
    }
}

impl<T: Clone> Csr<T> {
    /// Flatten `rows` (consuming nothing; rows are cloned into the
    /// arena — callers build the nested form once and drop it).
    pub fn from_rows(rows: &[Vec<T>]) -> Self {
        let mut c = Csr::default();
        c.rebuild_from_rows(rows);
        c
    }

    /// Refill the arena from `rows` in place, keeping the offset and
    /// data capacity from previous builds (the solve-context reuse
    /// path: rebuilt once per engine construction, steady-state
    /// allocation-free once capacities have grown to fit).
    pub fn rebuild_from_rows(&mut self, rows: &[Vec<T>]) {
        let total: usize = rows.iter().map(|r| r.len()).sum();
        assert!(total <= u32::MAX as usize, "CSR arena exceeds u32 offsets");
        self.off.clear();
        self.dat.clear();
        self.off.reserve(rows.len() + 1);
        self.dat.reserve(total);
        self.off.push(0u32);
        for r in rows {
            self.dat.extend_from_slice(r);
            self.off.push(self.dat.len() as u32);
        }
    }
}

impl<T> Csr<T> {
    /// Number of rows.
    pub fn num_rows(&self) -> usize {
        self.off.len() - 1
    }

    /// The contiguous slice of row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[T] {
        &self.dat[self.off[i] as usize..self.off[i + 1] as usize]
    }

    /// Index range of row `i` into the arena (for loops that must not
    /// hold a borrow across mutations — pair with [`Csr::at`]).
    #[inline]
    pub fn span(&self, i: usize) -> std::ops::Range<usize> {
        self.off[i] as usize..self.off[i + 1] as usize
    }

    /// Arena entry `k` (use with [`Csr::span`]).
    #[inline]
    pub fn at(&self, k: usize) -> &T {
        &self.dat[k]
    }

    /// Whether row `i` is empty.
    #[inline]
    pub fn row_is_empty(&self, i: usize) -> bool {
        self.off[i] == self.off[i + 1]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_rows() {
        let rows = vec![vec![1u32, 2], vec![], vec![3], vec![4, 5, 6]];
        let c = Csr::from_rows(&rows);
        assert_eq!(c.num_rows(), 4);
        for (i, r) in rows.iter().enumerate() {
            assert_eq!(c.row(i), r.as_slice());
            assert_eq!(c.row_is_empty(i), r.is_empty());
            let got: Vec<u32> = c.span(i).map(|k| *c.at(k)).collect();
            assert_eq!(&got, r);
        }
    }

    #[test]
    fn empty_csr() {
        let c: Csr<u8> = Csr::from_rows(&[]);
        assert_eq!(c.num_rows(), 0);
    }
}
