//! Deterministic fault injection for the solve pipeline.
//!
//! A *failpoint* is a named site in the code (e.g. `"engine.propagate"`)
//! where a fault can be injected at runtime: a panic, an artificial
//! delay, a spurious timeout, or an error return. Sites are compiled in
//! only under `cfg(test)` or the `failpoints` cargo feature; in default
//! builds every site is a no-op with zero runtime cost, so the hot
//! propagation loops are unaffected.
//!
//! Sites are armed two ways:
//!
//! * **Environment**: `MOCCASIN_FAILPOINTS="site=action;site=action"`,
//!   parsed once on first use. Actions: `panic`, `delay(ms)`, `timeout`,
//!   `error`, `off`; an optional `*N` suffix limits the number of
//!   firings (e.g. `lns.window=delay(50)*3`). This is how the CI
//!   fault-injection matrix arms a point for a whole test run.
//! * **Programmatically**: [`arm`] / [`disarm`] / [`reset`] from tests.
//!   [`reset`] restores the environment baseline (it does not erase
//!   env-armed points), so suites running under a `MOCCASIN_FAILPOINTS`
//!   matrix entry keep that entry armed across tests.
//!
//! The registry is process-global; test binaries that arm points must
//! serialize those tests (see `rust/tests/resilience.rs`).
//!
//! Call sites use the [`fail_point!`](crate::fail_point) macro, or call
//! [`hit`] directly when they need to distinguish a spurious timeout
//! from an error return.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Duration;

// The registry's poison recovery (a panic *is* this module's product,
// so a fired panic-action must not wedge the registry for later tests).
use crate::util::lock_recover as lock;

/// The fault a site injects when it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailAction {
    /// Panic with a message carrying the site name (tests `catch_unwind`
    /// containment and the degradation ladder).
    Panic,
    /// Sleep for the given number of milliseconds, then continue
    /// normally (tests watchdog stall detection and budget slices).
    Delay(u64),
    /// Report a spurious timeout: the site behaves as if its deadline
    /// had expired.
    Timeout,
    /// Report an error: the site takes its error-return path.
    Error,
    /// Explicitly disarmed (lets the env string override a default).
    Off,
}

/// What a fired failpoint asks the call site to do, beyond the effects
/// (panic, sleep) already performed inside [`hit`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FailSignal {
    /// Behave as if the deadline expired at this site.
    Timeout,
    /// Take the site's error-return path.
    Error,
}

struct Armed {
    action: FailAction,
    /// Remaining firings; `None` = unlimited.
    remaining: Option<u64>,
}

struct State {
    points: Mutex<HashMap<String, Armed>>,
    /// Number of currently armed points — the fast-path gate that keeps
    /// `hit()` to one atomic load when nothing is armed.
    armed: AtomicUsize,
    fired: Mutex<HashMap<String, u64>>,
}

static STATE: OnceLock<State> = OnceLock::new();

fn parse_env() -> HashMap<String, Armed> {
    let mut map = HashMap::new();
    let Ok(spec) = std::env::var("MOCCASIN_FAILPOINTS") else {
        return map;
    };
    for entry in spec.split(';') {
        let entry = entry.trim();
        if entry.is_empty() {
            continue;
        }
        let Some((site, rhs)) = entry.split_once('=') else {
            continue;
        };
        let (action_str, count) = match rhs.rsplit_once('*') {
            Some((a, n)) => (a, n.trim().parse::<u64>().ok()),
            None => (rhs, None),
        };
        let Some(action) = parse_action(action_str.trim()) else {
            continue;
        };
        if action == FailAction::Off {
            map.remove(site.trim());
            continue;
        }
        map.insert(site.trim().to_string(), Armed { action, remaining: count });
    }
    map
}

fn parse_action(s: &str) -> Option<FailAction> {
    if let Some(ms) = s.strip_prefix("delay(").and_then(|r| r.strip_suffix(')')) {
        return ms.trim().parse().ok().map(FailAction::Delay);
    }
    match s {
        "panic" => Some(FailAction::Panic),
        "timeout" => Some(FailAction::Timeout),
        "error" => Some(FailAction::Error),
        "off" => Some(FailAction::Off),
        _ => None,
    }
}

fn state() -> &'static State {
    STATE.get_or_init(|| {
        let map = parse_env();
        State {
            armed: AtomicUsize::new(map.len()),
            points: Mutex::new(map),
            fired: Mutex::new(HashMap::new()),
        }
    })
}

/// Arm `site` with `action`, firing at most `count` times (`None` =
/// unlimited). Overrides any previous arming of the same site,
/// including one from `MOCCASIN_FAILPOINTS`.
pub fn arm(site: &str, action: FailAction, count: Option<u64>) {
    let st = state();
    let mut pts = lock(&st.points);
    if action == FailAction::Off {
        if pts.remove(site).is_some() {
            st.armed.fetch_sub(1, Ordering::AcqRel);
        }
        return;
    }
    if pts.insert(site.to_string(), Armed { action, remaining: count }).is_none() {
        st.armed.fetch_add(1, Ordering::AcqRel);
    }
}

/// Disarm `site` (no-op if it was not armed).
pub fn disarm(site: &str) {
    arm(site, FailAction::Off, None);
}

/// Disarm every programmatically armed point, clear the fired counters,
/// and restore the `MOCCASIN_FAILPOINTS` environment baseline.
pub fn reset() {
    let st = state();
    let map = parse_env();
    let mut pts = lock(&st.points);
    st.armed.store(map.len(), Ordering::Release);
    *pts = map;
    lock(&st.fired).clear();
}

/// How many times `site` has fired since the last [`reset`].
pub fn fired(site: &str) -> u64 {
    lock(&state().fired).get(site).copied().unwrap_or(0)
}

/// Evaluate the failpoint at `site`. Panics and delays are performed
/// here; `Timeout`/`Error` are returned as a [`FailSignal`] for the
/// call site to interpret. Returns `None` when the site is not armed
/// (the overwhelmingly common case — one atomic load).
pub fn hit(site: &str) -> Option<FailSignal> {
    let st = state();
    if st.armed.load(Ordering::Acquire) == 0 {
        return None;
    }
    let action = {
        let mut pts = lock(&st.points);
        let armed = pts.get_mut(site)?;
        let action = armed.action;
        if let Some(rem) = &mut armed.remaining {
            if *rem == 0 {
                pts.remove(site);
                st.armed.fetch_sub(1, Ordering::AcqRel);
                return None;
            }
            *rem -= 1;
            if *rem == 0 {
                pts.remove(site);
                st.armed.fetch_sub(1, Ordering::AcqRel);
            }
        }
        action
    };
    *lock(&state().fired).entry(site.to_string()).or_insert(0) += 1;
    match action {
        FailAction::Panic => panic!("failpoint '{site}': injected panic"),
        FailAction::Delay(ms) => {
            std::thread::sleep(Duration::from_millis(ms));
            None
        }
        FailAction::Timeout => Some(FailSignal::Timeout),
        FailAction::Error => Some(FailSignal::Error),
        FailAction::Off => None,
    }
}

/// Injects a fault at a named site when armed (see
/// [`util::failpoint`](crate::util::failpoint)). The one-argument form
/// performs panics and delays and ignores timeout/error signals; the
/// two-argument form additionally early-returns the given expression on
/// a timeout or error signal. Compiles to nothing outside `cfg(test)` /
/// `--features failpoints`.
#[cfg(any(test, feature = "failpoints"))]
#[macro_export]
macro_rules! fail_point {
    ($site:expr) => {
        let _ = $crate::util::failpoint::hit($site);
    };
    ($site:expr, $ret:expr) => {
        if $crate::util::failpoint::hit($site).is_some() {
            return $ret;
        }
    };
}

/// Injects a fault at a named site when armed (see
/// [`util::failpoint`](crate::util::failpoint)). Fault injection is
/// compiled out in this build (enable with `--features failpoints`).
#[cfg(not(any(test, feature = "failpoints")))]
#[macro_export]
macro_rules! fail_point {
    ($site:expr) => {};
    ($site:expr, $ret:expr) => {};
}

#[cfg(test)]
mod tests {
    use super::*;

    // The registry is process-global; these tests use sites no other
    // test touches, so they are safe to run concurrently.

    #[test]
    fn unarmed_site_is_silent() {
        assert_eq!(hit("fp.test.unarmed"), None);
        assert_eq!(fired("fp.test.unarmed"), 0);
    }

    #[test]
    fn count_limited_arming_expires() {
        arm("fp.test.count", FailAction::Error, Some(2));
        assert_eq!(hit("fp.test.count"), Some(FailSignal::Error));
        assert_eq!(hit("fp.test.count"), Some(FailSignal::Error));
        assert_eq!(hit("fp.test.count"), None, "count must expire");
        assert_eq!(fired("fp.test.count"), 2);
    }

    #[test]
    fn disarm_removes_point() {
        arm("fp.test.disarm", FailAction::Timeout, None);
        assert_eq!(hit("fp.test.disarm"), Some(FailSignal::Timeout));
        disarm("fp.test.disarm");
        assert_eq!(hit("fp.test.disarm"), None);
    }

    #[test]
    fn panic_action_carries_site_name() {
        arm("fp.test.panic", FailAction::Panic, Some(1));
        let r = std::panic::catch_unwind(|| hit("fp.test.panic"));
        let msg = r.expect_err("must panic");
        let msg = msg.downcast_ref::<String>().expect("string payload");
        assert!(msg.contains("fp.test.panic"), "panic message: {msg}");
    }

    #[test]
    fn spec_parsing_roundtrip() {
        let spec = parse_action("delay(25)");
        assert_eq!(spec, Some(FailAction::Delay(25)));
        assert_eq!(parse_action("panic"), Some(FailAction::Panic));
        assert_eq!(parse_action("bogus"), None);
    }
}
