//! Process-global resilience event counters, plus per-request
//! [`Recorder`] handles for exact attribution.
//!
//! Recovery paths that have no `SearchStats` in scope — poisoned-lock
//! recovery in the portfolio shared state, watchdog kills from the
//! coordinator's monitor thread, contained member panics — record here
//! instead of logging nothing, so the counters surface in
//! `SearchStats::merge` output, `solve --verbose`, and the bench JSONs.
//!
//! The global counters are monotone for the life of the process and are
//! *process-wide diagnostics only*. Per-solve attribution goes through a
//! [`Recorder`]: a cloneable handle owned by one request whose `note_*`
//! methods bump both the request's local counters and the globals.
//! Before PR 8, solve paths attributed events by taking a global
//! [`snapshot`] before the work and folding the delta in afterwards —
//! under the serving tier's concurrent solves, two in-flight requests
//! would absorb each other's `watchdog_kills`/`member_retries` that way
//! (both deltas span the same window), so owned counters replaced the
//! delta absorption everywhere a request is identifiable.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

static LOCK_RECOVERIES: AtomicU64 = AtomicU64::new(0);
static WATCHDOG_KILLS: AtomicU64 = AtomicU64::new(0);
static MEMBER_PANICS: AtomicU64 = AtomicU64::new(0);
static MEMBER_RETRIES: AtomicU64 = AtomicU64::new(0);

/// A point-in-time reading of the process-global resilience counters
/// (also used to represent deltas between two readings).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EventSnapshot {
    /// Poisoned mutexes recovered via `lock_recover`.
    pub lock_recoveries: u64,
    /// Members/solves cancelled by a watchdog (stall, wall overrun, or
    /// RSS guard).
    pub watchdog_kills: u64,
    /// Panics contained by `catch_unwind` in members/workers.
    pub member_panics: u64,
    /// Transient member failures retried by `solve_many`.
    pub member_retries: u64,
}

impl EventSnapshot {
    /// Counter increments since `earlier` was taken.
    pub fn delta_since(&self, earlier: &EventSnapshot) -> EventSnapshot {
        EventSnapshot {
            lock_recoveries: self.lock_recoveries - earlier.lock_recoveries,
            watchdog_kills: self.watchdog_kills - earlier.watchdog_kills,
            member_panics: self.member_panics - earlier.member_panics,
            member_retries: self.member_retries - earlier.member_retries,
        }
    }
}

/// Read the current process-global counters.
pub fn snapshot() -> EventSnapshot {
    EventSnapshot {
        lock_recoveries: LOCK_RECOVERIES.load(Ordering::Relaxed),
        watchdog_kills: WATCHDOG_KILLS.load(Ordering::Relaxed),
        member_panics: MEMBER_PANICS.load(Ordering::Relaxed),
        member_retries: MEMBER_RETRIES.load(Ordering::Relaxed),
    }
}

/// Record recovery of a poisoned mutex.
pub fn note_lock_recovery() {
    LOCK_RECOVERIES.fetch_add(1, Ordering::Relaxed);
}

/// Record a watchdog cancelling a wedged or over-budget solve.
pub fn note_watchdog_kill() {
    WATCHDOG_KILLS.fetch_add(1, Ordering::Relaxed);
}

/// Record a panic contained by a member/worker `catch_unwind`.
pub fn note_member_panic() {
    MEMBER_PANICS.fetch_add(1, Ordering::Relaxed);
}

/// Record a transient member failure being retried.
pub fn note_member_retry() {
    MEMBER_RETRIES.fetch_add(1, Ordering::Relaxed);
}

/// Per-request resilience counters: a cloneable handle threaded through
/// one solve (portfolio shared state, `solve_many` worker, serve
/// session) whose `note_*` methods record against both this request and
/// the process-global totals. [`Recorder::local`] reads only what *this
/// request's* paths recorded, so two in-flight solves can no longer
/// steal each other's counts the way global snapshot/delta absorption
/// allowed.
#[derive(Debug, Clone, Default)]
pub struct Recorder {
    inner: Arc<RecorderInner>,
}

#[derive(Debug, Default)]
struct RecorderInner {
    lock_recoveries: AtomicU64,
    watchdog_kills: AtomicU64,
    member_panics: AtomicU64,
    member_retries: AtomicU64,
}

impl Recorder {
    /// Fresh per-request recorder with zeroed local counters.
    pub fn new() -> Self {
        Recorder::default()
    }

    /// Record recovery of a poisoned mutex against this request.
    pub fn note_lock_recovery(&self) {
        self.inner.lock_recoveries.fetch_add(1, Ordering::Relaxed);
        note_lock_recovery();
    }

    /// Record a watchdog kill against this request.
    pub fn note_watchdog_kill(&self) {
        self.inner.watchdog_kills.fetch_add(1, Ordering::Relaxed);
        note_watchdog_kill();
    }

    /// Record a contained member panic against this request.
    pub fn note_member_panic(&self) {
        self.inner.member_panics.fetch_add(1, Ordering::Relaxed);
        note_member_panic();
    }

    /// Record a retried member failure against this request.
    pub fn note_member_retry(&self) {
        self.inner.member_retries.fetch_add(1, Ordering::Relaxed);
        note_member_retry();
    }

    /// This request's own counters (never another in-flight request's)
    /// — fold into `SearchStats` with
    /// [`SearchStats::absorb_events`](crate::cp::SearchStats::absorb_events).
    pub fn local(&self) -> EventSnapshot {
        EventSnapshot {
            lock_recoveries: self.inner.lock_recoveries.load(Ordering::Relaxed),
            watchdog_kills: self.inner.watchdog_kills.load(Ordering::Relaxed),
            member_panics: self.inner.member_panics.load(Ordering::Relaxed),
            member_retries: self.inner.member_retries.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deltas_isolate_concurrent_noise_free_runs() {
        let before = snapshot();
        note_lock_recovery();
        note_watchdog_kill();
        note_watchdog_kill();
        let d = snapshot().delta_since(&before);
        // Other tests may bump counters concurrently, so assert lower
        // bounds only.
        assert!(d.lock_recoveries >= 1);
        assert!(d.watchdog_kills >= 2);
    }

    #[test]
    fn recorders_isolate_concurrent_requests() {
        // two "in-flight requests": events recorded on one handle must
        // never appear in the other's local snapshot, even though the
        // globals see both
        let a = Recorder::new();
        let b = Recorder::new();
        let before = snapshot();
        a.note_watchdog_kill();
        a.note_member_retry();
        b.note_member_panic();
        let la = a.local();
        let lb = b.local();
        assert_eq!(la.watchdog_kills, 1);
        assert_eq!(la.member_retries, 1);
        assert_eq!(la.member_panics, 0, "b's panic must not leak into a");
        assert_eq!(lb.member_panics, 1);
        assert_eq!(lb.watchdog_kills, 0, "a's kill must not leak into b");
        let d = snapshot().delta_since(&before);
        assert!(d.watchdog_kills >= 1 && d.member_panics >= 1 && d.member_retries >= 1);
    }

    #[test]
    fn recorder_clones_share_counters() {
        let a = Recorder::new();
        let a2 = a.clone();
        a2.note_lock_recovery();
        assert_eq!(a.local().lock_recoveries, 1);
    }
}
