//! Process-global resilience event counters.
//!
//! Recovery paths that have no `SearchStats` in scope — poisoned-lock
//! recovery in the portfolio shared state, watchdog kills from the
//! coordinator's monitor thread, contained member panics — record here
//! instead of logging nothing. Callers that *do* own stats take a
//! [`snapshot`] before the work and fold the delta into their
//! `SearchStats` afterwards, so the counters surface in
//! `SearchStats::merge` output, `solve --verbose`, and the bench JSONs.
//!
//! Counters are monotone for the life of the process; concurrent solves
//! may attribute each other's events to themselves, which is acceptable
//! for diagnostics (the process-wide totals stay exact).

use std::sync::atomic::{AtomicU64, Ordering};

static LOCK_RECOVERIES: AtomicU64 = AtomicU64::new(0);
static WATCHDOG_KILLS: AtomicU64 = AtomicU64::new(0);
static MEMBER_PANICS: AtomicU64 = AtomicU64::new(0);
static MEMBER_RETRIES: AtomicU64 = AtomicU64::new(0);

/// A point-in-time reading of the process-global resilience counters
/// (also used to represent deltas between two readings).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EventSnapshot {
    /// Poisoned mutexes recovered via `lock_recover`.
    pub lock_recoveries: u64,
    /// Members/solves cancelled by a watchdog (stall, wall overrun, or
    /// RSS guard).
    pub watchdog_kills: u64,
    /// Panics contained by `catch_unwind` in members/workers.
    pub member_panics: u64,
    /// Transient member failures retried by `solve_many`.
    pub member_retries: u64,
}

impl EventSnapshot {
    /// Counter increments since `earlier` was taken.
    pub fn delta_since(&self, earlier: &EventSnapshot) -> EventSnapshot {
        EventSnapshot {
            lock_recoveries: self.lock_recoveries - earlier.lock_recoveries,
            watchdog_kills: self.watchdog_kills - earlier.watchdog_kills,
            member_panics: self.member_panics - earlier.member_panics,
            member_retries: self.member_retries - earlier.member_retries,
        }
    }
}

/// Read the current process-global counters.
pub fn snapshot() -> EventSnapshot {
    EventSnapshot {
        lock_recoveries: LOCK_RECOVERIES.load(Ordering::Relaxed),
        watchdog_kills: WATCHDOG_KILLS.load(Ordering::Relaxed),
        member_panics: MEMBER_PANICS.load(Ordering::Relaxed),
        member_retries: MEMBER_RETRIES.load(Ordering::Relaxed),
    }
}

/// Record recovery of a poisoned mutex.
pub fn note_lock_recovery() {
    LOCK_RECOVERIES.fetch_add(1, Ordering::Relaxed);
}

/// Record a watchdog cancelling a wedged or over-budget solve.
pub fn note_watchdog_kill() {
    WATCHDOG_KILLS.fetch_add(1, Ordering::Relaxed);
}

/// Record a panic contained by a member/worker `catch_unwind`.
pub fn note_member_panic() {
    MEMBER_PANICS.fetch_add(1, Ordering::Relaxed);
}

/// Record a transient member failure being retried.
pub fn note_member_retry() {
    MEMBER_RETRIES.fetch_add(1, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deltas_isolate_concurrent_noise_free_runs() {
        let before = snapshot();
        note_lock_recovery();
        note_watchdog_kill();
        note_watchdog_kill();
        let d = snapshot().delta_since(&before);
        // Other tests may bump counters concurrently, so assert lower
        // bounds only.
        assert!(d.lock_recoveries >= 1);
        assert!(d.watchdog_kills >= 2);
    }
}
