//! Small std-only utilities: a deterministic PRNG (the build is fully
//! offline, so we carry no `rand` dependency), deadline/cancellation
//! plumbing for anytime solvers, the shared portfolio incumbent, a
//! minimal error type for the runtime layers, and the [`Csr`]
//! flat-arena adjacency type the CP kernel's hot loops walk.

pub mod alloc_count;
mod csr;
mod error;
pub mod events;
pub mod failpoint;
mod incumbent;
mod lru;
mod rng;

pub use csr::Csr;
pub use error::{Context, Error, Result};
pub use incumbent::Incumbent;
pub use lru::LruCache;
pub use rng::Rng;

use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Acquire `m`, recovering from poisoning instead of panicking.
///
/// Every mutex in the runtime layers guards a structure that is only
/// mutated in single statements (queues, maps, counters), so a panic
/// while holding the lock leaves no broken invariant behind — the
/// correct response is to keep going, not to cascade the panic into
/// every other thread touching the lock. Each recovery is counted via
/// [`events::note_lock_recovery`] so it surfaces in diagnostics instead
/// of passing silently.
///
/// This is the *only* sanctioned way to lock a mutex outside tests:
/// the `moccasin lint` panic-safety rule (`MC-LOCK`) flags every bare
/// `.lock()` call that is not inside a function named `lock_recover`.
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| {
        events::note_lock_recovery();
        p.into_inner()
    })
}

/// A wall-clock deadline for anytime solvers, optionally carrying a
/// shared [`Incumbent`] whose cancellation flag is polled alongside the
/// clock — the mechanism by which the first optimality proof in a
/// portfolio race stops every other member.
///
/// `Deadline` is `Clone` (not `Copy`): clones share the same start
/// instant and the same incumbent, so a cloned deadline expires at the
/// same moment and observes the same cancellation.
#[derive(Debug, Clone)]
pub struct Deadline {
    start: Instant,
    limit: Duration,
    incumbent: Option<Arc<Incumbent>>,
}

impl Deadline {
    /// Deadline expiring `limit` from now, with no cancellation channel.
    pub fn after(limit: Duration) -> Self {
        Deadline { start: Instant::now(), limit, incumbent: None }
    }

    /// A deadline that (practically) never expires.
    pub fn unlimited() -> Self {
        Deadline {
            start: Instant::now(),
            limit: Duration::from_secs(u64::MAX / 4),
            incumbent: None,
        }
    }

    /// Deadline expiring `limit` from now that also observes (and lets
    /// solvers prune against) the shared `incumbent`.
    pub fn with_incumbent(limit: Duration, incumbent: Arc<Incumbent>) -> Self {
        Deadline { start: Instant::now(), limit, incumbent: Some(incumbent) }
    }

    /// The shared incumbent this deadline observes, if any.
    pub fn incumbent(&self) -> Option<&Arc<Incumbent>> {
        self.incumbent.as_ref()
    }

    /// A sub-deadline: fresh clock over `limit` (capped at this
    /// deadline's remaining time), inheriting the incumbent — used for
    /// LNS window re-solves so cancellation propagates into them.
    pub fn sub(&self, limit: Duration) -> Deadline {
        Deadline {
            start: Instant::now(),
            limit: limit.min(self.remaining()),
            incumbent: self.incumbent.clone(),
        }
    }

    /// Has the shared incumbent (if any) been asked to stop — cancelled
    /// by a portfolio proof / watchdog, or preempted by a serving-tier
    /// controller? Both signals stop the solve at the next poll; the
    /// caller distinguishes them via [`Incumbent::is_preempted`] when
    /// labelling the outcome.
    #[inline]
    pub fn cancelled(&self) -> bool {
        self.incumbent.as_ref().is_some_and(|i| i.should_stop())
    }

    /// True once the time limit has passed *or* the shared incumbent has
    /// been cancelled (or preempted).
    #[inline]
    pub fn exceeded(&self) -> bool {
        self.cancelled() || self.start.elapsed() >= self.limit
    }

    /// Wall-clock time since this deadline was created.
    #[inline]
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Time left before expiry (zero if expired or cancelled).
    pub fn remaining(&self) -> Duration {
        if self.cancelled() {
            return Duration::ZERO;
        }
        self.limit.saturating_sub(self.start.elapsed())
    }

    /// The absolute instant at which this deadline expires, or `None`
    /// for (practically) unlimited deadlines. Used by the propagation
    /// engine's coarse in-fixpoint clock check, which compares against
    /// a monotonic `Instant` instead of re-deriving elapsed time.
    pub fn hard_stop(&self) -> Option<Instant> {
        self.start.checked_add(self.limit)
    }
}

/// Render a `catch_unwind` payload as a diagnostic string (panic
/// messages from `panic!("...")` are `String` or `&str`; anything else
/// becomes an opaque marker). Contained-panic responses embed this so a
/// failpoint-injected panic carries its site name to the caller.
pub fn panic_note(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".to_string()
    }
}

/// Peak resident-set size (high-water mark) of this process in
/// kilobytes, read from `/proc/self/status` (`VmHWM`). `None` on
/// platforms without procfs — the large-tier bench records it as 0
/// there. Used by `bench large-json` so memory scaling of the
/// L-instances is tracked alongside throughput.
pub fn peak_rss_kb() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    line.split_whitespace().nth(1)?.parse().ok()
}

/// Format a byte/unit count with thousands separators (report output).
pub fn fmt_u64(x: u64) -> String {
    let s = x.to_string();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_u64_groups() {
        assert_eq!(fmt_u64(0), "0");
        assert_eq!(fmt_u64(999), "999");
        assert_eq!(fmt_u64(1000), "1,000");
        assert_eq!(fmt_u64(1234567), "1,234,567");
    }

    #[test]
    fn deadline_basic() {
        let d = Deadline::after(Duration::from_millis(1));
        std::thread::sleep(Duration::from_millis(3));
        assert!(d.exceeded());
        assert_eq!(d.remaining(), Duration::ZERO);
        let u = Deadline::unlimited();
        assert!(!u.exceeded());
    }

    #[test]
    fn deadline_observes_cancellation() {
        let inc = Arc::new(Incumbent::new());
        let d = Deadline::with_incumbent(Duration::from_secs(3600), Arc::clone(&inc));
        assert!(!d.exceeded());
        inc.cancel();
        assert!(d.exceeded());
        assert!(d.cancelled());
        assert_eq!(d.remaining(), Duration::ZERO);
    }

    #[test]
    fn sub_deadline_inherits_incumbent_and_caps_limit() {
        let inc = Arc::new(Incumbent::new());
        let d = Deadline::with_incumbent(Duration::from_millis(50), Arc::clone(&inc));
        let s = d.sub(Duration::from_secs(10));
        assert!(s.remaining() <= Duration::from_millis(50));
        inc.cancel();
        assert!(s.exceeded(), "cancellation must reach sub-deadlines");
    }
}
