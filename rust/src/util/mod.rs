//! Small std-only utilities: a deterministic PRNG (the build is fully
//! offline, so we carry no `rand` dependency), timing helpers, and the
//! in-tree property-testing / bench harness support code.

mod rng;

pub use rng::Rng;

use std::time::{Duration, Instant};

/// A wall-clock deadline for anytime solvers.
#[derive(Debug, Clone, Copy)]
pub struct Deadline {
    start: Instant,
    limit: Duration,
}

impl Deadline {
    pub fn after(limit: Duration) -> Self {
        Deadline { start: Instant::now(), limit }
    }

    pub fn unlimited() -> Self {
        Deadline { start: Instant::now(), limit: Duration::from_secs(u64::MAX / 4) }
    }

    #[inline]
    pub fn exceeded(&self) -> bool {
        self.start.elapsed() >= self.limit
    }

    #[inline]
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn remaining(&self) -> Duration {
        self.limit.saturating_sub(self.start.elapsed())
    }
}

/// Format a byte/unit count with thousands separators (report output).
pub fn fmt_u64(x: u64) -> String {
    let s = x.to_string();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_u64_groups() {
        assert_eq!(fmt_u64(0), "0");
        assert_eq!(fmt_u64(999), "999");
        assert_eq!(fmt_u64(1000), "1,000");
        assert_eq!(fmt_u64(1234567), "1,234,567");
    }

    #[test]
    fn deadline_basic() {
        let d = Deadline::after(Duration::from_millis(1));
        std::thread::sleep(Duration::from_millis(3));
        assert!(d.exceeded());
        assert_eq!(d.remaining(), Duration::ZERO);
        let u = Deadline::unlimited();
        assert!(!u.exceeded());
    }
}
