//! Bounded least-recently-used cache for cross-request schedule reuse.
//!
//! The coordinator's (and the serve tier's) schedule cache used to be
//! an unbounded `HashMap` — fine for one batch, fatal for a
//! long-running daemon whose key space (graph fingerprint × budget ×
//! knobs) grows without bound under fleet traffic. [`LruCache`] caps
//! the entry count and evicts the least-recently-*used* entry on
//! overflow, tracking hit/miss/evict counters so cache behaviour is
//! observable in stats and the serve bench.
//!
//! Implementation: a `HashMap` from key to `(value, stamp)` plus a
//! `BTreeMap` from stamp to key ordered by recency (stamps come from a
//! monotone counter bumped on every touch). Lookup and insert are
//! O(log n) — no intrusive linked list, no unsafe, and n is the
//! configured cap (thousands), so the tree walk is noise next to a
//! solve.

use std::collections::{BTreeMap, HashMap};
use std::hash::Hash;

/// A bounded LRU map with hit/miss/evict counters.
#[derive(Debug)]
pub struct LruCache<K, V> {
    cap: usize,
    map: HashMap<K, (V, u64)>,
    by_recency: BTreeMap<u64, K>,
    tick: u64,
    /// Lookups that found a live entry.
    pub hits: u64,
    /// Lookups that found nothing.
    pub misses: u64,
    /// Entries evicted to make room (never counts explicit removals).
    pub evictions: u64,
}

impl<K: Hash + Eq + Clone, V> LruCache<K, V> {
    /// Cache holding at most `cap` entries. `cap == 0` disables storage
    /// entirely (every insert is dropped, every lookup misses) — the
    /// "no caching" configuration, kept valid so ops can turn the cache
    /// off without a separate code path.
    pub fn new(cap: usize) -> Self {
        LruCache {
            cap,
            map: HashMap::new(),
            by_recency: BTreeMap::new(),
            tick: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    /// Entry cap this cache was configured with.
    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Live entry count.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// Whether the cache currently holds no entries.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    fn next_tick(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }

    /// Look up `key`, marking the entry most-recently-used on a hit.
    /// Counts a hit or a miss.
    pub fn get(&mut self, key: &K) -> Option<&V> {
        let old_stamp = match self.map.get(key) {
            Some((_, s)) => *s,
            None => {
                self.misses += 1;
                return None;
            }
        };
        self.hits += 1;
        let tick = self.next_tick();
        self.by_recency.remove(&old_stamp);
        self.by_recency.insert(tick, key.clone());
        if let Some(entry) = self.map.get_mut(key) {
            entry.1 = tick;
        }
        self.map.get(key).map(|(v, _)| v)
    }

    /// Insert (or replace) `key`, evicting the least-recently-used
    /// entry if the cache is full. No-op when the cap is 0.
    pub fn insert(&mut self, key: K, value: V) {
        if self.cap == 0 {
            return;
        }
        let old_stamp = self.map.get(&key).map(|(_, s)| *s);
        if let Some(stamp) = old_stamp {
            // replacing in place: recency refreshes, no eviction needed
            self.by_recency.remove(&stamp);
        } else if self.map.len() >= self.cap {
            // evict the coldest entry (smallest stamp)
            if let Some((&stamp, _)) = self.by_recency.iter().next() {
                if let Some(victim) = self.by_recency.remove(&stamp) {
                    self.map.remove(&victim);
                    self.evictions += 1;
                }
            }
        }
        let tick = self.next_tick();
        self.by_recency.insert(tick, key.clone());
        self.map.insert(key, (value, tick));
    }

    /// Whether `key` is present, without touching recency or counters.
    pub fn contains(&self, key: &K) -> bool {
        self.map.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hits_misses_and_recency() {
        let mut c: LruCache<u32, &'static str> = LruCache::new(2);
        assert!(c.get(&1).is_none());
        assert_eq!(c.misses, 1);
        c.insert(1, "a");
        c.insert(2, "b");
        assert_eq!(c.get(&1), Some(&"a"));
        assert_eq!(c.hits, 1);
        // 1 is now the most recent; inserting 3 must evict 2
        c.insert(3, "c");
        assert_eq!(c.evictions, 1);
        assert!(c.contains(&1) && c.contains(&3));
        assert!(!c.contains(&2), "LRU victim must be the cold entry");
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn replace_refreshes_without_evicting() {
        let mut c: LruCache<u32, u32> = LruCache::new(2);
        c.insert(1, 10);
        c.insert(2, 20);
        c.insert(1, 11); // replace: no eviction, 1 becomes hottest
        assert_eq!(c.evictions, 0);
        assert_eq!(c.get(&1), Some(&11));
        c.insert(3, 30); // now 2 is coldest
        assert!(!c.contains(&2));
        assert!(c.contains(&1) && c.contains(&3));
    }

    #[test]
    fn zero_cap_disables_storage() {
        let mut c: LruCache<u32, u32> = LruCache::new(0);
        c.insert(1, 10);
        assert!(c.is_empty());
        assert!(c.get(&1).is_none());
        assert_eq!(c.evictions, 0);
    }

    #[test]
    fn eviction_order_follows_use_not_insertion() {
        let mut c: LruCache<u32, u32> = LruCache::new(3);
        for k in 1..=3 {
            c.insert(k, k);
        }
        // touch in reverse insertion order: 1 becomes hottest
        assert!(c.get(&2).is_some());
        assert!(c.get(&1).is_some());
        c.insert(4, 4); // evicts 3 (untouched)
        c.insert(5, 5); // evicts 2
        assert!(c.contains(&1), "most recently used must survive");
        assert!(!c.contains(&3) && !c.contains(&2));
        assert_eq!(c.evictions, 2);
    }
}
