//! Memory-managed schedule executor: runs a rematerialization sequence
//! over real XLA executables with a budget-enforcing tensor pool.
//!
//! This is the end-to-end proof that MOCCASIN's schedules work: the
//! transformer-LM training graph (embed → K blocks → loss → backward
//! chain) is built at *segment* granularity, each node backed by an AOT
//! artifact (`python/compile/aot.py`); the executor
//!
//! 1. profiles one no-remat step to get real per-segment durations,
//! 2. asks [`MoccasinSolver`] for a schedule under the activation-memory
//!    budget,
//! 3. executes the schedule for N training steps, re-running `block_fwd`
//!    wherever the schedule rematerializes an activation, with a tensor
//!    pool that asserts the Appendix-A.3 footprint never exceeds the
//!    budget, and applies host-side SGD from the streamed gradients.
//!
//! Weights are pinned outside the pool (they are not schedulable state —
//! the paper's problem is intermediate-tensor memory).

use crate::graph::{Graph, NodeId};
use crate::moccasin::{intervals_from_sequence, MoccasinSolver};
use crate::runtime::{HostTensor, Runtime};
use crate::util::{Context, Error, Result, Rng};
use std::time::Instant;

/// What each graph node executes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SegKind {
    /// Token embedding (node 0, produces activation a0).
    Embed,
    /// forward of block i (0-based)
    Fwd(usize),
    /// Loss + gradient head (consumes a_K, produces d_K).
    Loss,
    /// backward of block i
    Bwd(usize),
}

/// The segment-level training graph: `2K + 2` nodes.
pub struct SegmentGraph {
    /// The compute DAG handed to the solver.
    pub graph: Graph,
    /// What each node executes, indexed by node id.
    pub kinds: Vec<SegKind>,
}

/// Build the training graph for `k` blocks with `act_bytes` per
/// activation and per-node durations `w` (profiled or unit).
pub fn training_graph(k: usize, act_bytes: u64, w: &[u64]) -> SegmentGraph {
    let n = 2 * k + 2;
    assert_eq!(w.len(), n);
    let mut edges: Vec<(NodeId, NodeId)> = Vec::new();
    let mut kinds = Vec::with_capacity(n);
    kinds.push(SegKind::Embed); // node 0 → a0
    for i in 0..k {
        kinds.push(SegKind::Fwd(i)); // node 1+i: a_i → a_{i+1}
        edges.push((i as NodeId, (i + 1) as NodeId));
    }
    kinds.push(SegKind::Loss); // node k+1: consumes a_k, produces d_k
    edges.push((k as NodeId, (k + 1) as NodeId));
    for j in 0..k {
        let i = k - 1 - j; // block index for this backward node
        let node = (k + 2 + j) as NodeId;
        kinds.push(SegKind::Bwd(i));
        // needs the incoming gradient (previous bwd / loss) …
        edges.push((node - 1, node));
        // … and the block's *input* activation a_i (output of node i)
        edges.push((i as NodeId, node));
    }
    let mem = vec![act_bytes; n];
    let graph = Graph::from_edges("train", n, &edges, w.to_vec(), mem).unwrap();
    SegmentGraph { graph, kinds }
}

/// Transformer-LM parameters held host-side.
pub struct Params {
    /// Token embedding table `[vocab, d]`.
    pub embed: HostTensor,
    /// per block: wqkv, wo, w1, w2
    pub blocks: Vec<[HostTensor; 4]>,
    /// Output projection `[d, vocab]`.
    pub unembed: HostTensor,
}

impl Params {
    /// Deterministic random init matching python/compile/model.py scales.
    pub fn init(vocab: usize, d: usize, dff: usize, k: usize, seed: u64) -> Params {
        let mut rng = Rng::seed_from_u64(seed);
        let mut randn = |shape: &[usize], scale: f32| -> HostTensor {
            let n: usize = shape.iter().product();
            let data: Vec<f32> = (0..n)
                .map(|_| {
                    // Box-Muller
                    let u1 = rng.gen_f64().max(1e-12);
                    let u2 = rng.gen_f64();
                    ((-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()) as f32 * scale
                })
                .collect();
            HostTensor::F32 { shape: shape.to_vec(), data }
        };
        let s = 1.0 / (d as f32).sqrt();
        Params {
            embed: randn(&[vocab, d], 0.02),
            blocks: (0..k)
                .map(|_| {
                    [
                        randn(&[d, 3 * d], s),
                        randn(&[d, d], s),
                        randn(&[d, dff], s),
                        randn(&[dff, d], 1.0 / (dff as f32).sqrt()),
                    ]
                })
                .collect(),
            unembed: randn(&[d, vocab], s),
        }
    }

    fn sgd(p: &mut HostTensor, g: &HostTensor, lr: f32) {
        let pv = p.as_f32_mut();
        let gv = g.as_f32();
        for (x, &d) in pv.iter_mut().zip(gv) {
            *x -= lr * d;
        }
    }
}

/// Result of a training run.
pub struct TrainReport {
    /// Loss per step (first entry is the profiling step).
    pub losses: Vec<f32>,
    /// peak pool bytes observed across all steps
    pub peak_pool_bytes: u64,
    /// Enforced activation-memory budget in bytes.
    pub budget_bytes: u64,
    /// schedule stats
    pub remat_count: usize,
    /// Schedule duration increase over no-remat, in percent.
    pub tdi_percent: f64,
    /// profiled per-node durations (µs)
    pub durations_us: Vec<u64>,
    /// Wall-clock per scheduled training step (µs).
    pub step_wall_us: Vec<u64>,
}

/// Configuration for the end-to-end training driver.
pub struct TrainConfig {
    /// Number of transformer blocks `K`.
    pub blocks: usize,
    /// Training steps to run under the schedule.
    pub steps: usize,
    /// SGD learning rate.
    pub lr: f32,
    /// memory budget as a fraction of the no-remat activation peak
    pub budget_frac: f64,
    /// RNG seed (init + synthetic data).
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig { blocks: 4, steps: 50, lr: 0.05, budget_frac: 0.6, seed: 0 }
    }
}

/// One schedule-driven training step. Returns (loss, peak pool bytes).
#[allow(clippy::too_many_arguments)]
fn run_schedule(
    rt: &mut Runtime,
    sg: &SegmentGraph,
    seq: &[NodeId],
    params: &mut Params,
    tokens: &HostTensor,
    targets: &HostTensor,
    lr: f32,
    durations_us: Option<&mut Vec<u64>>,
) -> Result<(f32, u64)> {
    let k = params.blocks.len();
    // minimal-retention release positions for pool management
    let intervals = intervals_from_sequence(&sg.graph, seq);
    let mut pool: Vec<Option<HostTensor>> = vec![None; sg.graph.n()];
    let mut used: u64 = 0;
    let mut peak: u64 = 0;
    let mut loss_out = f32::NAN;
    let mut timings = vec![0u64; sg.graph.n()];

    // grads accumulated for SGD at step end
    let mut block_grads: Vec<Option<[HostTensor; 4]>> = (0..k).map(|_| None).collect();
    let mut unembed_grad: Option<HostTensor> = None;

    for (pos, &node) in seq.iter().enumerate() {
        let kind = sg.kinds[node as usize];
        let t0 = Instant::now();
        let out = match kind {
            SegKind::Embed => {
                let exe = rt.load("embed_fwd")?;
                exe.run(&[tokens, &params.embed])?.remove(0)
            }
            SegKind::Fwd(i) => {
                let x = pool[i].as_ref().context("input activation not resident")?;
                let [wqkv, wo, w1, w2] = &params.blocks[i];
                let exe = rt.load("block_fwd")?;
                exe.run(&[x, wqkv, wo, w1, w2])?.remove(0)
            }
            SegKind::Loss => {
                let a = pool[k].as_ref().context("final activation not resident")?;
                let exe = rt.load("loss_grad")?;
                let mut outs = exe.run(&[a, &params.unembed, targets])?;
                // (loss, da, dunembed)
                let dun = outs.remove(2);
                let da = outs.remove(1);
                let loss = outs.remove(0);
                loss_out = loss.as_f32()[0];
                unembed_grad = Some(dun);
                da
            }
            SegKind::Bwd(i) => {
                let x = pool[i].as_ref().context("activation for bwd not resident")?;
                // incoming gradient = output of the previous backward node
                let grad_node = if i == k - 1 { k + 1 } else { k + 2 + (k - 2 - i) };
                let dy = pool[grad_node].as_ref().context("grad not resident")?;
                let [wqkv, wo, w1, w2] = &params.blocks[i];
                let exe = rt.load("block_bwd")?;
                let mut outs = exe.run(&[x, wqkv, wo, w1, w2, dy])?;
                // (dx, dwqkv, dwo, dw1, dw2)
                let dw2 = outs.remove(4);
                let dw1 = outs.remove(3);
                let dwo = outs.remove(2);
                let dwqkv = outs.remove(1);
                block_grads[i] = Some([dwqkv, dwo, dw1, dw2]);
                outs.remove(0)
            }
        };
        timings[node as usize] = timings[node as usize].max(t0.elapsed().as_micros() as u64);

        // allocate output in the pool (charged at compute, A.3 eq. 17)
        used += out.byte_size();
        peak = peak.max(used);
        if let Some(old) = pool[node as usize].replace(out) {
            // a remat replaces the stale copy — if it was still resident
            // it would have been released at its minimal-retention point
            used -= old.byte_size();
        }
        // release every instance whose retention ends at this position
        for iv in intervals.iter() {
            if iv.end == pos && iv.start <= pos {
                // only drop if no later instance of the same node relies
                // on the pool slot (the slot holds the latest instance)
                let latest = intervals
                    .iter()
                    .filter(|j| j.node == iv.node && j.start <= pos)
                    .map(|j| j.start)
                    .max()
                    .unwrap();
                if iv.start == latest {
                    if let Some(t) = pool[iv.node as usize].take() {
                        used -= t.byte_size();
                    }
                }
            }
        }
    }

    // SGD
    for (i, g) in block_grads.into_iter().enumerate() {
        if let Some([gq, go, g1, g2]) = g {
            Params::sgd(&mut params.blocks[i][0], &gq, lr);
            Params::sgd(&mut params.blocks[i][1], &go, lr);
            Params::sgd(&mut params.blocks[i][2], &g1, lr);
            Params::sgd(&mut params.blocks[i][3], &g2, lr);
        }
    }
    if let Some(g) = unembed_grad {
        Params::sgd(&mut params.unembed, &g, lr);
    }
    if let Some(d) = durations_us {
        *d = timings;
    }
    Ok((loss_out, peak))
}

/// End-to-end driver: profile → schedule (MOCCASIN) → train under the
/// budget. `dims` must match the artifacts' manifest.
pub fn train_with_remat(
    artifact_dir: &str,
    vocab: usize,
    d_model: usize,
    d_ff: usize,
    seq_len: usize,
    batch: usize,
    cfg: &TrainConfig,
) -> Result<TrainReport> {
    let mut rt = Runtime::new(artifact_dir)?;
    let k = cfg.blocks;
    let mut params = Params::init(vocab, d_model, d_ff, k, cfg.seed);
    let act_bytes = (4 * batch * seq_len * d_model) as u64;

    // synthetic next-token task: targets = (tokens + 1) % vocab
    let mut rng = Rng::seed_from_u64(cfg.seed ^ 0xDA7A);
    let tok_data: Vec<i32> =
        (0..batch * seq_len).map(|_| rng.gen_range(vocab) as i32).collect();
    let tgt_data: Vec<i32> = tok_data.iter().map(|&t| (t + 1) % vocab as i32).collect();
    let tokens = HostTensor::I32 { shape: vec![batch, seq_len], data: tok_data };
    let targets = HostTensor::I32 { shape: vec![batch, seq_len], data: tgt_data };

    // ---- profile step: no-remat topological order, measure durations
    let unit = vec![1u64; 2 * k + 2];
    let sg0 = training_graph(k, act_bytes, &unit);
    let order: Vec<NodeId> = (0..sg0.graph.n() as NodeId).collect();
    let mut durations_us = vec![1u64; sg0.graph.n()];
    let (first_loss, no_remat_peak) = run_schedule(
        &mut rt,
        &sg0,
        &order,
        &mut params,
        &tokens,
        &targets,
        cfg.lr,
        Some(&mut durations_us),
    )?;

    // ---- schedule under the budget with profiled durations
    let profiled: Vec<u64> = durations_us.iter().map(|&d| d.max(1)).collect();
    let sg = training_graph(k, act_bytes, &profiled);
    let budget = ((no_remat_peak as f64) * cfg.budget_frac) as u64;
    let budget = budget.max(sg.graph.working_set_floor());
    let solver = MoccasinSolver {
        time_limit: std::time::Duration::from_secs(5),
        ..Default::default()
    };
    let outcome = solver.solve(&sg.graph, budget, None);
    let sol = outcome.best.context("no feasible schedule at this budget")?;

    // ---- train under the schedule
    let mut losses = vec![first_loss];
    let mut peak_pool = 0u64;
    let mut step_wall_us = Vec::with_capacity(cfg.steps);
    for _ in 0..cfg.steps {
        let t0 = Instant::now();
        let (loss, peak) = run_schedule(
            &mut rt, &sg, &sol.seq, &mut params, &tokens, &targets, cfg.lr, None,
        )?;
        step_wall_us.push(t0.elapsed().as_micros() as u64);
        losses.push(loss);
        peak_pool = peak_pool.max(peak);
        if peak > budget {
            return Err(Error::msg(format!(
                "pool peak {peak} exceeded budget {budget} — scheduler/executor disagree"
            )));
        }
    }

    Ok(TrainReport {
        losses,
        peak_pool_bytes: peak_pool,
        budget_bytes: budget,
        remat_count: sol.eval.remat_count,
        tdi_percent: sol.eval.tdi_percent,
        durations_us,
        step_wall_us,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{eval_sequence, topological_order};

    #[test]
    fn training_graph_shape() {
        let w = vec![1u64; 10];
        let sg = training_graph(4, 100, &w);
        assert_eq!(sg.graph.n(), 10);
        // U-shape: fwd chain + loss + bwd chain + K activation cross edges
        assert_eq!(sg.graph.m(), 4 + 1 + 4 + 4);
        assert!(topological_order(&sg.graph).is_some());
        assert_eq!(sg.kinds[0], SegKind::Embed);
        assert_eq!(sg.kinds[5], SegKind::Loss);
        assert_eq!(sg.kinds[9], SegKind::Bwd(0));
    }

    #[test]
    fn training_graph_no_remat_peak_is_all_activations() {
        let w = vec![1u64; 10];
        let sg = training_graph(4, 100, &w);
        let order: Vec<u32> = (0..10).collect();
        let ev = eval_sequence(&sg.graph, &order).unwrap();
        // all K+1 activations + current grad live at the first backward
        assert!(ev.peak_mem >= 500, "peak {}", ev.peak_mem);
    }

    #[test]
    fn remat_reduces_training_graph_peak() {
        let w = vec![1u64; 10];
        let sg = training_graph(4, 100, &w);
        let order: Vec<u32> = (0..10).collect();
        let full = eval_sequence(&sg.graph, &order).unwrap().peak_mem;
        let budget = (full as f64 * 0.7) as u64;
        let out = MoccasinSolver::default().solve(&sg.graph, budget, None);
        let best = out.best.expect("gradient checkpointing schedule exists");
        assert!(best.eval.peak_mem <= budget);
        assert!(best.eval.remat_count >= 1, "should recompute some forward");
    }

    #[test]
    fn params_init_deterministic() {
        let a = Params::init(64, 32, 64, 2, 7);
        let b = Params::init(64, 32, 64, 2, 7);
        assert_eq!(a.embed.as_f32()[..8], b.embed.as_f32()[..8]);
    }
}
