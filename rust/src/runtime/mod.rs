//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! Wraps the `xla` crate (PJRT C API, CPU plugin): one
//! [`Runtime`] per process holds the client; each artifact becomes a
//! compiled [`Executable`]. Python never runs here — artifacts are
//! produced once by `make artifacts` (python/compile/aot.py) and loaded
//! as text (HLO text round-trips across the jax≥0.5 / xla_extension
//! 0.5.1 proto-id mismatch; see DESIGN.md).

use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Host tensor (f32 or i32), the executor's currency.
#[derive(Debug, Clone)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    pub fn zeros_f32(shape: &[usize]) -> HostTensor {
        HostTensor::F32 { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn num_elements(&self) -> usize {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => {
                shape.iter().product()
            }
        }
    }

    /// Size in bytes (both supported dtypes are 4-byte).
    pub fn byte_size(&self) -> u64 {
        4 * self.num_elements() as u64
    }

    pub fn as_f32(&self) -> &[f32] {
        match self {
            HostTensor::F32 { data, .. } => data,
            _ => panic!("expected f32 tensor"),
        }
    }

    pub fn as_f32_mut(&mut self) -> &mut [f32] {
        match self {
            HostTensor::F32 { data, .. } => data,
            _ => panic!("expected f32 tensor"),
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        Ok(match self {
            HostTensor::F32 { shape, data } => {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims)?
            }
            HostTensor::I32 { shape, data } => {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims)?
            }
        })
    }

    fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => {
                Ok(HostTensor::F32 { shape: dims, data: lit.to_vec::<f32>()? })
            }
            xla::ElementType::S32 => {
                Ok(HostTensor::I32 { shape: dims, data: lit.to_vec::<i32>()? })
            }
            other => anyhow::bail!("unsupported element type {other:?}"),
        }
    }
}

/// A compiled artifact.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Executable {
    /// Execute with host tensors; returns the flattened output tuple.
    pub fn run(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let mut result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: decompose the tuple
        let parts = result.decompose_tuple()?;
        parts.iter().map(HostTensor::from_literal).collect()
    }
}

/// The PJRT CPU runtime: client + compiled-artifact cache.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: HashMap<String, Executable>,
    dir: PathBuf,
}

impl Runtime {
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, cache: HashMap::new(), dir: artifact_dir.as_ref().to_path_buf() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile `<dir>/<name>.hlo.txt` (cached).
    pub fn load(&mut self, name: &str) -> Result<&Executable> {
        if !self.cache.contains_key(name) {
            let path = self.dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )
            .with_context(|| format!("loading HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).with_context(|| format!("compiling {name}"))?;
            self.cache
                .insert(name.to_string(), Executable { exe, name: name.to_string() });
        }
        Ok(&self.cache[name])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_sizes() {
        let t = HostTensor::zeros_f32(&[2, 3, 4]);
        assert_eq!(t.num_elements(), 24);
        assert_eq!(t.byte_size(), 96);
        assert_eq!(t.as_f32().len(), 24);
    }

    // PJRT round-trip tests live in rust/tests/runtime_e2e.rs (they need
    // built artifacts).
}
