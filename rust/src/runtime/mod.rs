//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! The real backend wraps the `xla` crate (PJRT C API, CPU plugin): one
//! [`Runtime`] per process holds the client; each artifact becomes a
//! compiled [`Executable`]. Python never runs here — artifacts are
//! produced once by `make artifacts` (python/compile/aot.py) and loaded
//! as text (HLO text round-trips across the jax≥0.5 / xla_extension
//! 0.5.1 proto-id mismatch; see DESIGN.md).
//!
//! **Feature gating.** The `xla` crate cannot be fetched in the offline
//! build, so the PJRT glue is behind the `pjrt` cargo feature (see the
//! note at the top of `Cargo.toml` for how to vendor it). Without the
//! feature this module compiles a stub whose [`Runtime::new`] returns
//! an error; everything host-side ([`HostTensor`], the executor's
//! scheduling logic, all solvers) builds and tests regardless.

use crate::util::{Error, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

#[cfg(feature = "pjrt")]
use crate::util::Context;

/// Host tensor (f32 or i32), the executor's currency.
#[derive(Debug, Clone)]
pub enum HostTensor {
    /// 32-bit float tensor (row-major).
    F32 {
        /// Dimension sizes.
        shape: Vec<usize>,
        /// Flat row-major data.
        data: Vec<f32>,
    },
    /// 32-bit signed integer tensor (row-major).
    I32 {
        /// Dimension sizes.
        shape: Vec<usize>,
        /// Flat row-major data.
        data: Vec<i32>,
    },
}

impl HostTensor {
    /// All-zero f32 tensor of the given shape.
    pub fn zeros_f32(shape: &[usize]) -> HostTensor {
        HostTensor::F32 { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    /// Number of scalar elements.
    pub fn num_elements(&self) -> usize {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => {
                shape.iter().product()
            }
        }
    }

    /// Size in bytes (both supported dtypes are 4-byte).
    pub fn byte_size(&self) -> u64 {
        4 * self.num_elements() as u64
    }

    /// Borrow the f32 data (panics on an i32 tensor).
    pub fn as_f32(&self) -> &[f32] {
        match self {
            HostTensor::F32 { data, .. } => data,
            _ => panic!("expected f32 tensor"),
        }
    }

    /// Mutably borrow the f32 data (panics on an i32 tensor).
    pub fn as_f32_mut(&mut self) -> &mut [f32] {
        match self {
            HostTensor::F32 { data, .. } => data,
            _ => panic!("expected f32 tensor"),
        }
    }
}

#[cfg(feature = "pjrt")]
impl HostTensor {
    fn to_literal(&self) -> Result<xla::Literal> {
        Ok(match self {
            HostTensor::F32 { shape, data } => {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims).context("reshaping f32 literal")?
            }
            HostTensor::I32 { shape, data } => {
                let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims).context("reshaping i32 literal")?
            }
        })
    }

    fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape().context("reading literal shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(HostTensor::F32 {
                shape: dims,
                data: lit.to_vec::<f32>().context("reading f32 literal")?,
            }),
            xla::ElementType::S32 => Ok(HostTensor::I32 {
                shape: dims,
                data: lit.to_vec::<i32>().context("reading i32 literal")?,
            }),
            other => Err(Error::msg(format!("unsupported element type {other:?}"))),
        }
    }
}

/// A compiled artifact.
pub struct Executable {
    #[cfg(feature = "pjrt")]
    exe: xla::PjRtLoadedExecutable,
    /// Artifact name (file stem under the artifact directory).
    pub name: String,
}

#[cfg(feature = "pjrt")]
impl Executable {
    /// Execute with host tensors; returns the flattened output tuple.
    pub fn run(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let mut result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .context("executing")?[0][0]
            .to_literal_sync()
            .context("fetching result")?;
        // aot.py lowers with return_tuple=True: decompose the tuple
        let parts = result.decompose_tuple().context("decomposing output tuple")?;
        parts.iter().map(HostTensor::from_literal).collect()
    }
}

#[cfg(not(feature = "pjrt"))]
impl Executable {
    /// Execute with host tensors; returns the flattened output tuple.
    ///
    /// Stub: always fails (the `pjrt` feature is disabled, so no
    /// [`Executable`] can exist — this is unreachable in practice).
    pub fn run(&self, _inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        Err(Error::msg(format!(
            "cannot run `{}`: built without the `pjrt` feature",
            self.name
        )))
    }
}

/// The PJRT CPU runtime: client + compiled-artifact cache.
///
/// Without the `pjrt` feature, [`Runtime::new`] returns an error
/// explaining how to enable the real backend.
pub struct Runtime {
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
    cache: HashMap<String, Executable>,
    dir: PathBuf,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Create a CPU PJRT client rooted at `artifact_dir`.
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, cache: HashMap::new(), dir: artifact_dir.as_ref().to_path_buf() })
    }

    /// Name of the PJRT platform backing this client.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile `<dir>/<name>.hlo.txt` (cached).
    pub fn load(&mut self, name: &str) -> Result<&Executable> {
        if !self.cache.contains_key(name) {
            let path = self.dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )
            .with_context(|| format!("loading HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).with_context(|| format!("compiling {name}"))?;
            self.cache
                .insert(name.to_string(), Executable { exe, name: name.to_string() });
        }
        Ok(&self.cache[name])
    }
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    /// Stub constructor: always fails with an explanation (the offline
    /// default build carries no PJRT backend).
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Runtime> {
        let _ = artifact_dir.as_ref();
        Err(Error::msg(
            "PJRT runtime unavailable: this build has no `pjrt` feature. Vendor the \
             `xla` crate and build with `--features pjrt` (see Cargo.toml) to execute \
             real artifacts; solvers and benches work without it.",
        ))
    }

    /// Name of the PJRT platform backing this client (stub).
    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    /// Load + compile an artifact (stub: always fails).
    pub fn load(&mut self, name: &str) -> Result<&Executable> {
        let _ = (&self.cache, &self.dir);
        Err(Error::msg(format!(
            "cannot load `{name}`: built without the `pjrt` feature"
        )))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_sizes() {
        let t = HostTensor::zeros_f32(&[2, 3, 4]);
        assert_eq!(t.num_elements(), 24);
        assert_eq!(t.byte_size(), 96);
        assert_eq!(t.as_f32().len(), 24);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_reports_missing_feature() {
        let e = Runtime::new("artifacts").err().expect("stub must fail");
        assert!(e.to_string().contains("pjrt"), "{e}");
    }

    // PJRT round-trip tests live in rust/tests/runtime_e2e.rs (they need
    // built artifacts).
}
