//! The worker pool: interruptible solver sessions with death recovery.
//!
//! Each worker thread loops `next_job -> cache probe -> session ->
//! terminal`. The session runs under `catch_unwind`: a panicking solve
//! (injected via the `serve.worker` failpoint or real) takes the worker
//! down, which (a) retries the request exactly once on a fresh worker
//! with the first attempt recorded in its degradation provenance, and
//! (b) respawns a replacement thread so the pool never shrinks.

use super::{lock_recover, queue, JobHandle, QueuedJob, ServeEvent, ServiceInner, ServiceStats, Terminal};
use crate::coordinator::{Backend, Coordinator, SolveRequest, SolveResponse};
use crate::coordinator::{Watchdog, WatchdogConfig};
use crate::moccasin::MoccasinSolver;
use crate::util::{events, panic_note, Rng};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A response with nothing computed (a job preempted before dispatch
/// still owes its caller a well-formed best-so-far).
pub(crate) fn empty_response(note: &str) -> SolveResponse {
    SolveResponse {
        solution: None,
        trace: Vec::new(),
        proved_optimal: false,
        from_cache: false,
        error: Some(note.to_string()),
        stats: Default::default(),
        degradation: None,
    }
}

/// The coordinator-shaped request a serve job corresponds to — only
/// used to derive the shared cache key (Moccasin backend, no explicit
/// order; `time_limit` is not part of the key).
fn coord_request(inner: &ServiceInner, job: &QueuedJob) -> SolveRequest {
    SolveRequest {
        budget: job.req.budget,
        c: job.req.c,
        time_limit: job.req.deadline,
        backend: Backend::Moccasin,
        order: None,
        presolve: job.req.presolve,
        search: job.req.search,
        stall_ms: inner.cfg.stall_ms,
        rss_limit_kb: None,
    }
}

/// Spawn worker `idx` (also used to respawn after a death). The handle
/// is pushed into `worker_handles` for shutdown to join. Returns
/// whether the OS granted the thread: a failed spawn shrinks the pool
/// instead of panicking (the caller decides what an empty pool means).
pub(crate) fn spawn_worker(inner: &Arc<ServiceInner>, idx: usize) -> bool {
    let owned = Arc::clone(inner);
    let spawned = std::thread::Builder::new()
        .name(format!("moccasin-serve-{idx}"))
        .spawn(move || worker_loop(&owned, idx));
    match spawned {
        Ok(h) => {
            lock_recover(&inner.worker_handles).push(h);
            true
        }
        Err(e) => {
            eprintln!("serve: could not spawn worker {idx}: {e}");
            false
        }
    }
}

fn worker_loop(inner: &Arc<ServiceInner>, idx: usize) {
    while let Some(job) = queue::next_job(inner) {
        if job.handle.is_finished() {
            continue;
        }
        // shared schedule cache: an identical request already solved
        // cleanly (any submitter, any time) is answered immediately
        let key = Coordinator::cache_key(&job.req.graph, &coord_request(inner, &job));
        let cached = lock_recover(&inner.cache).get(&key).cloned();
        if let Some(mut resp) = cached {
            resp.from_cache = true;
            ServiceStats::bump(&inner.stats.cache_hits);
            inner.finish(&job.handle, Terminal::Solved(Box::new(resp)));
            continue;
        }
        ServiceStats::bump(&inner.stats.cache_misses);

        inner.in_flight.fetch_add(1, Ordering::Relaxed);
        job.handle.emit(ServeEvent::Started { job: job.handle.id, attempt: job.attempt });
        let t0 = Instant::now();
        let result = catch_unwind(AssertUnwindSafe(|| run_session(inner, &job)));
        inner.in_flight.fetch_sub(1, Ordering::Relaxed);

        match result {
            Ok((terminal, cacheable)) => {
                inner.update_ema(t0.elapsed().as_millis() as u64);
                if cacheable {
                    if let Terminal::Solved(resp) = &terminal {
                        lock_recover(&inner.cache).insert(key, (**resp).clone());
                    }
                }
                inner.finish(&job.handle, terminal);
            }
            Err(payload) => {
                // the session tore this thread's stack down: recover
                // the request, then let the thread die and respawn
                let note = panic_note(payload.as_ref());
                ServiceStats::bump(&inner.stats.worker_deaths);
                events::note_member_panic();
                let shutting_down = inner.shutdown.load(Ordering::Acquire);
                let will_retry = job.attempt == 0
                    && !shutting_down
                    && !job.handle.incumbent.should_stop()
                    && !job.remaining().is_zero();
                job.handle.emit(ServeEvent::Died {
                    job: job.handle.id,
                    attempt: job.attempt,
                    note: note.clone(),
                    will_retry,
                });
                if will_retry {
                    ServiceStats::bump(&inner.stats.retries);
                    events::note_member_retry();
                    // deterministic jittered backoff, as solve_many's
                    let mut rng = Rng::seed_from_u64(0x5EBE ^ job.handle.id);
                    std::thread::sleep(Duration::from_millis(5 + rng.next_u64() % 20));
                    // re-check shutdown UNDER the queue lock: shutdown
                    // drains the queue while holding it, so a retry
                    // pushed after that drain would never be dispatched
                    // and its job would lose its terminal
                    let mut q = lock_recover(&inner.queue);
                    if inner.shutdown.load(Ordering::Acquire) {
                        drop(q);
                        inner.finish(
                            &job.handle,
                            Terminal::Failed {
                                error: format!(
                                    "service shut down before retry: {note}"
                                ),
                            },
                        );
                    } else {
                        q.push_front(QueuedJob {
                            handle: Arc::clone(&job.handle),
                            req: job.req.clone(),
                            attempt: 1,
                            enqueued: job.enqueued,
                            prior_failure: Some(note),
                        });
                        drop(q);
                        inner.available.notify_one();
                    }
                } else {
                    let outcome = death_terminal(&job.handle, job.attempt, &note);
                    inner.finish(&job.handle, outcome);
                }
                if !shutting_down {
                    spawn_worker(inner, idx);
                }
                return;
            }
        }
    }
}

/// Terminal for a job whose worker died with no retry left: honor an
/// outstanding cancel/preempt label, otherwise fail structurally.
fn death_terminal(handle: &JobHandle, attempt: u32, note: &str) -> Terminal {
    if handle.client_cancel.load(Ordering::Acquire) {
        return Terminal::Cancelled;
    }
    if handle.incumbent.is_preempted() {
        return Terminal::Preempted(Box::new(empty_response(&format!(
            "worker died before preempt completed: {note}"
        ))));
    }
    Terminal::Failed {
        error: format!("worker died on attempt {attempt} (no retry left): {note}"),
    }
}

/// One solver session: watchdog-guarded, interruptible, streaming.
/// Returns the terminal plus whether the response is cacheable (clean,
/// first-attempt, unkilled, completed solves only).
fn run_session(inner: &ServiceInner, job: &QueuedJob) -> (Terminal, bool) {
    // injected structural failure (Error/Timeout) or death (Panic —
    // propagates to the worker loop's catch_unwind); compiled out
    // without cfg(test) / --features failpoints
    crate::fail_point!(
        "serve.worker",
        (
            Terminal::Failed { error: "failpoint 'serve.worker' fired".to_string() },
            false,
        )
    );
    let remaining = job.remaining();
    if remaining.is_zero() {
        // raced the sweeper at dispatch; answer exactly like it would
        return (
            Terminal::Expired { waited_ms: job.enqueued.elapsed().as_millis() as u64 },
            false,
        );
    }
    let inc = Arc::clone(&job.handle.incumbent);
    let wd = Watchdog::spawn(
        Arc::clone(&inc),
        WatchdogConfig::for_wall(remaining, None, inner.cfg.stall_ms),
    );
    // injected stall (Delay): the session holds its worker without
    // beating the heartbeat — the watchdog (and queue backpressure
    // tests) see a genuinely stuck session
    crate::fail_point!("serve.session");

    let solver = MoccasinSolver {
        c: job.req.c,
        time_limit: remaining,
        presolve: job.req.presolve,
        search: job.req.search,
        incumbent: Some(Arc::clone(&inc)),
        ..Default::default()
    };
    let session_start = Instant::now();
    let handle = &job.handle;
    let out = solver.solve_with(&job.req.graph, job.req.budget, None, |sol| {
        handle.emit(ServeEvent::Incumbent {
            job: handle.id,
            duration: sol.eval.duration,
            peak_mem: sol.eval.peak_mem,
            remats: sol.eval.remat_count,
            elapsed: session_start.elapsed(),
        });
    });
    let report = wd.stop();

    let mut degradation = out.degradation;
    if let Some(reason) = report.reason {
        degradation.note_failure(format!("watchdog: {}", reason.as_str()));
    }
    if let Some(prior) = &job.prior_failure {
        degradation.note_failure(format!("worker death on attempt 0: {prior}"));
        degradation.retries += 1;
    }
    let mut stats = out.stats;
    stats.watchdog_kills += u64::from(report.kills);
    if job.attempt > 0 {
        stats.member_panics += 1;
        stats.member_retries += 1;
    }
    let cacheable = job.attempt == 0
        && report.kills == 0
        && degradation.is_clean()
        && (out.best.is_some() || out.proved_optimal);
    let resp = SolveResponse {
        solution: out.best,
        trace: out.trace.iter().map(|p| (p.elapsed, p.duration)).collect(),
        // a watchdog-killed session cannot claim a proof
        proved_optimal: out.proved_optimal && report.kills == 0,
        from_cache: false,
        error: None,
        stats,
        degradation: Some(degradation),
    };
    if handle.client_cancel.load(Ordering::Acquire) {
        (Terminal::Cancelled, false)
    } else if inc.is_preempted() {
        (Terminal::Preempted(Box::new(resp)), false)
    } else {
        (Terminal::Solved(Box::new(resp)), cacheable)
    }
}
