//! NDJSON wire format: one JSON object per line, both directions.
//!
//! Client → server lines are either **submits** or **controls**:
//!
//! ```json
//! {"graph":"G1","budget_frac":0.9,"c":2,"deadline_ms":30000,"search":"learned","tag":"a"}
//! {"graph":"rl:100:236:1","budget":12345}
//! {"control":"preempt","job":3}
//! {"control":"tighten","job":3,"bound":420}
//! {"control":"cancel","job":3}
//! ```
//!
//! `graph` is a spec accepted by
//! [`graph_from_spec`](crate::generators::graph_from_spec); the budget
//! is absolute (`budget`) or a fraction of the graph's no-remat peak
//! (`budget_frac`). `tag` is an opaque client string echoed on every
//! event for that job.
//!
//! Server → client lines mirror [`ServeEvent`]: `{"event":"queued"|
//! "started"|"incumbent"|"died"|"terminal", "job":N, "tag":...}` plus
//! per-event fields; terminal lines carry `"outcome"` (`solved`,
//! `preempted`, `cancelled`, `overloaded`, `expired`, `failed`) and,
//! for solved/preempted, the schedule summary and degradation
//! provenance. A malformed request line is answered with
//! `{"event":"error","error":...}` — the wire never goes silent.

use super::json::{escape, parse, Json};
use super::{ControlSignal, JobId, ServeConfig, ServeEvent, ServeRequest, Terminal};
use crate::cp::SearchStrategy;
use crate::generators::graph_from_spec;
use crate::graph::topological_order;
use std::fmt::Write as _;
use std::sync::Arc;
use std::time::Duration;

/// A parsed client line.
pub enum WireMsg {
    /// Submit a solve; `tag` is echoed on every event for the job.
    Submit {
        /// The resolved request.
        req: ServeRequest,
        /// Opaque client correlation string.
        tag: Option<String>,
    },
    /// A control signal for an earlier job.
    Control {
        /// The job (as returned in that job's `queued` event / assigned
        /// by submit order).
        job: JobId,
        /// The signal.
        signal: ControlSignal,
    },
}

/// Parse one client line. Errors are human-readable and meant to be
/// echoed back as an `error` event.
pub fn parse_line(line: &str, cfg: &ServeConfig) -> Result<WireMsg, String> {
    let v = parse(line)?;
    if v.get("control").is_some() {
        return parse_control(&v);
    }
    parse_submit(&v, cfg)
}

fn parse_control(v: &Json) -> Result<WireMsg, String> {
    let kind = v
        .get("control")
        .and_then(Json::as_str)
        .ok_or_else(|| "\"control\" must be a string".to_string())?;
    let job = v
        .get("job")
        .and_then(Json::as_u64)
        .ok_or_else(|| "control needs a \"job\" id".to_string())?;
    let signal = match kind {
        "preempt" => ControlSignal::Preempt,
        "cancel" => ControlSignal::Cancel,
        "tighten" => {
            let bound = v
                .get("bound")
                .and_then(Json::as_u64)
                .ok_or_else(|| "tighten needs a \"bound\"".to_string())?;
            ControlSignal::TightenBound(bound)
        }
        other => return Err(format!("unknown control {other:?} (use preempt|tighten|cancel)")),
    };
    Ok(WireMsg::Control { job, signal })
}

fn parse_submit(v: &Json, cfg: &ServeConfig) -> Result<WireMsg, String> {
    let spec = v
        .get("graph")
        .and_then(Json::as_str)
        .ok_or_else(|| "submit needs a \"graph\" spec".to_string())?;
    let graph = graph_from_spec(spec)
        .ok_or_else(|| format!("unknown graph spec {spec:?} (named instance or rl:n:m:seed)"))?;
    let budget = match (v.get("budget").and_then(Json::as_u64), v.get("budget_frac")) {
        (Some(b), _) => b,
        (None, Some(f)) => {
            let frac = f.as_f64().ok_or_else(|| "\"budget_frac\" must be a number".to_string())?;
            if !(frac.is_finite() && frac > 0.0) {
                return Err(format!("budget_frac {frac} out of range"));
            }
            let order = topological_order(&graph).ok_or_else(|| "graph has a cycle".to_string())?;
            let peak = graph
                .peak_mem_no_remat(&order)
                .map_err(|e| format!("cannot evaluate no-remat peak: {e:?}"))?;
            (peak as f64 * frac) as u64
        }
        (None, None) => return Err("submit needs \"budget\" or \"budget_frac\"".to_string()),
    };
    let c = match v.get("c") {
        None => 2,
        Some(c) => c.as_u64().ok_or_else(|| "\"c\" must be a nonnegative integer".to_string())?
            as usize,
    };
    let deadline = match v.get("deadline_ms") {
        None => cfg.default_deadline,
        Some(d) => Duration::from_millis(
            d.as_u64().ok_or_else(|| "\"deadline_ms\" must be a nonnegative integer".to_string())?,
        ),
    };
    let search = match v.get("search").and_then(Json::as_str) {
        None => SearchStrategy::default(),
        Some(name) => SearchStrategy::parse(name)
            .ok_or_else(|| format!("unknown search {name:?} (use chronological|learned)"))?,
    };
    let tag = v.get("tag").and_then(Json::as_str).map(str::to_string);
    Ok(WireMsg::Submit {
        req: ServeRequest {
            graph: Arc::new(graph),
            budget,
            c,
            deadline,
            search,
            presolve: Default::default(),
        },
        tag,
    })
}

fn push_tag(out: &mut String, tag: Option<&str>) {
    if let Some(t) = tag {
        let _ = write!(out, ",\"tag\":\"{}\"", escape(t));
    }
}

/// Encode an error answer for a malformed client line.
pub fn encode_error(err: &str) -> String {
    format!("{{\"event\":\"error\",\"error\":\"{}\"}}", escape(err))
}

/// Encode one event as a single NDJSON line (no trailing newline).
pub fn encode_event(ev: &ServeEvent, tag: Option<&str>) -> String {
    let mut out = String::with_capacity(96);
    match ev {
        ServeEvent::Queued { job, position } => {
            let _ = write!(out, "{{\"event\":\"queued\",\"job\":{job},\"position\":{position}");
        }
        ServeEvent::Started { job, attempt } => {
            let _ = write!(out, "{{\"event\":\"started\",\"job\":{job},\"attempt\":{attempt}");
        }
        ServeEvent::Incumbent { job, duration, peak_mem, remats, elapsed } => {
            let _ = write!(
                out,
                "{{\"event\":\"incumbent\",\"job\":{job},\"duration\":{duration},\
                 \"peak_mem\":{peak_mem},\"remats\":{remats},\"elapsed_ms\":{}",
                elapsed.as_millis()
            );
        }
        ServeEvent::Died { job, attempt, note, will_retry } => {
            let _ = write!(
                out,
                "{{\"event\":\"died\",\"job\":{job},\"attempt\":{attempt},\
                 \"note\":\"{}\",\"will_retry\":{will_retry}",
                escape(note)
            );
        }
        ServeEvent::Terminal { job, outcome } => {
            let _ = write!(
                out,
                "{{\"event\":\"terminal\",\"job\":{job},\"outcome\":\"{}\"",
                outcome.name()
            );
            encode_terminal(&mut out, outcome);
        }
    }
    push_tag(&mut out, tag);
    out.push('}');
    out
}

fn encode_terminal(out: &mut String, outcome: &Terminal) {
    match outcome {
        Terminal::Solved(resp) | Terminal::Preempted(resp) => {
            match resp.solution.as_ref() {
                Some(sol) => {
                    let _ = write!(
                        out,
                        ",\"duration\":{},\"peak_mem\":{},\"remats\":{}",
                        sol.eval.duration, sol.eval.peak_mem, sol.eval.remat_count
                    );
                }
                None => out.push_str(",\"duration\":null"),
            }
            let _ = write!(
                out,
                ",\"proved_optimal\":{},\"from_cache\":{},\"improvements\":{}",
                resp.proved_optimal,
                resp.from_cache,
                resp.trace.len()
            );
            if let Some(err) = &resp.error {
                let _ = write!(out, ",\"error\":\"{}\"", escape(err));
            }
            if let Some(deg) = &resp.degradation {
                // to_json emits a complete object — embed it verbatim
                let _ = write!(out, ",\"degradation\":{}", deg.to_json());
            }
        }
        Terminal::Cancelled => {}
        Terminal::Overloaded { queue_len, est_wait_ms, reason } => {
            let _ = write!(
                out,
                ",\"queue_len\":{queue_len},\"est_wait_ms\":{est_wait_ms},\"reason\":\"{}\"",
                escape(reason)
            );
        }
        Terminal::Expired { waited_ms } => {
            let _ = write!(out, ",\"waited_ms\":{waited_ms}");
        }
        Terminal::Failed { error } => {
            let _ = write!(out, ",\"error\":\"{}\"", escape(error));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ServeConfig {
        ServeConfig::default()
    }

    #[test]
    fn parses_submit_with_absolute_budget() {
        let msg = parse_line(
            r#"{"graph":"rl:100:236:1","budget":500,"c":3,"deadline_ms":1500,"tag":"x"}"#,
            &cfg(),
        )
        .unwrap();
        let WireMsg::Submit { req, tag } = msg else { panic!("expected submit") };
        assert_eq!(req.budget, 500);
        assert_eq!(req.c, 3);
        assert_eq!(req.deadline, Duration::from_millis(1500));
        assert_eq!(tag.as_deref(), Some("x"));
        assert_eq!(req.graph.n(), 100);
    }

    #[test]
    fn parses_submit_with_budget_fraction_of_no_remat_peak() {
        let msg = parse_line(r#"{"graph":"G1","budget_frac":0.9}"#, &cfg()).unwrap();
        let WireMsg::Submit { req, tag } = msg else { panic!("expected submit") };
        assert!(tag.is_none());
        assert_eq!(req.deadline, cfg().default_deadline);
        let order = topological_order(&req.graph).unwrap();
        let peak = req.graph.peak_mem_no_remat(&order).unwrap();
        assert_eq!(req.budget, (peak as f64 * 0.9) as u64);
        assert!(req.budget < peak);
    }

    #[test]
    fn parses_controls() {
        let m = parse_line(r#"{"control":"preempt","job":7}"#, &cfg()).unwrap();
        assert!(
            matches!(m, WireMsg::Control { job: 7, signal: ControlSignal::Preempt })
        );
        let m = parse_line(r#"{"control":"tighten","job":7,"bound":42}"#, &cfg()).unwrap();
        assert!(matches!(
            m,
            WireMsg::Control { job: 7, signal: ControlSignal::TightenBound(42) }
        ));
        let m = parse_line(r#"{"control":"cancel","job":9}"#, &cfg()).unwrap();
        assert!(matches!(m, WireMsg::Control { job: 9, signal: ControlSignal::Cancel }));
    }

    #[test]
    fn malformed_lines_give_structured_errors() {
        for (line, needle) in [
            ("{", "expected"),
            (r#"{"budget":1}"#, "graph"),
            (r#"{"graph":"nope","budget":1}"#, "unknown graph spec"),
            (r#"{"graph":"G1"}"#, "budget"),
            (r#"{"graph":"G1","budget_frac":-0.5}"#, "out of range"),
            (r#"{"control":"explode","job":1}"#, "unknown control"),
            (r#"{"control":"tighten","job":1}"#, "bound"),
            (r#"{"graph":"G1","budget":1,"search":"psychic"}"#, "unknown search"),
        ] {
            let err = parse_line(line, &cfg()).err().unwrap_or_else(|| {
                panic!("line {line:?} should fail");
            });
            assert!(err.contains(needle), "error {err:?} should mention {needle:?}");
            // every error encodes into a valid single-line event
            let enc = encode_error(&err);
            let v = parse(&enc).unwrap();
            assert_eq!(v.get("event").and_then(Json::as_str), Some("error"));
            assert!(!enc.contains('\n'));
        }
    }

    #[test]
    fn events_encode_to_single_line_json() {
        let evs = [
            ServeEvent::Queued { job: 1, position: 0 },
            ServeEvent::Started { job: 1, attempt: 0 },
            ServeEvent::Incumbent {
                job: 1,
                duration: 10,
                peak_mem: 20,
                remats: 2,
                elapsed: Duration::from_millis(7),
            },
            ServeEvent::Died {
                job: 1,
                attempt: 0,
                note: "boom \"quote\"".to_string(),
                will_retry: true,
            },
            ServeEvent::Terminal { job: 1, outcome: Terminal::Cancelled },
            ServeEvent::Terminal {
                job: 2,
                outcome: Terminal::Overloaded {
                    queue_len: 5,
                    est_wait_ms: 900,
                    reason: "queue full (5/5)".to_string(),
                },
            },
            ServeEvent::Terminal { job: 3, outcome: Terminal::Expired { waited_ms: 60 } },
            ServeEvent::Terminal {
                job: 4,
                outcome: Terminal::Failed { error: "worker died".to_string() },
            },
        ];
        for ev in &evs {
            let line = encode_event(ev, Some("t-1"));
            assert!(!line.contains('\n'), "single line: {line}");
            let v = parse(&line).unwrap_or_else(|e| panic!("{line}: {e}"));
            assert_eq!(v.get("tag").and_then(Json::as_str), Some("t-1"));
            assert!(v.get("event").and_then(Json::as_str).is_some());
        }
        // terminal lines carry the outcome class
        let line = encode_event(
            &ServeEvent::Terminal { job: 2, outcome: Terminal::Cancelled },
            None,
        );
        let v = parse(&line).unwrap();
        assert_eq!(v.get("outcome").and_then(Json::as_str), Some("cancelled"));
        assert!(v.get("tag").is_none());
    }

    #[test]
    fn solved_terminal_carries_schedule_and_degradation() {
        use crate::moccasin::{Degradation, Rung};
        let resp = crate::serve::worker::empty_response("nothing yet");
        let mut resp = resp;
        resp.degradation = Some(Degradation::clean(Rung::Learned));
        let line = encode_event(
            &ServeEvent::Terminal { job: 9, outcome: Terminal::Solved(Box::new(resp)) },
            Some("z"),
        );
        let v = parse(&line).unwrap_or_else(|e| panic!("{line}: {e}"));
        assert_eq!(v.get("outcome").and_then(Json::as_str), Some("solved"));
        assert!(matches!(v.get("duration"), Some(Json::Null)));
        assert_eq!(v.get("error").and_then(Json::as_str), Some("nothing yet"));
        let deg = v.get("degradation").expect("degradation object");
        assert_eq!(deg.get("rung").and_then(Json::as_str), Some("learned"));
        assert_eq!(deg.get("clean").and_then(Json::as_bool), Some(true));
    }
}
