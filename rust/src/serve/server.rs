//! The serve daemon: NDJSON over a Unix-domain socket.
//!
//! One process-wide [`SolverService`] (worker pool, admission queue,
//! shared schedule cache) serves every connection; each connection gets
//! a reader thread (parses submit/control lines) and a writer that
//! streams the connection's job events back, tagged for correlation.
//! The transport is deliberately line-oriented so `nc -U` is a usable
//! client.

use super::wire::{self, WireMsg};
use super::{ServeConfig, ServeEvent, SolverService};
use crate::util::lock_recover;
use std::collections::HashMap;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::Path;
use std::sync::{mpsc, Arc, Mutex};

/// A bound daemon, ready to accept connections.
pub struct Server {
    listener: UnixListener,
    svc: Arc<SolverService>,
    cfg: ServeConfig,
}

impl Server {
    /// Bind the daemon socket (replacing a stale socket file from a
    /// previous run) and start the solver service.
    pub fn bind(path: &Path, cfg: ServeConfig) -> std::io::Result<Server> {
        let _ = std::fs::remove_file(path);
        let listener = UnixListener::bind(path)?;
        let svc = Arc::new(SolverService::start(cfg.clone()));
        Ok(Server { listener, svc, cfg })
    }

    /// Accept loop: one handler thread per connection. Runs until the
    /// process is killed (the daemon has no in-band shutdown; `SIGTERM`
    /// it and restart — requests in flight get their terminals from the
    /// service's own shutdown path only on clean `drop`).
    pub fn serve(&self) -> std::io::Result<()> {
        for stream in self.listener.incoming() {
            let stream = stream?;
            let svc = Arc::clone(&self.svc);
            let cfg = self.cfg.clone();
            let _ = std::thread::Builder::new()
                .name("moccasin-serve-conn".to_string())
                .spawn(move || handle_connection(stream, &svc, &cfg));
        }
        Ok(())
    }

    /// The underlying service (tests and embedders).
    pub fn service(&self) -> &SolverService {
        &self.svc
    }
}

/// Write one NDJSON line (shared by the event pump and the reader's
/// error answers; the mutex keeps lines whole).
fn send_line(out: &Mutex<BufWriter<UnixStream>>, line: &str) -> bool {
    let mut w = lock_recover(out);
    w.write_all(line.as_bytes()).and_then(|_| w.write_all(b"\n")).and_then(|_| w.flush()).is_ok()
}

fn handle_connection(stream: UnixStream, svc: &SolverService, cfg: &ServeConfig) {
    let Ok(write_half) = stream.try_clone() else { return };
    let out = Arc::new(Mutex::new(BufWriter::new(write_half)));
    // job -> client tag, shared by the reader (registers under the lock
    // spanning submit, so the writer can never encode a job's event
    // before its tag is visible) and the event pump (reads at encode)
    let tags: Arc<Mutex<HashMap<u64, Option<String>>>> = Arc::new(Mutex::new(HashMap::new()));
    let (tx, rx) = mpsc::channel::<ServeEvent>();

    let pump = {
        let out = Arc::clone(&out);
        let tags = Arc::clone(&tags);
        std::thread::Builder::new()
            .name("moccasin-serve-pump".to_string())
            .spawn(move || {
                // ends when every sender is gone: the reader's handle on
                // EOF plus each job's handle at its terminal
                while let Ok(ev) = rx.recv() {
                    let mut map = lock_recover(&tags);
                    let (job, terminal) = match &ev {
                        ServeEvent::Queued { job, .. }
                        | ServeEvent::Started { job, .. }
                        | ServeEvent::Incumbent { job, .. }
                        | ServeEvent::Died { job, .. } => (*job, false),
                        ServeEvent::Terminal { job, .. } => (*job, true),
                    };
                    let tag = map.get(&job).cloned().flatten();
                    if terminal {
                        map.remove(&job);
                    }
                    drop(map);
                    let line = wire::encode_event(&ev, tag.as_deref());
                    if !send_line(&out, &line) {
                        return; // client hung up
                    }
                }
            })
    };
    // No pump thread means no way to deliver events for this
    // connection: drop it (the client sees EOF and can reconnect)
    // instead of taking the whole accept loop down.
    let Ok(pump) = pump else {
        eprintln!("serve: could not spawn event pump; dropping connection");
        return;
    };

    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        match wire::parse_line(&line, cfg) {
            Ok(WireMsg::Submit { req, tag }) => {
                let mut map = lock_recover(&tags);
                let id = svc.submit(req, tx.clone());
                map.insert(id, tag);
            }
            Ok(WireMsg::Control { job, signal }) => {
                if !svc.control(job, signal) {
                    let _ = send_line(
                        &out,
                        &wire::encode_error(&format!(
                            "control for unknown or finished job {job}"
                        )),
                    );
                }
            }
            Err(e) => {
                if !send_line(&out, &wire::encode_error(&e)) {
                    break;
                }
            }
        }
    }
    // EOF (or error): stop feeding the pump; it drains in-flight jobs'
    // events and exits once their terminals have been delivered
    drop(tx);
    let _ = pump.join();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::json::Json;
    use std::time::Duration;

    #[test]
    fn socket_round_trip_submit_stream_terminal() {
        let _g = crate::serve::tests::serial();
        crate::util::failpoint::reset();
        let path = std::env::temp_dir()
            .join(format!("moccasin-serve-test-{}.sock", std::process::id()));
        let server = Server::bind(
            &path,
            ServeConfig { workers: 1, ..Default::default() },
        )
        .expect("bind");
        let listener = server;
        std::thread::spawn(move || {
            let _ = listener.serve();
        });

        let mut stream = UnixStream::connect(&path).expect("connect");
        stream
            .write_all(
                b"{\"graph\":\"rl:40:90:7\",\"budget_frac\":0.85,\"deadline_ms\":20000,\
                  \"tag\":\"rt\"}\nnot json\n",
            )
            .unwrap();
        stream.flush().unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(30)))
            .unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        let mut saw_error = false;
        let mut saw_incumbent = false;
        let mut outcome = None;
        for line in reader.lines() {
            let line = line.expect("daemon must answer before the read timeout");
            let v = crate::serve::json::parse(&line).unwrap_or_else(|e| panic!("{line}: {e}"));
            match v.get("event").and_then(Json::as_str) {
                Some("error") => saw_error = true,
                Some("incumbent") => {
                    saw_incumbent = true;
                    assert_eq!(v.get("tag").and_then(Json::as_str), Some("rt"));
                }
                Some("terminal") => {
                    assert_eq!(v.get("tag").and_then(Json::as_str), Some("rt"));
                    outcome = v.get("outcome").and_then(Json::as_str).map(str::to_string);
                    break;
                }
                _ => {}
            }
        }
        assert!(saw_error, "malformed line must be answered with an error event");
        assert!(saw_incumbent, "incumbents must stream over the wire");
        assert_eq!(outcome.as_deref(), Some("solved"));
        let _ = std::fs::remove_file(&path);
    }
}
