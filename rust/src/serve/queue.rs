//! Job handles, the admitted-request queue, and the expiry sweeper.
//!
//! The exactly-one-terminal invariant is arbitrated here: every path
//! that wants to deliver a job's outcome goes through
//! [`JobHandle::finish`], a compare-and-swap that exactly one caller
//! wins. Losers (e.g. a sweeper expiring a job the instant a worker
//! dequeues it) see `false` and drop their outcome.

use super::{lock_recover, JobId, ServeEvent, ServeRequest, ServiceInner, Terminal};
use crate::util::Incumbent;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Per-job shared state: the interrupt surface (`incumbent`), the
/// terminal arbiter (`finished`) and the event channel back to the
/// submitter.
pub(crate) struct JobHandle {
    /// The job's id (key into [`ServiceInner::jobs`]).
    pub(crate) id: JobId,
    /// Shared incumbent: control signals flip its flags, the session's
    /// deadline/watchdog/engine poll them, and `TightenBound` records
    /// into it.
    pub(crate) incumbent: Arc<Incumbent>,
    /// Set by [`ControlSignal::Cancel`](super::ControlSignal::Cancel)
    /// before `incumbent.cancel()`, so a stopped session can tell a
    /// client cancel from any other cancellation source.
    pub(crate) client_cancel: AtomicBool,
    /// Terminal-delivered flag (the CAS arbiter).
    finished: AtomicBool,
    /// Event channel to the submitter. `mpsc::Sender` is not `Sync` on
    /// all toolchains in range, so it sits behind a mutex; sends are
    /// brief and never block (the channel is unbounded).
    events: Mutex<mpsc::Sender<ServeEvent>>,
}

impl JobHandle {
    pub(crate) fn new(id: JobId, events: mpsc::Sender<ServeEvent>) -> Arc<Self> {
        Arc::new(JobHandle {
            id,
            incumbent: Arc::new(Incumbent::new()),
            client_cancel: AtomicBool::new(false),
            finished: AtomicBool::new(false),
            events: Mutex::new(events),
        })
    }

    /// Best-effort progress event: a submitter that dropped its
    /// receiver just stops listening — never an error.
    pub(crate) fn emit(&self, ev: ServeEvent) {
        let _ = lock_recover(&self.events).send(ev);
    }

    /// Deliver the terminal iff this caller wins the race. Exactly one
    /// `finish` per job returns `true`.
    pub(crate) fn finish(&self, outcome: Terminal) -> bool {
        if self
            .finished
            .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
            .is_err()
        {
            return false;
        }
        self.emit(ServeEvent::Terminal { job: self.id, outcome });
        true
    }

    pub(crate) fn is_finished(&self) -> bool {
        self.finished.load(Ordering::Acquire)
    }
}

/// An admitted request waiting for (or re-queued to) a worker.
pub(crate) struct QueuedJob {
    pub(crate) handle: Arc<JobHandle>,
    pub(crate) req: ServeRequest,
    /// 0 = first attempt; 1 = the single post-death retry.
    pub(crate) attempt: u32,
    /// Original admission time — kept across a retry, so the deadline
    /// spans queue wait + all attempts.
    pub(crate) enqueued: Instant,
    /// The first attempt's panic note, threaded into the retried
    /// response's degradation provenance.
    pub(crate) prior_failure: Option<String>,
}

impl QueuedJob {
    /// Deadline remaining from the original admission instant.
    pub(crate) fn remaining(&self) -> Duration {
        self.req.deadline.saturating_sub(self.enqueued.elapsed())
    }
}

/// Resolve a queued job that must not be dispatched: client-cancelled,
/// preempted while queued (nothing computed — an empty best-so-far), or
/// past its deadline. `None` means "dispatch it".
fn undispatchable_outcome(job: &QueuedJob) -> Option<Terminal> {
    if job.handle.client_cancel.load(Ordering::Acquire) {
        return Some(Terminal::Cancelled);
    }
    if job.handle.incumbent.is_preempted() {
        return Some(Terminal::Preempted(Box::new(super::worker::empty_response(
            "preempted while queued",
        ))));
    }
    if job.remaining().is_zero() {
        return Some(Terminal::Expired { waited_ms: job.enqueued.elapsed().as_millis() as u64 });
    }
    None
}

/// Finish every queued job that became undispatchable; retain the rest.
/// Shared by the sweeper (promptness while all workers are busy) and
/// the dispatch path (exactness at the pop).
fn sweep_queue(inner: &ServiceInner) {
    let mut finish: Vec<(Arc<JobHandle>, Terminal)> = Vec::new();
    {
        let mut q = lock_recover(&inner.queue);
        q.retain(|job| match undispatchable_outcome(job) {
            Some(outcome) => {
                finish.push((Arc::clone(&job.handle), outcome));
                false
            }
            None => true,
        });
    }
    // deliver outside the queue lock (finish takes the jobs lock;
    // queue-before-jobs is the crate's lock order, but event sends
    // don't need either)
    for (handle, outcome) in finish {
        inner.finish(&handle, outcome);
    }
}

/// Block until a dispatchable job is available (or shutdown). Expired /
/// cancelled / queue-preempted jobs encountered on the way are answered
/// here, never returned.
pub(crate) fn next_job(inner: &ServiceInner) -> Option<QueuedJob> {
    loop {
        if inner.shutdown.load(Ordering::Acquire) {
            return None;
        }
        sweep_queue(inner);
        {
            let mut q = lock_recover(&inner.queue);
            if let Some(job) = q.pop_front() {
                return Some(job);
            }
            // short timed wait: re-check shutdown and queue expiries
            // even if a notify is lost to a poisoned wake
            let (guard, _) = inner
                .available
                .wait_timeout(q, Duration::from_millis(20))
                .unwrap_or_else(|p| p.into_inner());
            drop(guard);
        }
    }
}

/// The expiry sweeper: answers jobs whose deadline passes (or that are
/// cancelled/preempted) *while still queued*, promptly, even when every
/// worker is busy — a queued request must never wait for a worker just
/// to learn it expired.
pub(crate) fn spawn_sweeper(inner: &Arc<ServiceInner>) {
    let owned = Arc::clone(inner);
    let spawned = std::thread::Builder::new()
        .name("moccasin-serve-sweep".to_string())
        .spawn(move || loop {
            if owned.shutdown.load(Ordering::Acquire) {
                return;
            }
            sweep_queue(&owned);
            std::thread::sleep(Duration::from_millis(10));
        });
    match spawned {
        Ok(h) => lock_recover(&inner.worker_handles).push(h),
        // Degraded but functional: without the sweeper, expired queued
        // jobs are still answered at dispatch (next_job re-checks the
        // deadline) — expiry is just no longer proactive.
        Err(e) => eprintln!("serve: could not spawn sweeper thread: {e}"),
    }
}
