//! Minimal std-only JSON parsing for the serving wire format.
//!
//! The build is fully offline (no `serde`), and the emission side of
//! the wire format is hand-rolled `format!` strings like the bench
//! JSONs — but *parsing* client request lines needs a real (if small)
//! JSON reader. This is a recursive-descent parser over the subset the
//! wire format uses: objects, arrays, strings (with escapes), numbers,
//! booleans and null. It is strict about structure (trailing garbage is
//! an error) and bounds recursion depth so a hostile client cannot
//! overflow the daemon's stack.

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any JSON number (kept as `f64`; the wire format's integral
    /// fields go through [`Json::as_u64`], which rejects fractions).
    Num(f64),
    /// A string with escapes decoded.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object, in source order (duplicate keys: first wins via
    /// [`Json::get`]).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Member `key` of an object (`None` for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(members) => members.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// The value as a float, if it is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The value as a nonnegative integer: a finite number with no
    /// fractional part in `u64` range (the wire format's ids, budgets
    /// and millisecond fields).
    pub fn as_u64(&self) -> Option<u64> {
        let x = self.as_f64()?;
        // f64 is exact only up to 2^53, which comfortably covers the
        // wire format's ids/budgets/milliseconds
        if x.is_finite() && x >= 0.0 && x.fract() == 0.0 && x <= 9.007_199_254_740_992e15 {
            Some(x as u64)
        } else {
            None
        }
    }

    /// The value as a string slice, if it is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The value as a bool, if it is one.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Escape `s` for embedding in a JSON string literal (emission side).
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Parse one JSON document from `input`. The whole input must be a
/// single value (plus surrounding whitespace) — exactly one NDJSON
/// line's worth.
pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser { b: input.as_bytes(), i: 0 };
    p.skip_ws();
    let v = p.value(0)?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(format!("trailing garbage at byte {}", p.i));
    }
    Ok(v)
}

/// Nesting depth cap: the wire format needs 2–3 levels; 64 keeps any
/// legitimate payload working while bounding stack use per connection.
const MAX_DEPTH: usize = 64;

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&c) = self.b.get(self.i) {
            if c == b' ' || c == b'\t' || c == b'\n' || c == b'\r' {
                self.i += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect_byte(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json, String> {
        if depth > MAX_DEPTH {
            return Err("nesting too deep".to_string());
        }
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(format!("unexpected '{}' at byte {}", c as char, self.i)),
            None => Err("unexpected end of input".to_string()),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self.peek().is_some_and(|c| c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| "non-utf8 number".to_string())?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number '{text}' at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect_byte(b'"')?;
        let mut out = String::new();
        loop {
            let Some(c) = self.peek() else {
                return Err("unterminated string".to_string());
            };
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(e) = self.peek() else {
                        return Err("unterminated escape".to_string());
                    };
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            // surrogate pair: \uD800-\uDBFF must be
                            // followed by a low surrogate escape
                            let cp = if (0xD800..0xDC00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.i += 1;
                                    self.expect_byte(b'u')?;
                                    let lo = self.hex4()?;
                                    0x10000 + ((cp - 0xD800) << 10) + (lo.wrapping_sub(0xDC00))
                                } else {
                                    0xFFFD
                                }
                            } else {
                                cp
                            };
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i - 1)),
                    }
                }
                c if c < 0x20 => return Err("control character in string".to_string()),
                c if c < 0x80 => out.push(c as char),
                _ => {
                    // multi-byte UTF-8: re-decode from the source slice
                    let rest = &self.b[self.i - 1..];
                    let s = std::str::from_utf8(rest)
                        .map_err(|_| "non-utf8 string".to_string())?;
                    let ch = s.chars().next().ok_or("empty utf8 slice")?;
                    out.push(ch);
                    self.i += ch.len_utf8() - 1;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, String> {
        if self.i + 4 > self.b.len() {
            return Err("truncated \\u escape".to_string());
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| "non-utf8 \\u escape".to_string())?;
        let v = u32::from_str_radix(s, 16).map_err(|_| "bad \\u escape".to_string())?;
        self.i += 4;
        Ok(v)
    }

    fn object(&mut self, depth: usize) -> Result<Json, String> {
        self.expect_byte(b'{')?;
        let mut members = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(members));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            let v = self.value(depth + 1)?;
            members.push((key, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(members));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self, depth: usize) -> Result<Json, String> {
        self.expect_byte(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_wire_shaped_objects() {
        let v = parse(
            r#"{"graph":"G1","budget_frac":0.9,"c":2,"deadline_ms":30000,"tags":["a","b"],"x":null,"y":true}"#,
        )
        .unwrap();
        assert_eq!(v.get("graph").and_then(Json::as_str), Some("G1"));
        assert_eq!(v.get("budget_frac").and_then(Json::as_f64), Some(0.9));
        assert_eq!(v.get("deadline_ms").and_then(Json::as_u64), Some(30_000));
        assert_eq!(v.get("y").and_then(Json::as_bool), Some(true));
        assert_eq!(v.get("x"), Some(&Json::Null));
        match v.get("tags") {
            Some(Json::Arr(items)) => assert_eq!(items.len(), 2),
            other => panic!("expected array, got {other:?}"),
        }
        assert!(v.get("missing").is_none());
    }

    #[test]
    fn rejects_malformed_input() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("{\"a\":}").is_err());
        assert!(parse("[1,2,]").is_err());
        assert!(parse("{}trailing").is_err());
        assert!(parse("\"unterminated").is_err());
        // integral accessor rejects fractions and negatives
        assert_eq!(parse("1.5").unwrap().as_u64(), None);
        assert_eq!(parse("-3").unwrap().as_u64(), None);
        assert_eq!(parse("42").unwrap().as_u64(), Some(42));
    }

    #[test]
    fn escape_roundtrips_through_parse() {
        let nasty = "a\"b\\c\nd\te\u{1}f — π";
        let doc = format!("{{\"s\":\"{}\"}}", escape(nasty));
        let v = parse(&doc).unwrap();
        assert_eq!(v.get("s").and_then(Json::as_str), Some(nasty));
    }

    #[test]
    fn depth_cap_rejects_hostile_nesting() {
        let deep = format!("{}1{}", "[".repeat(200), "]".repeat(200));
        assert!(parse(&deep).is_err());
        let ok = format!("{}1{}", "[".repeat(20), "]".repeat(20));
        assert!(parse(&ok).is_ok());
    }

    #[test]
    fn unicode_escapes_decode() {
        // raw multi-byte UTF-8 passes through
        let v = parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
        // \u escapes, including a surrogate pair
        let v = parse("\"\\u0041\\ud83d\\ude00\"").unwrap();
        assert_eq!(v.as_str(), Some("A😀"));
    }
}
