//! Solver-as-a-service: an admission-controlled request queue in front
//! of a fixed pool of interruptible solver sessions.
//!
//! The coordinator ([`crate::coordinator`]) answers one caller at a
//! time; a compiler fleet talks to a long-running daemon instead. This
//! module is that daemon's core, independent of any transport:
//!
//! * **Admission control** — [`SolverService::submit`] never blocks and
//!   never silently drops. A request that cannot be served within its
//!   deadline — queue at capacity, or the estimated wait (backlog ×
//!   recent solve time / workers) already exceeding the deadline — is
//!   answered immediately with a structured [`Terminal::Overloaded`].
//! * **Interruptible sessions** — every accepted job owns a shared
//!   [`Incumbent`]; typed [`ControlSignal`]s act on it directly:
//!   `Cancel` trips the cancellation flag, `Preempt` trips the
//!   preemption flag (the solve yields its best-so-far at the next
//!   cooperative poll — the propagation engine's in-fixpoint heartbeat
//!   tick), and `TightenBound` publishes an external bound the branch &
//!   bound prunes against mid-solve.
//! * **Streaming anytime results** — every improving incumbent is
//!   emitted as a [`ServeEvent::Incumbent`] over the caller's channel
//!   while the solve is still running, so a client can act on a good
//!   schedule before the proof lands.
//! * **Worker-death recovery** — a session that panics (or is killed by
//!   its per-session watchdog) takes its worker thread down; the pool
//!   respawns a replacement, the request is retried exactly once on a
//!   fresh worker (front of queue, deterministic jittered backoff), and
//!   the retried response carries the first attempt's failure in its
//!   [`Degradation`](crate::moccasin::Degradation) provenance.
//! * **Exactly one terminal** — whatever happens (solved, degraded,
//!   preempted, cancelled, shed, expired in queue, failed), each
//!   submitted job receives exactly one [`ServeEvent::Terminal`],
//!   arbitrated by a compare-and-swap on the job handle. No hangs, no
//!   drops, no duplicate terminals — regression-tested under fault
//!   injection by `rust/tests/resilience.rs`.
//!
//! Wire transport (NDJSON over a Unix socket) lives in [`wire`] and
//! [`server`]; the `bench serve-json` load generator drives either the
//! in-process service or a live socket.

pub mod json;
mod queue;
#[cfg(unix)]
pub mod server;
pub mod wire;
mod worker;

pub(crate) use queue::JobHandle;
use queue::QueuedJob;

use crate::coordinator::{CacheKey, SolveResponse, DEFAULT_CACHE_CAP};
use crate::cp::SearchStrategy;
use crate::graph::Graph;
use crate::presolve::PresolveConfig;
use crate::util::{Incumbent, LruCache};
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Identifies one submitted request for control signals and events.
pub type JobId = u64;

/// Service configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Worker sessions solving concurrently. `0` = auto: available
    /// parallelism capped at 4.
    pub workers: usize,
    /// Queued (not yet dispatched) request cap; a submit beyond it is
    /// shed with [`Terminal::Overloaded`].
    pub queue_cap: usize,
    /// Schedule-cache capacity shared across all requests (entries;
    /// `0` disables caching). Only clean, completed solves are cached —
    /// never preempted, killed, retried or panicked ones.
    pub cache_cap: usize,
    /// Deadline applied by the wire layer when a request carries none.
    pub default_deadline: Duration,
    /// Per-session watchdog heartbeat-stall override in milliseconds
    /// (`None` = derived from the request deadline).
    pub stall_ms: Option<u64>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: 0,
            queue_cap: 64,
            cache_cap: DEFAULT_CACHE_CAP,
            default_deadline: Duration::from_secs(30),
            stall_ms: None,
        }
    }
}

impl ServeConfig {
    /// Resolve `workers == 0` to the machine's parallelism, capped at 4.
    pub fn effective_workers(&self) -> usize {
        if self.workers != 0 {
            return self.workers;
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2).clamp(1, 4)
    }
}

/// One solve request as the service sees it (the wire layer resolves
/// graph specs and budget fractions into this).
#[derive(Clone)]
pub struct ServeRequest {
    /// The compute graph (shared — the service never copies it).
    pub graph: Arc<Graph>,
    /// Memory budget `M`.
    pub budget: u64,
    /// Max retention intervals per node (the paper's `C`).
    pub c: usize,
    /// End-to-end latency budget: queue wait plus solve. A request
    /// whose deadline passes while still queued is answered with
    /// [`Terminal::Expired`] without ever being dispatched.
    pub deadline: Duration,
    /// CP kernel search strategy.
    pub search: SearchStrategy,
    /// Root presolve configuration.
    pub presolve: PresolveConfig,
}

impl ServeRequest {
    /// A request with the library defaults (`C = 2`, 30 s deadline,
    /// default search/presolve).
    pub fn new(graph: Arc<Graph>, budget: u64) -> Self {
        ServeRequest {
            graph,
            budget,
            c: 2,
            deadline: Duration::from_secs(30),
            search: SearchStrategy::default(),
            presolve: PresolveConfig::default(),
        }
    }
}

/// Typed control signals acting on an in-flight (or queued) job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ControlSignal {
    /// Stop at the next cooperative poll and return the best incumbent
    /// found so far ([`Terminal::Preempted`]). A still-queued job is
    /// answered immediately (with nothing computed).
    Preempt,
    /// Publish an external upper bound on the objective; the session's
    /// branch & bound prunes against it from the next poll on. Does not
    /// stop the solve.
    TightenBound(u64),
    /// Abandon the job: the result is no longer wanted
    /// ([`Terminal::Cancelled`]).
    Cancel,
}

/// Events streamed to the submitter over its channel. Every job
/// receives exactly one [`ServeEvent::Terminal`]; all other events are
/// progress.
#[derive(Debug, Clone)]
pub enum ServeEvent {
    /// The job passed admission and is waiting for a worker.
    Queued {
        /// The job.
        job: JobId,
        /// Number of requests ahead of it in the queue at admission.
        position: usize,
    },
    /// A worker session started solving (attempt 0, or 1 for the single
    /// post-death retry).
    Started {
        /// The job.
        job: JobId,
        /// 0 = first attempt, 1 = retry after a worker death.
        attempt: u32,
    },
    /// An improving incumbent, streamed while the solve is running.
    Incumbent {
        /// The job.
        job: JobId,
        /// Total schedule duration of the new best.
        duration: u64,
        /// Its peak memory footprint.
        peak_mem: u64,
        /// Its rematerialization count.
        remats: usize,
        /// Wall-clock since the session started.
        elapsed: Duration,
    },
    /// The worker session died (panic — injected or real). If
    /// `will_retry`, the job goes back to the front of the queue for
    /// one retry on a fresh worker; otherwise a terminal follows.
    Died {
        /// The job.
        job: JobId,
        /// The attempt that died.
        attempt: u32,
        /// The panic note.
        note: String,
        /// Whether the single retry is still available (and the job's
        /// deadline has not passed).
        will_retry: bool,
    },
    /// The job's single terminal outcome.
    Terminal {
        /// The job.
        job: JobId,
        /// What happened.
        outcome: Terminal,
    },
}

/// The one terminal outcome every submitted job receives.
#[derive(Debug, Clone)]
pub enum Terminal {
    /// The solve completed (possibly degraded — see
    /// `response.degradation` — and possibly with no feasible
    /// schedule, in which case `solution` is `None`).
    Solved(Box<SolveResponse>),
    /// A [`ControlSignal::Preempt`] stopped the solve; the response
    /// carries the best-so-far (which may be nothing for a job
    /// preempted while still queued).
    Preempted(Box<SolveResponse>),
    /// A [`ControlSignal::Cancel`] abandoned the job.
    Cancelled,
    /// Admission control shed the request — the structured "try later /
    /// elsewhere" answer, never a silent drop.
    Overloaded {
        /// Queue length observed at admission.
        queue_len: usize,
        /// Estimated wait at admission, in milliseconds.
        est_wait_ms: u64,
        /// Which admission rule shed it.
        reason: String,
    },
    /// The deadline passed while the request was still queued; it was
    /// never dispatched.
    Expired {
        /// How long it had been queued, in milliseconds.
        waited_ms: u64,
    },
    /// The solve failed structurally (both attempts panicked, or the
    /// service shut down with the job still queued).
    Failed {
        /// Diagnostic.
        error: String,
    },
}

impl Terminal {
    /// Stable lower-case class name (wire format / bench JSON).
    pub fn name(&self) -> &'static str {
        match self {
            Terminal::Solved(_) => "solved",
            Terminal::Preempted(_) => "preempted",
            Terminal::Cancelled => "cancelled",
            Terminal::Overloaded { .. } => "overloaded",
            Terminal::Expired { .. } => "expired",
            Terminal::Failed { .. } => "failed",
        }
    }
}

/// Monotone service counters (atomics — read with
/// [`ServiceStats::snapshot`]).
#[derive(Debug, Default)]
pub struct ServiceStats {
    submitted: AtomicU64,
    admitted: AtomicU64,
    solved: AtomicU64,
    preempted: AtomicU64,
    cancelled: AtomicU64,
    shed: AtomicU64,
    expired: AtomicU64,
    failed: AtomicU64,
    retries: AtomicU64,
    worker_deaths: AtomicU64,
    cache_hits: AtomicU64,
    cache_misses: AtomicU64,
}

/// A point-in-time reading of the service counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServiceStatsSnapshot {
    /// Requests submitted (all of them — every one gets a terminal).
    pub submitted: u64,
    /// Requests that passed admission into the queue.
    pub admitted: u64,
    /// [`Terminal::Solved`] outcomes delivered.
    pub solved: u64,
    /// [`Terminal::Preempted`] outcomes delivered.
    pub preempted: u64,
    /// [`Terminal::Cancelled`] outcomes delivered.
    pub cancelled: u64,
    /// [`Terminal::Overloaded`] outcomes delivered (admission sheds).
    pub shed: u64,
    /// [`Terminal::Expired`] outcomes delivered (died in queue).
    pub expired: u64,
    /// [`Terminal::Failed`] outcomes delivered.
    pub failed: u64,
    /// Post-death retries dispatched (at most one per job).
    pub retries: u64,
    /// Worker threads lost to a panicking session (each respawned).
    pub worker_deaths: u64,
    /// Requests answered from the shared schedule cache.
    pub cache_hits: u64,
    /// Requests that had to solve.
    pub cache_misses: u64,
}

impl ServiceStats {
    fn bump(field: &AtomicU64) {
        field.fetch_add(1, Ordering::Relaxed);
    }

    /// Read all counters.
    pub fn snapshot(&self) -> ServiceStatsSnapshot {
        ServiceStatsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            admitted: self.admitted.load(Ordering::Relaxed),
            solved: self.solved.load(Ordering::Relaxed),
            preempted: self.preempted.load(Ordering::Relaxed),
            cancelled: self.cancelled.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            expired: self.expired.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
            worker_deaths: self.worker_deaths.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.cache_misses.load(Ordering::Relaxed),
        }
    }
}

/// Shared state behind the service facade (workers, the sweeper and the
/// facade all hold an `Arc` of this).
pub(crate) struct ServiceInner {
    pub(crate) cfg: ServeConfig,
    /// Admitted, not-yet-dispatched jobs. Lock order: `queue` before
    /// `jobs` (never the reverse).
    pub(crate) queue: Mutex<VecDeque<QueuedJob>>,
    /// Signalled on enqueue / control / shutdown.
    pub(crate) available: Condvar,
    /// Every live (un-terminated) job, for control-signal routing.
    pub(crate) jobs: Mutex<HashMap<JobId, Arc<JobHandle>>>,
    pub(crate) next_id: AtomicU64,
    /// Sessions currently solving (for the admission wait estimate).
    pub(crate) in_flight: AtomicUsize,
    pub(crate) shutdown: AtomicBool,
    /// Bounded schedule cache shared across requests (keyed exactly
    /// like the coordinator's, with the Moccasin backend).
    pub(crate) cache: Mutex<LruCache<CacheKey, SolveResponse>>,
    /// Exponential moving average of recent session wall-clock, in ms
    /// (0 = no completed solve yet; admission then relies on the queue
    /// cap alone).
    pub(crate) ema_solve_ms: AtomicU64,
    pub(crate) stats: ServiceStats,
    /// Worker (and sweeper) join handles; dying workers push their
    /// replacement's handle here before exiting.
    pub(crate) worker_handles: Mutex<Vec<JoinHandle<()>>>,
}

// Every service lock goes through the shared recovering helper: the
// guarded structures here (a `VecDeque`, a `HashMap`, an `LruCache`)
// are only ever mutated in single statements, so poisoning carries no
// broken invariant — and the service must keep draining its queue even
// after a worker panic.
pub(crate) use crate::util::lock_recover;

impl ServiceInner {
    /// Deliver `outcome` as the job's terminal iff no other path beat
    /// us to it, bump the matching counter, and unregister the job.
    pub(crate) fn finish(&self, handle: &JobHandle, outcome: Terminal) -> bool {
        let class = match &outcome {
            Terminal::Solved(_) => &self.stats.solved,
            Terminal::Preempted(_) => &self.stats.preempted,
            Terminal::Cancelled => &self.stats.cancelled,
            Terminal::Overloaded { .. } => &self.stats.shed,
            Terminal::Expired { .. } => &self.stats.expired,
            Terminal::Failed { .. } => &self.stats.failed,
        };
        if handle.finish(outcome) {
            ServiceStats::bump(class);
            lock_recover(&self.jobs).remove(&handle.id);
            true
        } else {
            false
        }
    }

    /// Fold one completed session's wall-clock into the admission EMA
    /// (`ema := (3·ema + sample) / 4`, seeded by the first sample).
    pub(crate) fn update_ema(&self, sample_ms: u64) {
        let sample = sample_ms.max(1);
        let _ = self.ema_solve_ms.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |old| {
            Some(if old == 0 { sample } else { (3 * old + sample) / 4 })
        });
    }
}

/// Which admission rule rejects a request, if any. Pure function of the
/// observed service state so the policy is unit-testable.
pub(crate) fn admission_verdict(
    queue_len: usize,
    in_flight: usize,
    workers: usize,
    ema_solve_ms: u64,
    deadline_ms: u64,
    queue_cap: usize,
) -> Result<(), (u64, String)> {
    if queue_len >= queue_cap {
        let est = (queue_len + in_flight) as u64 * ema_solve_ms / workers.max(1) as u64;
        return Err((est, format!("queue full ({queue_len}/{queue_cap})")));
    }
    // backlog ahead of this request, spread across the pool, paced by
    // the recent per-solve wall clock; no completed solve yet (ema 0)
    // means no estimate — admit and let the queue cap govern
    let est = (queue_len + in_flight) as u64 * ema_solve_ms / workers.max(1) as u64;
    if ema_solve_ms > 0 && est > deadline_ms {
        return Err((
            est,
            format!("estimated wait {est}ms exceeds deadline {deadline_ms}ms"),
        ));
    }
    Ok(())
}

/// The solver service: a fixed pool of interruptible worker sessions
/// behind an admission-controlled queue. See the module docs for the
/// full contract.
pub struct SolverService {
    inner: Arc<ServiceInner>,
    joined: AtomicBool,
}

impl SolverService {
    /// Start the service: spawns the worker pool and the queue sweeper.
    pub fn start(cfg: ServeConfig) -> Self {
        let workers = cfg.effective_workers();
        let cache_cap = cfg.cache_cap;
        let inner = Arc::new(ServiceInner {
            cfg,
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            jobs: Mutex::new(HashMap::new()),
            next_id: AtomicU64::new(0),
            in_flight: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
            cache: Mutex::new(LruCache::new(cache_cap)),
            ema_solve_ms: AtomicU64::new(0),
            stats: ServiceStats::default(),
            worker_handles: Mutex::new(Vec::new()),
        });
        let mut spawned = 0usize;
        for idx in 0..workers {
            if worker::spawn_worker(&inner, idx) {
                spawned += 1;
            }
        }
        if spawned == 0 {
            // No worker could start: flip shutdown so every submit is
            // answered with a structured Overloaded terminal instead of
            // queueing jobs nothing will ever drain.
            eprintln!("serve: no worker threads available; service starts shut down");
            inner.shutdown.store(true, Ordering::Release);
        }
        queue::spawn_sweeper(&inner);
        SolverService { inner, joined: AtomicBool::new(false) }
    }

    /// Submit a request. Never blocks on solving; every outcome —
    /// including an admission shed — arrives on `events` as exactly one
    /// [`ServeEvent::Terminal`]. The returned [`JobId`] addresses
    /// [`SolverService::control`].
    pub fn submit(&self, req: ServeRequest, events: mpsc::Sender<ServeEvent>) -> JobId {
        let inner = &self.inner;
        let id = inner.next_id.fetch_add(1, Ordering::Relaxed) + 1;
        let handle = JobHandle::new(id, events);
        ServiceStats::bump(&inner.stats.submitted);
        lock_recover(&inner.jobs).insert(id, Arc::clone(&handle));
        if inner.shutdown.load(Ordering::Acquire) {
            inner.finish(
                &handle,
                Terminal::Overloaded {
                    queue_len: 0,
                    est_wait_ms: 0,
                    reason: "service shutting down".to_string(),
                },
            );
            return id;
        }
        let deadline_ms = req.deadline.as_millis() as u64;
        let mut q = lock_recover(&inner.queue);
        // re-check under the queue lock: shutdown drains the queue while
        // holding it, and a job enqueued after that drain would never be
        // dispatched (and so never answered)
        if inner.shutdown.load(Ordering::Acquire) {
            drop(q);
            inner.finish(
                &handle,
                Terminal::Overloaded {
                    queue_len: 0,
                    est_wait_ms: 0,
                    reason: "service shutting down".to_string(),
                },
            );
            return id;
        }
        let verdict = admission_verdict(
            q.len(),
            inner.in_flight.load(Ordering::Relaxed),
            inner.cfg.effective_workers(),
            inner.ema_solve_ms.load(Ordering::Relaxed),
            deadline_ms,
            inner.cfg.queue_cap,
        );
        match verdict {
            Err((est_wait_ms, reason)) => {
                let queue_len = q.len();
                drop(q);
                inner.finish(
                    &handle,
                    Terminal::Overloaded { queue_len, est_wait_ms, reason },
                );
            }
            Ok(()) => {
                let position = q.len();
                q.push_back(QueuedJob {
                    handle: Arc::clone(&handle),
                    req,
                    attempt: 0,
                    enqueued: Instant::now(),
                    prior_failure: None,
                });
                drop(q);
                ServiceStats::bump(&inner.stats.admitted);
                handle.emit(ServeEvent::Queued { job: id, position });
                inner.available.notify_one();
            }
        }
        id
    }

    /// Send a control signal to a job. Returns `false` if the job is
    /// unknown or already terminated (signals are then no-ops — the
    /// terminal has been delivered).
    pub fn control(&self, job: JobId, signal: ControlSignal) -> bool {
        let handle = lock_recover(&self.inner.jobs).get(&job).cloned();
        let Some(handle) = handle else {
            return false;
        };
        match signal {
            ControlSignal::Preempt => handle.incumbent.preempt(),
            ControlSignal::TightenBound(bound) => {
                handle.incumbent.record(bound);
            }
            ControlSignal::Cancel => {
                handle.client_cancel.store(true, Ordering::Release);
                handle.incumbent.cancel();
            }
        }
        // wake idle workers / the sweeper so queued jobs resolve their
        // cancel or preempt promptly
        self.inner.available.notify_all();
        true
    }

    /// Read the service counters.
    pub fn stats(&self) -> ServiceStatsSnapshot {
        self.inner.stats.snapshot()
    }

    /// Queued (admitted, not yet dispatched) request count.
    pub fn queue_len(&self) -> usize {
        lock_recover(&self.inner.queue).len()
    }

    /// Schedule-cache observability: (hits, misses, evictions, len) of
    /// the shared cache — lookup counters, not the request-level
    /// `cache_hits` in [`ServiceStats`].
    pub fn cache_counters(&self) -> (u64, u64, u64, usize) {
        let c = lock_recover(&self.inner.cache);
        (c.hits, c.misses, c.evictions, c.len())
    }

    /// Stop the service: reject new submits, preempt in-flight
    /// sessions (they terminate with their best-so-far), fail still
    /// queued jobs structurally, and join every worker. Idempotent.
    pub fn shutdown(&self) {
        if self.joined.swap(true, Ordering::AcqRel) {
            return;
        }
        let inner = &self.inner;
        inner.shutdown.store(true, Ordering::Release);
        // fail everything still queued (each gets its one terminal)
        let drained: Vec<QueuedJob> = lock_recover(&inner.queue).drain(..).collect();
        for job in drained {
            inner.finish(
                &job.handle,
                Terminal::Failed { error: "service shut down before dispatch".to_string() },
            );
        }
        // ask in-flight sessions to yield their best-so-far
        for handle in lock_recover(&inner.jobs).values() {
            handle.incumbent.preempt();
        }
        inner.available.notify_all();
        // dying workers may push replacement handles while we join, so
        // drain until the vector stays empty
        loop {
            let h = lock_recover(&inner.worker_handles).pop();
            match h {
                Some(h) => {
                    let _ = h.join();
                }
                None => break,
            }
        }
    }
}

impl Drop for SolverService {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::failpoint::{self, FailAction};

    /// The serve failpoint sites are process-global; tests that arm
    /// them (or depend on them *not* being armed) serialize here
    /// (`pub(crate)` so the socket test in [`server`] joins the queue).
    static GATE: Mutex<()> = Mutex::new(());

    pub(crate) fn serial() -> std::sync::MutexGuard<'static, ()> {
        GATE.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Chain + long skip with heavy source: optimum duration 6 at
    /// budget 10 (one remat of node 0), solved in milliseconds.
    fn chain() -> Arc<Graph> {
        Arc::new(
            Graph::from_edges(
                "c",
                5,
                &[(0, 1), (1, 2), (2, 3), (3, 4), (0, 4)],
                vec![1; 5],
                vec![5, 4, 4, 4, 1],
            )
            .unwrap(),
        )
    }

    fn drain_until_terminal(rx: &mpsc::Receiver<ServeEvent>) -> (Vec<ServeEvent>, Terminal) {
        let mut progress = Vec::new();
        loop {
            let ev = rx
                .recv_timeout(Duration::from_secs(30))
                .expect("terminal must arrive (no hangs)");
            match ev {
                ServeEvent::Terminal { outcome, .. } => return (progress, outcome),
                other => progress.push(other),
            }
        }
    }

    #[test]
    fn submit_solves_streams_and_caches() {
        let _g = serial();
        failpoint::reset();
        let svc = SolverService::start(ServeConfig {
            workers: 1,
            ..Default::default()
        });
        let (tx, rx) = mpsc::channel();
        let req = ServeRequest {
            deadline: Duration::from_secs(20),
            ..ServeRequest::new(chain(), 10)
        };
        let id = svc.submit(req.clone(), tx);
        let (progress, outcome) = drain_until_terminal(&rx);
        assert!(progress
            .iter()
            .any(|e| matches!(e, ServeEvent::Queued { job, .. } if *job == id)));
        assert!(progress
            .iter()
            .any(|e| matches!(e, ServeEvent::Started { attempt: 0, .. })));
        assert!(
            progress.iter().any(|e| matches!(e, ServeEvent::Incumbent { .. })),
            "anytime incumbents must stream"
        );
        let resp = match outcome {
            Terminal::Solved(resp) => resp,
            other => panic!("expected solved, got {}", other.name()),
        };
        assert_eq!(resp.solution.as_ref().unwrap().eval.duration, 6);
        assert!(resp.proved_optimal);
        assert!(!resp.from_cache);
        // second submit: same key, served from the shared cache
        let (tx2, rx2) = mpsc::channel();
        svc.submit(req, tx2);
        let (_, outcome2) = drain_until_terminal(&rx2);
        let Terminal::Solved(resp2) = outcome2 else {
            panic!("expected cached solved");
        };
        assert!(resp2.from_cache);
        let s = svc.stats();
        assert_eq!(s.submitted, 2);
        assert_eq!(s.solved, 2);
        assert_eq!(s.cache_hits, 1);
        assert_eq!(s.cache_misses, 1);
        svc.shutdown();
        // exactly one terminal each: channels are drained and closed
        assert!(rx.try_recv().is_err());
        assert!(rx2.try_recv().is_err());
    }

    #[test]
    fn queue_full_sheds_with_structured_overload() {
        let _g = serial();
        failpoint::reset();
        // slow the (single) worker down deterministically so the queue
        // backs up: the session sleeps 300 ms before solving
        failpoint::arm("serve.session", FailAction::Delay(300), Some(1));
        let svc = SolverService::start(ServeConfig {
            workers: 1,
            queue_cap: 1,
            ..Default::default()
        });
        let (tx_a, rx_a) = mpsc::channel();
        let mk = || ServeRequest {
            deadline: Duration::from_secs(20),
            ..ServeRequest::new(chain(), 10)
        };
        svc.submit(mk(), tx_a);
        // let the worker take A into its delayed session
        std::thread::sleep(Duration::from_millis(100));
        let (tx_b, rx_b) = mpsc::channel();
        svc.submit(mk(), tx_b); // queued (1/1)
        let (tx_c, rx_c) = mpsc::channel();
        svc.submit(mk(), tx_c); // queue full -> shed
        let (_, outcome_c) = drain_until_terminal(&rx_c);
        match outcome_c {
            Terminal::Overloaded { queue_len, reason, .. } => {
                assert_eq!(queue_len, 1);
                assert!(reason.contains("queue full"), "reason: {reason}");
            }
            other => panic!("expected overloaded, got {}", other.name()),
        }
        // the shed request never blocks the admitted ones
        let (_, oa) = drain_until_terminal(&rx_a);
        let (_, ob) = drain_until_terminal(&rx_b);
        assert!(matches!(oa, Terminal::Solved(_)));
        assert!(matches!(ob, Terminal::Solved(_)));
        assert_eq!(svc.stats().shed, 1);
        svc.shutdown();
    }

    #[test]
    fn deadline_expires_in_queue_without_dispatch() {
        let _g = serial();
        failpoint::reset();
        // occupy the single worker long enough for B's deadline to pass
        failpoint::arm("serve.session", FailAction::Delay(400), Some(1));
        let svc = SolverService::start(ServeConfig {
            workers: 1,
            ..Default::default()
        });
        let (tx_a, rx_a) = mpsc::channel();
        svc.submit(
            ServeRequest { deadline: Duration::from_secs(20), ..ServeRequest::new(chain(), 10) },
            tx_a,
        );
        std::thread::sleep(Duration::from_millis(100));
        let (tx_b, rx_b) = mpsc::channel();
        let t0 = Instant::now();
        svc.submit(
            ServeRequest {
                deadline: Duration::from_millis(50),
                ..ServeRequest::new(chain(), 10)
            },
            tx_b,
        );
        let (progress_b, outcome_b) = drain_until_terminal(&rx_b);
        // the sweeper answers the expired request while the worker is
        // still busy — long before A's 400 ms session ends
        assert!(
            t0.elapsed() < Duration::from_millis(300),
            "expiry must not wait for the busy worker"
        );
        match outcome_b {
            Terminal::Expired { waited_ms } => assert!(waited_ms >= 50),
            other => panic!("expected expired, got {}", other.name()),
        }
        assert!(
            !progress_b.iter().any(|e| matches!(e, ServeEvent::Started { .. })),
            "an expired request must never be dispatched"
        );
        let (_, oa) = drain_until_terminal(&rx_a);
        assert!(matches!(oa, Terminal::Solved(_)));
        assert_eq!(svc.stats().expired, 1);
        svc.shutdown();
    }

    #[test]
    fn preempt_yields_best_so_far_and_cancel_is_distinct() {
        let _g = serial();
        failpoint::reset();
        failpoint::arm("serve.session", FailAction::Delay(250), Some(2));
        let svc = SolverService::start(ServeConfig {
            workers: 2,
            ..Default::default()
        });
        let mk = || ServeRequest {
            deadline: Duration::from_secs(20),
            ..ServeRequest::new(chain(), 10)
        };
        // A: preempted mid-session (during the injected delay)
        let (tx_a, rx_a) = mpsc::channel();
        let a = svc.submit(mk(), tx_a);
        // B: cancelled mid-session
        let (tx_b, rx_b) = mpsc::channel();
        let b = svc.submit(mk(), tx_b);
        std::thread::sleep(Duration::from_millis(100));
        assert!(svc.control(a, ControlSignal::Preempt));
        assert!(svc.control(b, ControlSignal::Cancel));
        let (_, oa) = drain_until_terminal(&rx_a);
        let (_, ob) = drain_until_terminal(&rx_b);
        assert!(
            matches!(oa, Terminal::Preempted(_)),
            "preempt must label the outcome preempted, got {}",
            oa.name()
        );
        assert!(
            matches!(ob, Terminal::Cancelled),
            "cancel must label the outcome cancelled, got {}",
            ob.name()
        );
        // signals to finished or unknown jobs are rejected
        assert!(!svc.control(a, ControlSignal::Preempt));
        assert!(!svc.control(9999, ControlSignal::Cancel));
        // preempted/cancelled responses are never cached: a re-submit
        // of the same request solves cleanly
        let (tx_c, rx_c) = mpsc::channel();
        svc.submit(mk(), tx_c);
        let (_, oc) = drain_until_terminal(&rx_c);
        let Terminal::Solved(resp) = oc else { panic!("expected solved") };
        assert!(!resp.from_cache);
        svc.shutdown();
    }

    #[test]
    fn tighten_bound_reaches_a_live_job() {
        let _g = serial();
        failpoint::reset();
        failpoint::arm("serve.session", FailAction::Delay(150), Some(1));
        let svc = SolverService::start(ServeConfig { workers: 1, ..Default::default() });
        let (tx, rx) = mpsc::channel();
        let id = svc.submit(
            ServeRequest { deadline: Duration::from_secs(20), ..ServeRequest::new(chain(), 10) },
            tx,
        );
        std::thread::sleep(Duration::from_millis(50));
        // an external bound at the known optimum: the session prunes
        // against it and still terminates cleanly
        assert!(svc.control(id, ControlSignal::TightenBound(6)));
        let (_, outcome) = drain_until_terminal(&rx);
        assert!(matches!(outcome, Terminal::Solved(_)), "got {}", outcome.name());
        svc.shutdown();
    }

    #[test]
    fn admission_policy_is_exact() {
        // pure-function checks of the two shed rules
        assert!(admission_verdict(0, 0, 2, 0, 1000, 8).is_ok());
        // queue at cap
        let err = admission_verdict(8, 2, 2, 100, 10_000, 8).unwrap_err();
        assert!(err.1.contains("queue full"));
        // estimated wait beyond deadline: (4+2)/2 * 400ms = 1200ms > 1s
        let err = admission_verdict(4, 2, 2, 400, 1000, 8).unwrap_err();
        assert_eq!(err.0, 1200);
        assert!(err.1.contains("exceeds deadline"));
        // same backlog, roomier deadline: admitted
        assert!(admission_verdict(4, 2, 2, 400, 2000, 8).is_ok());
        // no solve-time estimate yet: only the cap governs
        assert!(admission_verdict(7, 7, 1, 0, 1, 8).is_ok());
    }

    #[test]
    fn shutdown_fails_queued_jobs_structurally() {
        let _g = serial();
        failpoint::reset();
        failpoint::arm("serve.session", FailAction::Delay(300), Some(1));
        let svc = SolverService::start(ServeConfig { workers: 1, ..Default::default() });
        let mk = || ServeRequest {
            deadline: Duration::from_secs(20),
            ..ServeRequest::new(chain(), 10)
        };
        let (tx_a, rx_a) = mpsc::channel();
        svc.submit(mk(), tx_a);
        std::thread::sleep(Duration::from_millis(80));
        let (tx_b, rx_b) = mpsc::channel();
        svc.submit(mk(), tx_b);
        svc.shutdown();
        // in-flight A is preempted to its best-so-far; queued B fails
        // structurally; post-shutdown submits shed — all terminal, none
        // hang
        let (_, oa) = drain_until_terminal(&rx_a);
        assert!(
            matches!(oa, Terminal::Preempted(_) | Terminal::Solved(_)),
            "got {}",
            oa.name()
        );
        let (_, ob) = drain_until_terminal(&rx_b);
        assert!(matches!(ob, Terminal::Failed { .. }), "got {}", ob.name());
        let (tx_c, rx_c) = mpsc::channel();
        svc.submit(mk(), tx_c);
        let (_, oc) = drain_until_terminal(&rx_c);
        match oc {
            Terminal::Overloaded { reason, .. } => {
                assert!(reason.contains("shutting down"))
            }
            other => panic!("expected overloaded, got {}", other.name()),
        }
    }
}
