//! Sparse lazy segment tree over the time axis — the O(log H)
//! timetable profile backing the incremental `Cumulative` propagator.
//!
//! The linear profile (a flattened `(time, load)` step vector rebuilt
//! from a diff map whenever any compulsory part moves) costs O(K) per
//! profile change, where K is the number of breakpoints — which grows
//! with the instance, so on paper-scale-and-beyond graphs (n ≥ 1000,
//! see `generators::LARGE_GRAPHS`) every cumulative propagation pays a
//! scan proportional to the horizon. This tree replaces that with:
//!
//! * `range_add(l, r, d)` — register/unregister one compulsory part in
//!   O(log H): nodes are allocated on demand along the two boundary
//!   paths, so memory is proportional to the *touched* coordinates
//!   (domain values that actually become part boundaries), never to
//!   the horizon.
//! * `max()` — the overload check, O(1) off the root.
//! * `load_at(t)` — the timetable filter's point query, O(log H).
//! * `first_over(l, r, cap)` — earliest `t ∈ [l, r]` with
//!   `load(t) > cap`, O(log H); replaces the linear breakpoint scan of
//!   the fixed-placement overload check and doubles as the
//!   peak-witness lookup for conflict explanations.
//!
//! Lazy convention (no push-down): `Node::add` is an addition applying
//! to the node's whole range, already included in `Node::max` but not
//! yet propagated to children; an absent child stands for a subtree
//! whose values all equal the sum of `add` along the path above it.
//! Loads are step functions changing only at update boundaries, so
//! every answer this tree gives is *value-identical* to the linear
//! profile's — which is what lets the chronological search walk the
//! exact same tree under either structure (asserted by
//! `prop_segtree_profile_matches_linear`).

/// Child sentinel: subtree untouched (uniform zero relative to the
/// adds accumulated above it).
const NIL: u32 = u32::MAX;

#[derive(Debug, Clone, Copy)]
struct Node {
    left: u32,
    right: u32,
    /// Pending addition over the node's whole range (included in
    /// `max`, not yet pushed to children).
    add: i64,
    /// Maximum true value over the node's range, relative to the adds
    /// accumulated *above* this node.
    max: i64,
}

/// Sparse lazy range-add / max-query segment tree over `[lo, hi)`.
#[derive(Debug)]
pub(crate) struct SegTreeProfile {
    lo: i64,
    hi: i64,
    nodes: Vec<Node>,
}

impl SegTreeProfile {
    /// Empty profile over the half-open coordinate range `[lo, hi)`
    /// (degenerate ranges are widened to one point).
    pub fn new(lo: i64, hi: i64) -> Self {
        let mut t = SegTreeProfile { lo: 0, hi: 1, nodes: Vec::with_capacity(1) };
        t.reset(lo, hi);
        t
    }

    /// Empty the tree and re-cover `[lo, hi)` in place, keeping the
    /// node arena's capacity (solve-context reuse: a pooled profile is
    /// reset once per engine construction instead of reallocated).
    pub fn reset(&mut self, lo: i64, hi: i64) {
        self.lo = lo;
        self.hi = hi.max(lo + 1);
        self.nodes.clear();
        self.nodes.push(Node { left: NIL, right: NIL, add: 0, max: 0 });
    }

    /// Maximum load over the whole axis (0 when nothing is registered).
    #[inline]
    pub fn max(&self) -> i64 {
        self.nodes[0].max
    }

    fn child(&mut self, u: usize, right: bool) -> usize {
        let c = if right { self.nodes[u].right } else { self.nodes[u].left };
        if c != NIL {
            return c as usize;
        }
        let id = self.nodes.len() as u32;
        self.nodes.push(Node { left: NIL, right: NIL, add: 0, max: 0 });
        if right {
            self.nodes[u].right = id;
        } else {
            self.nodes[u].left = id;
        }
        id as usize
    }

    /// Add `d` on `[l, r)` (clamped to the tree's range).
    pub fn range_add(&mut self, l: i64, r: i64, d: i64) {
        let (l, r) = (l.max(self.lo), r.min(self.hi));
        if l >= r || d == 0 {
            return;
        }
        self.add_rec(0, self.lo, self.hi, l, r, d);
    }

    fn add_rec(&mut self, u: usize, a: i64, b: i64, l: i64, r: i64, d: i64) {
        if l <= a && b <= r {
            self.nodes[u].add += d;
            self.nodes[u].max += d;
            return;
        }
        let m = a + (b - a) / 2;
        if l < m {
            let c = self.child(u, false);
            self.add_rec(c, a, m, l, r.min(m), d);
        }
        if r > m {
            let c = self.child(u, true);
            self.add_rec(c, m, b, l.max(m), r, d);
        }
        // recompute: an absent child is a uniform-zero subtree
        let n = self.nodes[u];
        let lm = if n.left != NIL { self.nodes[n.left as usize].max } else { 0 };
        let rm = if n.right != NIL { self.nodes[n.right as usize].max } else { 0 };
        self.nodes[u].max = n.add + lm.max(rm);
    }

    /// Load at point `t` (0 outside the tree's range).
    pub fn load_at(&self, t: i64) -> i64 {
        if t < self.lo || t >= self.hi {
            return 0;
        }
        let (mut u, mut a, mut b) = (0usize, self.lo, self.hi);
        let mut acc = 0i64;
        loop {
            acc += self.nodes[u].add;
            if b - a == 1 {
                return acc;
            }
            let m = a + (b - a) / 2;
            let c = if t < m { self.nodes[u].left } else { self.nodes[u].right };
            if c == NIL {
                return acc;
            }
            if t < m {
                b = m;
            } else {
                a = m;
            }
            u = c as usize;
        }
    }

    /// Earliest `t ∈ [l, r]` (inclusive) with `load(t) > cap`, if any.
    pub fn first_over(&self, l: i64, r: i64, cap: i64) -> Option<i64> {
        let (l, r) = (l.max(self.lo), (r + 1).min(self.hi));
        if l >= r {
            return None;
        }
        self.fo_rec(Some(0), self.lo, self.hi, l, r, cap, 0)
    }

    /// A point achieving the maximum load (the overload witness for
    /// conflict explanations). Returns the leftmost such point — the
    /// same breakpoint the linear profile's max scan reports.
    pub fn peak_time(&self) -> i64 {
        self.first_over(self.lo, self.hi - 1, self.max() - 1).unwrap_or(self.lo)
    }

    #[allow(clippy::too_many_arguments)]
    fn fo_rec(
        &self,
        u: Option<usize>,
        a: i64,
        b: i64,
        l: i64,
        r: i64,
        cap: i64,
        acc: i64,
    ) -> Option<i64> {
        // invariant: [a, b) ∩ [l, r) is nonempty
        let Some(u) = u else {
            // untouched subtree: every point carries exactly `acc`
            return if acc > cap { Some(a.max(l)) } else { None };
        };
        let n = &self.nodes[u];
        if acc + n.max <= cap {
            return None; // no point in this subtree exceeds the cap
        }
        let acc = acc + n.add;
        if b - a == 1 {
            return if acc > cap { Some(a) } else { None };
        }
        let m = a + (b - a) / 2;
        if l < m {
            let c = if n.left == NIL { None } else { Some(n.left as usize) };
            if let Some(t) = self.fo_rec(c, a, m, l, r.min(m), cap, acc) {
                return Some(t);
            }
        }
        if r > m {
            let c = if n.right == NIL { None } else { Some(n.right as usize) };
            if let Some(t) = self.fo_rec(c, m, b, l.max(m), r, cap, acc) {
                return Some(t);
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Dense reference: a plain array over the same range.
    struct Ref {
        lo: i64,
        vals: Vec<i64>,
    }

    impl Ref {
        fn new(lo: i64, hi: i64) -> Self {
            Ref { lo, vals: vec![0; (hi - lo) as usize] }
        }
        fn range_add(&mut self, l: i64, r: i64, d: i64) {
            for t in l.max(self.lo)..r.min(self.lo + self.vals.len() as i64) {
                self.vals[(t - self.lo) as usize] += d;
            }
        }
        fn load_at(&self, t: i64) -> i64 {
            let i = t - self.lo;
            if i < 0 || i >= self.vals.len() as i64 {
                0
            } else {
                self.vals[i as usize]
            }
        }
        fn max(&self) -> i64 {
            self.vals.iter().copied().max().unwrap_or(0).max(0)
        }
        fn first_over(&self, l: i64, r: i64, cap: i64) -> Option<i64> {
            (l.max(self.lo)..=r.min(self.lo + self.vals.len() as i64 - 1))
                .find(|&t| self.load_at(t) > cap)
        }
    }

    #[test]
    fn basic_parts() {
        let mut t = SegTreeProfile::new(0, 16);
        t.range_add(2, 6, 3); // part [2,5] demand 3
        t.range_add(4, 9, 2); // part [4,8] demand 2
        assert_eq!(t.max(), 5);
        assert_eq!(t.load_at(3), 3);
        assert_eq!(t.load_at(4), 5);
        assert_eq!(t.load_at(6), 2);
        assert_eq!(t.load_at(9), 0);
        assert_eq!(t.first_over(0, 15, 3), Some(4));
        assert_eq!(t.first_over(0, 15, 4), Some(4));
        assert_eq!(t.first_over(0, 15, 5), None);
        assert_eq!(t.first_over(5, 15, 3), Some(5));
        assert_eq!(t.peak_time(), 4);
        // removal restores the old profile exactly
        t.range_add(4, 9, -2);
        assert_eq!(t.max(), 3);
        assert_eq!(t.load_at(4), 3);
        assert_eq!(t.first_over(0, 15, 2), Some(2));
    }

    #[test]
    fn empty_tree_is_all_zero() {
        let t = SegTreeProfile::new(5, 5); // degenerate, widened
        assert_eq!(t.max(), 0);
        assert_eq!(t.load_at(5), 0);
        assert_eq!(t.first_over(0, 100, -1), Some(5), "zero > -1 inside range");
        assert_eq!(t.first_over(0, 100, 0), None);
    }

    /// Randomized add/remove fuzz against the dense reference — the
    /// in-tree oracle for the tree (the cross-structure oracle is the
    /// linear profile itself, see `prop_segtree_profile_matches_linear`).
    #[test]
    fn fuzz_against_dense_reference() {
        // Miri interprets ~1000× slower than native; the nightly Miri
        // CI job runs this test for its UB coverage, not its case
        // breadth, so shrink the sweep there (native runs keep it all).
        let (cases, ops) = if cfg!(miri) { (4, 40) } else { (60, 200) };
        let mut rng = Rng::seed_from_u64(0xC0FFEE);
        for case in 0..cases {
            let lo = rng.gen_range(40) as i64 - 20;
            let span = 2 + rng.gen_range(120) as i64;
            let mut tree = SegTreeProfile::new(lo, lo + span);
            let mut reference = Ref::new(lo, lo + span);
            let mut live: Vec<(i64, i64, i64)> = Vec::new();
            for _ in 0..ops {
                if !live.is_empty() && rng.gen_bool(0.4) {
                    // remove a live part
                    let k = rng.gen_range(live.len());
                    let (l, r, d) = live.swap_remove(k);
                    tree.range_add(l, r, -d);
                    reference.range_add(l, r, -d);
                } else {
                    let l = lo + rng.gen_range(span as usize) as i64;
                    let r = l + 1 + rng.gen_range(20) as i64;
                    let d = 1 + rng.gen_range(9) as i64;
                    tree.range_add(l, r, d);
                    reference.range_add(l, r, d);
                    live.push((l, r, d));
                }
                assert_eq!(tree.max(), reference.max(), "case {case}: max");
                for _ in 0..8 {
                    let t = lo - 2 + rng.gen_range((span + 4) as usize) as i64;
                    assert_eq!(
                        tree.load_at(t),
                        reference.load_at(t),
                        "case {case}: load_at({t})"
                    );
                }
                let ql = lo - 1 + rng.gen_range((span + 2) as usize) as i64;
                let qr = ql + rng.gen_range(40) as i64;
                let cap = rng.gen_range(25) as i64 - 2;
                assert_eq!(
                    tree.first_over(ql, qr, cap),
                    reference.first_over(ql, qr, cap),
                    "case {case}: first_over({ql},{qr},{cap})"
                );
            }
        }
    }
}
