//! Conflict analysis and no-good learning over bound literals.
//!
//! This is the conflict-driven half of the search kernel (after the
//! lazy-clause-generation design of `plaans/aries` and CP-SAT, see
//! PAPERS.md): every pruning recorded on the trail carries an
//! **explanation** — a conjunction of bound predicates
//! ([`Lit`]: `x ≥ v` / `x ≤ v`) that implied it — and every failure
//! carries a conflict explanation. [`analyze`] resolves a conflict
//! backwards over the current decision level to the **first unique
//! implication point**, producing a bound-predicate **no-good**: a
//! conjunction of literals that can never again all hold. The no-good
//! is stored in the [`NoGoodDb`] and enforced by a watched-literal
//! propagator integrated into the engine's cheap queue tier, so the
//! search never re-explores a subtree any prefix of which it has
//! already refuted — including across Luby restarts (each engine's
//! database lives for its whole solve).
//!
//! Soundness invariants (each is load-bearing):
//! * An explanation recorded for a trail entry only references
//!   literals true *before* the entry was pushed, so resolution always
//!   moves strictly backwards in time.
//! * Literals entailed at decision level 0 (root facts, possibly under
//!   the monotonically tightening objective bound) are dropped from
//!   no-goods — they hold for the remainder of the run.
//! * Decisions are single bound literals ([`crate::cp::SearchStrategy`]'s
//!   learned mode branches `x ≤ v` / `x ≥ v`), so the 1UIP cut always
//!   terminates with exactly one current-level literal whose negation
//!   is again a bound literal.
//! * Watched literals need no maintenance on backtrack: undoing only
//!   relaxes bounds, which can never turn a watched non-true literal
//!   true.

use super::domain::{event, Lit, VarId};
use super::engine::PropagationEngine;
use super::propagators::{Conflict, Ctx, REASON_DECISION, REASON_PROP};
use super::search::SearchStats;

// ---------------------------------------------------------------------
// Luby restart sequence
// ---------------------------------------------------------------------

/// The Luby sequence `1 1 2 1 1 2 4 1 1 2 1 1 2 4 8 …` (1-indexed):
/// the conflict budget of restart `i` is `base · luby(i)`. The optimal
/// universal restart schedule (Luby et al. 1993); learned no-goods and
/// activities are kept across restarts, so restarting only re-orders
/// exploration.
pub(crate) fn luby(mut i: u64) -> u64 {
    debug_assert!(i >= 1);
    loop {
        // find k with 2^k - 1 >= i
        let mut k = 1u32;
        while (1u64 << k) - 1 < i {
            k += 1;
        }
        if (1u64 << k) - 1 == i {
            return 1u64 << (k - 1);
        }
        i -= (1u64 << (k - 1)) - 1;
    }
}

// ---------------------------------------------------------------------
// Variable activities (VSIDS) + branch-position heap
// ---------------------------------------------------------------------

/// VSIDS-style variable activities: bumped for every variable involved
/// in a conflict (its explanation literals and resolved entries),
/// decayed geometrically per conflict via a growing increment, rescaled
/// before overflow.
pub(crate) struct VarActivity {
    act: Vec<f64>,
    inc: f64,
    /// Variables bumped since the last [`VarActivity::swap_bumped`] —
    /// the search re-sifts their heap entries after each analysis.
    bumped: Vec<u32>,
}

const ACT_DECAY: f64 = 0.95;
const ACT_RESCALE: f64 = 1e100;

impl Default for VarActivity {
    fn default() -> Self {
        VarActivity { act: Vec::new(), inc: 1.0, bumped: Vec::new() }
    }
}

impl VarActivity {
    /// Zeroed activities for `nvars` variables.
    pub fn new(nvars: usize) -> Self {
        VarActivity { act: vec![0.0; nvars], inc: 1.0, bumped: Vec::new() }
    }

    /// Re-zero for a new solve over `nvars` variables, keeping buffer
    /// capacity (the solve-context reuse path).
    pub fn reset(&mut self, nvars: usize) {
        self.act.clear();
        self.act.resize(nvars, 0.0);
        self.inc = 1.0;
        self.bumped.clear();
    }

    /// Activity of `var`.
    #[inline]
    pub fn get(&self, var: u32) -> f64 {
        self.act[var as usize]
    }

    /// Bump `var` by the current increment (conflict participation).
    pub fn bump(&mut self, var: VarId) {
        let v = var.0 as usize;
        self.act[v] += self.inc;
        self.bumped.push(var.0);
        if self.act[v] > ACT_RESCALE {
            for a in self.act.iter_mut() {
                *a *= 1.0 / ACT_RESCALE;
            }
            self.inc *= 1.0 / ACT_RESCALE;
        }
    }

    /// Geometric decay (applied once per conflict): growing the
    /// increment instead of shrinking every activity.
    pub fn decay(&mut self) {
        self.inc *= 1.0 / ACT_DECAY;
    }

    /// Move the variables bumped since the last call into `out`
    /// (capacities ping-pong between the two buffers, so steady-state
    /// conflict handling never reallocates).
    pub fn swap_bumped(&mut self, out: &mut Vec<u32>) {
        out.clear();
        std::mem::swap(&mut self.bumped, out);
    }
}

/// Indexed max-heap over branch-order *positions*, keyed by the
/// activity of the variable at each position (ties broken toward the
/// earlier position, so zero-activity search degenerates exactly to
/// the static branch order). Supports the increase-key (`resift`)
/// needed after conflict bumps.
pub(crate) struct BranchHeap {
    heap: Vec<u32>,
    /// position → index in `heap`, or [`BranchHeap::ABSENT`].
    loc: Vec<u32>,
}

impl Default for BranchHeap {
    fn default() -> Self {
        BranchHeap { heap: Vec::new(), loc: Vec::new() }
    }
}

impl BranchHeap {
    const ABSENT: u32 = u32::MAX;

    /// Empty heap over `npos` branch positions.
    pub fn new(npos: usize) -> Self {
        BranchHeap { heap: Vec::with_capacity(npos), loc: vec![Self::ABSENT; npos] }
    }

    /// Re-empty for a new solve over `npos` branch positions, keeping
    /// buffer capacity (the solve-context reuse path).
    pub fn reset(&mut self, npos: usize) {
        self.heap.clear();
        self.loc.clear();
        self.loc.resize(npos, Self::ABSENT);
    }

    /// Whether no position is queued.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Strict priority order: higher activity first, then earlier
    /// position.
    #[inline]
    fn before(a: u32, b: u32, act: &VarActivity, pos_var: &[u32]) -> bool {
        let (ka, kb) = (act.get(pos_var[a as usize]), act.get(pos_var[b as usize]));
        ka > kb || (ka == kb && a < b)
    }

    fn sift_up(&mut self, mut i: usize, act: &VarActivity, pos_var: &[u32]) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if Self::before(self.heap[i], self.heap[parent], act, pos_var) {
                self.heap.swap(i, parent);
                self.loc[self.heap[i] as usize] = i as u32;
                self.loc[self.heap[parent] as usize] = parent as u32;
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize, act: &VarActivity, pos_var: &[u32]) {
        loop {
            let (l, r) = (2 * i + 1, 2 * i + 2);
            let mut best = i;
            if l < self.heap.len() && Self::before(self.heap[l], self.heap[best], act, pos_var)
            {
                best = l;
            }
            if r < self.heap.len() && Self::before(self.heap[r], self.heap[best], act, pos_var)
            {
                best = r;
            }
            if best == i {
                break;
            }
            self.heap.swap(i, best);
            self.loc[self.heap[i] as usize] = i as u32;
            self.loc[self.heap[best] as usize] = best as u32;
            i = best;
        }
    }

    /// Queue position `p` (no-op if already queued).
    pub fn insert(&mut self, p: u32, act: &VarActivity, pos_var: &[u32]) {
        if self.loc[p as usize] != Self::ABSENT {
            return;
        }
        let i = self.heap.len();
        self.heap.push(p);
        self.loc[p as usize] = i as u32;
        self.sift_up(i, act, pos_var);
    }

    /// Pop the highest-priority position.
    pub fn pop(&mut self, act: &VarActivity, pos_var: &[u32]) -> Option<u32> {
        let top = *self.heap.first()?;
        self.loc[top as usize] = Self::ABSENT;
        let last = self.heap.pop()?;
        if !self.heap.is_empty() {
            self.heap[0] = last;
            self.loc[last as usize] = 0;
            self.sift_down(0, act, pos_var);
        }
        Some(top)
    }

    /// Restore the heap invariant for `p` after its key increased.
    pub fn resift(&mut self, p: u32, act: &VarActivity, pos_var: &[u32]) {
        let i = self.loc[p as usize];
        if i != Self::ABSENT {
            self.sift_up(i as usize, act, pos_var);
        }
    }
}

// ---------------------------------------------------------------------
// Learned-no-good database with watched bound literals
// ---------------------------------------------------------------------

/// One learned no-good: a conjunction of bound literals that must
/// never all hold again. Enforced clause-style — when all but one
/// literal are true, the negation of the remaining literal is
/// propagated.
pub(crate) struct NoGood {
    /// The forbidden conjunction (assertion literal first at creation).
    pub lits: Vec<Lit>,
    /// Indices (into `lits`) of the two watched literals.
    pub watch: [u32; 2],
    /// Activity for database reduction (bumped on conflict
    /// participation, decayed geometrically).
    pub activity: f64,
}

/// The learned-constraint database: no-goods, per-variable watch lists
/// over their watched literals, a propagation queue drained with the
/// engine's cheap tier, and activity bookkeeping for reduction.
pub(crate) struct NoGoodDb {
    /// All live no-goods (ids are indices; reduction re-numbers).
    pub nogoods: Vec<NoGood>,
    /// var → `(nogood id, watch slot, lit index)`; an entry is stale —
    /// and lazily dropped — once the no-good's watch slot moved away
    /// from that literal.
    watches: Vec<Vec<(u32, u8, u32)>>,
    queue: Vec<u32>,
    in_queue: Vec<bool>,
    act_inc: f64,
}

const NG_DECAY: f64 = 0.999;

impl Default for NoGoodDb {
    fn default() -> Self {
        NoGoodDb::new(0)
    }
}

impl NoGoodDb {
    /// Empty database over `nvars` variables.
    pub fn new(nvars: usize) -> Self {
        NoGoodDb {
            nogoods: Vec::new(),
            watches: vec![Vec::new(); nvars],
            queue: Vec::new(),
            in_queue: Vec::new(),
            act_inc: 1.0,
        }
    }

    /// Re-empty for a new solve over `nvars` variables. Per-variable
    /// watch rows and the queue keep their capacity; rows beyond
    /// `nvars` are retained (cleared) so shrinking window re-solves
    /// never free them.
    pub fn reset(&mut self, nvars: usize) {
        self.nogoods.clear();
        for w in self.watches.iter_mut() {
            w.clear();
        }
        if self.watches.len() < nvars {
            self.watches.resize_with(nvars, Vec::new);
        }
        self.queue.clear();
        self.in_queue.clear();
        self.act_inc = 1.0;
    }

    /// Number of stored no-goods.
    pub fn len(&self) -> usize {
        self.nogoods.len()
    }

    /// Park watch 0 of `gid` on literal index `k`, moving watch 1 off
    /// `k` if the two would collide (shared by the inert and the
    /// asserting arms of [`NoGoodDb::propagate`]).
    fn park_watch0(&mut self, gid: u32, k: u32) {
        self.set_watch(gid, 0, k);
        if self.nogoods[gid as usize].watch[1] == k {
            let alt = if k == 0 { 1 } else { 0 };
            self.set_watch(gid, 1, alt);
        }
    }

    /// Point watch `slot` of `gid` at literal index `li`, registering
    /// the new watch entry (the old entry goes stale and is dropped
    /// lazily by [`NoGoodDb::on_event`]).
    fn set_watch(&mut self, gid: u32, slot: usize, li: u32) {
        let ng = &mut self.nogoods[gid as usize];
        if ng.watch[slot] == li {
            return;
        }
        ng.watch[slot] = li;
        let var = ng.lits[li as usize].var.0 as usize;
        self.watches[var].push((gid, slot as u8, li));
    }

    /// Store a new no-good (assertion literal first) and enqueue it for
    /// propagation. Returns its id.
    ///
    /// Clone-audit note: `lits` is a per-no-good heap allocation,
    /// deliberately kept — the database owns each learned conjunction
    /// for the rest of the solve (watch indices point into it), so it
    /// cannot live in a per-conflict scratch buffer.
    pub fn add(&mut self, lits: Vec<Lit>) -> u32 {
        debug_assert!(lits.len() >= 2, "size-1 no-goods are asserted at the root");
        let gid = self.nogoods.len() as u32;
        self.nogoods.push(NoGood {
            lits,
            watch: [u32::MAX, u32::MAX],
            activity: self.act_inc,
        });
        self.set_watch(gid, 0, 0);
        self.set_watch(gid, 1, 1);
        self.in_queue.push(true);
        self.queue.push(gid);
        gid
    }

    /// Wake no-goods watching a literal on `var` that `mask` may have
    /// made true; lazily drops stale watch entries.
    pub fn on_event(&mut self, var: u32, mask: u8) {
        let list = &mut self.watches[var as usize];
        if list.is_empty() {
            return;
        }
        let nogoods = &self.nogoods;
        let (queue, in_queue) = (&mut self.queue, &mut self.in_queue);
        let mut i = 0;
        while i < list.len() {
            let (gid, slot, li) = list[i];
            let ng = &nogoods[gid as usize];
            if ng.watch[slot as usize] != li {
                list.swap_remove(i);
                continue;
            }
            let want = if ng.lits[li as usize].is_lb { event::LB } else { event::UB };
            if mask & want != 0 && !in_queue[gid as usize] {
                in_queue[gid as usize] = true;
                queue.push(gid);
            }
            i += 1;
        }
    }

    /// Pop the next queued no-good.
    pub fn pop_queue(&mut self) -> Option<u32> {
        let gid = self.queue.pop()?;
        self.in_queue[gid as usize] = false;
        Some(gid)
    }

    /// Drop all queued work (conflict path).
    pub fn clear_queue(&mut self) {
        for &g in &self.queue {
            self.in_queue[g as usize] = false;
        }
        self.queue.clear();
    }

    /// Bump a no-good's activity (it participated in a conflict).
    pub fn bump(&mut self, gid: u32) {
        let a = &mut self.nogoods[gid as usize].activity;
        *a += self.act_inc;
        if *a > ACT_RESCALE {
            for ng in self.nogoods.iter_mut() {
                ng.activity *= 1.0 / ACT_RESCALE;
            }
            self.act_inc *= 1.0 / ACT_RESCALE;
        }
    }

    /// Geometric activity decay (once per conflict).
    pub fn decay(&mut self) {
        self.act_inc *= 1.0 / NG_DECAY;
    }

    /// Propagate no-good `gid`: scan its literals under the current
    /// domains; if one is false the no-good is inert on this branch, if
    /// two are unfixed the watches move there, if exactly one is
    /// unfixed its negation is asserted (explained by the other
    /// literals), and if all are true the no-good is violated.
    pub fn propagate(
        &mut self,
        gid: u32,
        ctx: &mut Ctx,
        stats: &mut SearchStats,
    ) -> Result<(), Conflict> {
        let g = gid as usize;
        let mut unknown: [u32; 2] = [0; 2];
        let mut n_unknown = 0usize;
        let mut false_at: Option<u32> = None;
        {
            let ng = &self.nogoods[g];
            for (k, l) in ng.lits.iter().enumerate() {
                if l.is_false_in(ctx.doms) {
                    false_at = Some(k as u32);
                    break;
                }
                if !l.is_true_in(ctx.doms) {
                    if n_unknown < 2 {
                        unknown[n_unknown] = k as u32;
                    }
                    n_unknown += 1;
                    if n_unknown == 2 {
                        break;
                    }
                }
            }
        }
        if let Some(k) = false_at {
            // a falsified literal makes the conjunction unviolatable on
            // this branch: park a watch on it (it stays non-true until
            // undone, which preserves the watch invariant)
            self.park_watch0(gid, k);
            return Ok(());
        }
        match n_unknown {
            0 => {
                // every literal holds → the no-good is violated
                if ctx.explaining() {
                    ctx.begin_expl();
                    for i in 0..self.nogoods[g].lits.len() {
                        let l = self.nogoods[g].lits[i];
                        ctx.expl_push(l);
                    }
                }
                ctx.fail()
            }
            1 => {
                // all but one hold → assert the negation of the rest
                let k = unknown[0];
                let lit = self.nogoods[g].lits[k as usize];
                if ctx.explaining() {
                    ctx.begin_expl();
                    for i in 0..self.nogoods[g].lits.len() {
                        if i != k as usize {
                            let l = self.nogoods[g].lits[i];
                            ctx.expl_push(l);
                        }
                    }
                }
                self.park_watch0(gid, k);
                stats.nogoods_pruned += 1;
                let neg = lit.negation();
                ctx.expl.reason = gid;
                let r = if neg.is_lb {
                    ctx.set_min(neg.var, neg.val)
                } else {
                    ctx.set_max(neg.var, neg.val)
                };
                ctx.expl.reason = REASON_PROP;
                r
            }
            _ => {
                // two unfixed literals: watch them
                self.set_watch(gid, 0, unknown[0]);
                self.set_watch(gid, 1, unknown[1]);
                Ok(())
            }
        }
    }

    /// Activity-based reduction: drop the lower-activity half of the
    /// no-goods longer than 2 literals (binary no-goods are cheap and
    /// strong). Must run with the trail at the root — no trail entry
    /// may reference a no-good id afterwards — which the learned search
    /// guarantees by reducing only at restarts.
    ///
    /// Clone-audit note: the `long_acts` vector and the database
    /// rebuild below allocate, deliberately — reduction runs at restart
    /// cadence (every `nogood_cap` conflicts at most), never inside the
    /// per-node propagation loop.
    pub fn reduce(&mut self) {
        let mut long_acts: Vec<f64> = self
            .nogoods
            .iter()
            .filter(|ng| ng.lits.len() > 2)
            .map(|ng| ng.activity)
            .collect();
        if long_acts.is_empty() {
            return;
        }
        long_acts.sort_by(f64::total_cmp);
        let threshold = long_acts[long_acts.len() / 2];
        let old = std::mem::take(&mut self.nogoods);
        for w in self.watches.iter_mut() {
            w.clear();
        }
        self.queue.clear();
        self.in_queue.clear();
        for ng in old {
            if ng.lits.len() <= 2 || ng.activity >= threshold {
                let gid = self.nogoods.len() as u32;
                self.nogoods.push(NoGood { watch: [u32::MAX, u32::MAX], ..ng });
                // re-enqueue: the fresh watches point at arbitrary
                // literals, and a kept no-good may even be unit (or
                // violated) at the restart root under the tightened
                // objective bound — one propagation pass re-parks every
                // watch correctly instead of waiting for an unrelated
                // event
                self.in_queue.push(true);
                self.queue.push(gid);
                self.set_watch(gid, 0, 0);
                self.set_watch(gid, 1, 1);
            }
        }
    }
}

// ---------------------------------------------------------------------
// Conflict analysis (first unique implication point)
// ---------------------------------------------------------------------

/// Result of conflict analysis.
pub(crate) enum Analyzed {
    /// The conflict holds at decision level 0: the search space is
    /// exhausted under the current objective bound.
    Root,
    /// A learned no-good. `lits[0]` is the assertion literal (the 1UIP,
    /// made true at the conflicting level); after backjumping to
    /// `level`, the no-good propagates its negation.
    NoGood {
        /// The forbidden conjunction, assertion literal first.
        lits: Vec<Lit>,
        /// Backjump level (highest level among the non-assertion
        /// literals; 0 when the no-good is otherwise empty).
        level: usize,
    },
}

/// Earliest trail entry whose recorded bound entails `l`, or `None`
/// when `l` already holds in the root domain. Precondition: `l` is
/// currently true (explanation/conflict literals always are at
/// analysis time). Walks the per-variable entry chain newest→oldest;
/// the first entry whose *pre-change* bound no longer entails `l` is
/// the one that established it.
fn entailing_entry(eng: &PropagationEngine, l: Lit) -> Option<u32> {
    let mut cur = eng.expl.last_entry[l.var.0 as usize];
    while cur != super::propagators::NO_ENTRY {
        let i = cur as usize;
        let mlit = eng.expl.lit[i];
        if mlit.is_lb == l.is_lb {
            let old = eng.expl.old_val[i];
            let prev_entails = if l.is_lb { old >= l.val } else { old <= l.val };
            if !prev_entails {
                debug_assert!(
                    if l.is_lb { mlit.val >= l.val } else { mlit.val <= l.val },
                    "chain walk passed a non-entailing entry for a true literal"
                );
                return Some(cur);
            }
        }
        cur = eng.expl.prev[i];
    }
    None
}

/// Per-conflict scratch for [`analyze`], pooled in the solve context:
/// 1UIP analysis runs once per conflict and previously allocated a
/// pair of `BTreeMap`s plus three vectors every time — with the pool,
/// steady-state conflict handling performs no heap allocation at all
/// (the learned no-good's own literal vector excepted; see
/// [`NoGoodDb::add`]).
#[derive(Default)]
pub(crate) struct AnalyzeScratch {
    /// Current-decision-level marks over the trail span above the level
    /// base.
    mark: Vec<bool>,
    /// Raw lower-level literals routed out of the resolution (merged
    /// per (variable, kind) at collection time).
    low: Vec<Lit>,
    /// Merged lower-level literals with their decision levels.
    rest: Vec<(usize, Lit)>,
    /// Degenerate-cut literals kept verbatim.
    kept: Vec<Lit>,
    /// Ids of no-goods whose propagations were resolved through; the
    /// caller bumps them (`analyze` borrows the engine shared, so it
    /// cannot touch the engine-owned database itself). Cleared at the
    /// start of every analysis.
    pub ng_bumps: Vec<u32>,
}

/// Route one literal of the working conjunction: drop it if root-level,
/// mark its entailing trail entry if at the conflicting level, push it
/// onto the lower-level list otherwise. Bumps the variable's activity
/// (conflict participation).
#[allow(clippy::too_many_arguments)]
fn route_lit(
    eng: &PropagationEngine,
    l: Lit,
    base: usize,
    mark: &mut [bool],
    count: &mut usize,
    low: &mut Vec<Lit>,
    act: &mut VarActivity,
) {
    let Some(idx) = entailing_entry(eng, l) else {
        return; // true in the root domain: adds nothing
    };
    if eng.level_of(idx) == 0 {
        return; // root fact (level-0 propagation): holds for the run
    }
    act.bump(l.var);
    if (idx as usize) >= base {
        if !mark[idx as usize - base] {
            mark[idx as usize - base] = true;
            *count += 1;
        }
    } else {
        low.push(l);
    }
}

/// Resolve the current conflict (explanation in `conflict`) to the
/// first unique implication point, producing a learned no-good and its
/// backjump level, or [`Analyzed::Root`] when the conflict needs no
/// decision. Bumps variable activities along the way; the ids of
/// no-goods whose propagations were resolved through are left in
/// `scratch.ng_bumps` for the caller to bump.
pub(crate) fn analyze(
    eng: &PropagationEngine,
    conflict: &[Lit],
    act: &mut VarActivity,
    scratch: &mut AnalyzeScratch,
) -> Analyzed {
    let AnalyzeScratch { mark, low, rest, kept, ng_bumps } = scratch;
    ng_bumps.clear();
    kept.clear();
    low.clear();
    rest.clear();
    let cur = eng.current_level();
    if cur == 0 {
        return Analyzed::Root;
    }
    let base = eng.level_marks[cur - 1] as usize;
    let tlen = eng.trail.len();
    // reuse the pooled mark buffer: analysis runs once per conflict,
    // and this span allocation would otherwise dominate its cost
    mark.clear();
    mark.resize(tlen - base, false);
    let mut count = 0usize;
    for &l in conflict {
        route_lit(eng, l, base, mark, &mut count, low, act);
    }

    // Resolution: repeatedly replace the newest current-level literal
    // by its explanation until one remains (the 1UIP). Decisions are
    // single literals sitting at the level start, so they can only be
    // reached last — i.e. as the UIP itself.
    let mut assertion: Option<Lit> = None;
    let mut scan = tlen;
    while count > 0 {
        let mut i = scan;
        loop {
            i -= 1;
            if mark[i - base] {
                break;
            }
        }
        scan = i;
        let reason = eng.expl.reason_of[i];
        mark[i - base] = false;
        count -= 1;
        if count == 0 {
            // exactly one current-level literal left: the UIP
            if reason != REASON_PROP && reason != REASON_DECISION {
                ng_bumps.push(reason);
            }
            assertion = Some(eng.expl.lit[i]);
            break;
        }
        if reason == REASON_DECISION {
            // Structurally unreachable: the decision is the level's
            // first entry, so every other current-level literal is
            // resolved before the scan reaches it (making it the UIP
            // above). Keeping the literal stays sound if it ever fires.
            debug_assert!(false, "decision reached while other current-level literals pend");
            kept.push(eng.expl.lit[i]);
            continue;
        }
        if reason != REASON_PROP {
            ng_bumps.push(reason);
        }
        for k in eng.expl.expl_off[i] as usize..eng.expl.expl_off[i + 1] as usize {
            let l = eng.expl.arena[k];
            route_lit(eng, l, base, mark, &mut count, low, act);
        }
    }

    // Merge the lower-level literals per (variable, kind) — lower
    // bounds to the larger value, upper bounds to the smaller — and
    // collect them with their levels, LB literals first and each kind
    // in variable order (the historical map-iteration order, preserved
    // because the degenerate-assertion fallback below tie-breaks on
    // collection order).
    low.sort_unstable_by_key(|l| (!l.is_lb, l.var.0));
    let mut j = 0;
    while j < low.len() {
        let mut l = low[j];
        let mut k = j + 1;
        while k < low.len() && low[k].var == l.var && low[k].is_lb == l.is_lb {
            l.val = if l.is_lb { l.val.max(low[k].val) } else { l.val.min(low[k].val) };
            k += 1;
        }
        j = k;
        let idx = entailing_entry(eng, l).expect("merged literal lost its entry");
        rest.push((eng.level_of(idx), l));
    }

    let assertion = match assertion {
        Some(a) => a,
        None => match kept.pop() {
            Some(a) => a,
            None => {
                // No current-level literal at all (e.g. a conflict fired
                // by an in-place objective tightening after a solution):
                // the deepest lower-level literal becomes the assertion;
                // with no lower-level literal either, the conflict holds
                // at the root.
                let Some(deepest) =
                    rest.iter().enumerate().max_by_key(|(_, &(lvl, _))| lvl).map(|(i, _)| i)
                else {
                    return Analyzed::Root;
                };
                rest.swap_remove(deepest).1
            }
        },
    };

    // Drop lower-level literals the assertion already entails (same
    // variable and kind, weaker bound) and compute the backjump level.
    rest.retain(|&(_, l)| {
        !(l.var == assertion.var
            && l.is_lb == assertion.is_lb
            && if l.is_lb { assertion.val >= l.val } else { assertion.val <= l.val })
    });
    // Deterministic literal order (the merge above is ordered already,
    // but make the level-major order explicit for stable no-goods).
    rest.sort_by_key(|&(lvl, l)| (lvl, l.var.0, l.is_lb));
    let level = if kept.is_empty() {
        rest.iter().map(|&(lvl, _)| lvl).max().unwrap_or(0)
    } else {
        cur - 1 // degenerate multi-literal cut: chronological step
    };
    // the learned conjunction itself is a fresh allocation: the no-good
    // database keeps it alive for the rest of the solve (see
    // `NoGoodDb::add`)
    let mut lits = Vec::with_capacity(1 + kept.len() + rest.len());
    lits.push(assertion);
    lits.append(kept);
    lits.extend(rest.drain(..).map(|(_, l)| l));
    Analyzed::NoGood { lits, level }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn luby_prefix_is_canonical() {
        let want = [1u64, 1, 2, 1, 1, 2, 4, 1, 1, 2, 1, 1, 2, 4, 8];
        let got: Vec<u64> = (1..=want.len() as u64).map(luby).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn branch_heap_orders_by_activity_then_position() {
        let pos_var: Vec<u32> = vec![0, 1, 2, 3];
        let mut act = VarActivity::new(4);
        let mut h = BranchHeap::new(4);
        for p in 0..4 {
            h.insert(p, &act, &pos_var);
        }
        // equal activities → static order
        assert_eq!(h.pop(&act, &pos_var), Some(0));
        // bump var 2 → its position jumps the queue
        act.bump(VarId(2));
        h.resift(2, &act, &pos_var);
        assert_eq!(h.pop(&act, &pos_var), Some(2));
        assert_eq!(h.pop(&act, &pos_var), Some(1));
        assert_eq!(h.pop(&act, &pos_var), Some(3));
        assert!(h.is_empty());
        // re-insertion is idempotent
        h.insert(1, &act, &pos_var);
        h.insert(1, &act, &pos_var);
        assert_eq!(h.pop(&act, &pos_var), Some(1));
        assert!(h.pop(&act, &pos_var).is_none());
    }

    #[test]
    fn lit_negation_roundtrip() {
        let l = Lit::geq(VarId(3), 5);
        assert_eq!(l.negation(), Lit::leq(VarId(3), 4));
        assert_eq!(l.negation().negation(), l);
    }
}
