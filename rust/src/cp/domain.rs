//! Trailed finite domains.
//!
//! A domain is an ordered value universe plus `[lo, hi]` index bounds.
//! The universe is either a contiguous integer range (stored as just a
//! base — no materialization, so end-of-retention variables can range
//! over all `n(n+1)/2` events for free) or an explicit strictly
//! increasing value slice (the staged start domains `{id(j,k) : j ≥ k}`).
//! Explicit universes are `(Arc<Vec<i64>>, offset)` windows, so the
//! presolve layer can pack every start domain of a model into one flat
//! arena and hand each variable a cache-friendly slice of it instead of
//! a separately allocated `Vec` per variable (see
//! `presolve`/`StagedModel::build_with`).
//! All solver-time updates are bound tightenings, so the trail only
//! needs `(var, lo, hi)` triples — O(1) undo, no allocation during
//! search. (Interior removals never happen: search branches `x = min` /
//! `x ≥ min + 1`, and all propagators filter bounds.)

use std::sync::Arc;

/// Variable handle (dense index into the model).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct VarId(pub u32);

/// Event-kind bitmasks for typed domain events (see [`DomainEvent`]).
///
/// A propagator registers, per watched variable, the mask of events that
/// can actually enable new filtering for it; the propagation engine then
/// wakes it only on those events. E.g. `LeOffset { x, y, .. }` reads
/// `min(x)` and `max(y)` only, so it subscribes to `LB` on `x` and `UB`
/// on `y` and sleeps through every other bound change.
pub mod event {
    /// Lower bound raised (`min` increased).
    pub const LB: u8 = 1;
    /// Upper bound lowered (`max` decreased).
    pub const UB: u8 = 2;
    /// The domain became a singleton with this change.
    pub const FIX: u8 = 4;
    /// Any event (conservative subscription).
    pub const ANY: u8 = LB | UB | FIX;
}

/// A typed domain-change event: which variable changed and how.
///
/// Every solver-time tightening posts exactly one event carrying
/// [`event::LB`] or [`event::UB`], or-ed with [`event::FIX`] when the
/// change collapsed the domain to a singleton.
#[derive(Debug, Clone, Copy)]
pub struct DomainEvent {
    /// The variable whose bounds changed.
    pub var: VarId,
    /// Bitmask of [`event`] kinds describing the change.
    pub mask: u8,
}

/// A bound predicate (`x ≥ v` or `x ≤ v`) — the literal currency of
/// explained propagation and no-good learning.
///
/// Every solver-time tightening establishes exactly one `Lit`; the
/// explanation of a pruning or a failure is a conjunction of `Lit`s
/// that implied it, and learned no-goods are conjunctions of `Lit`s
/// whose simultaneous truth is forbidden (see `cp::learn`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Lit {
    /// The variable the predicate constrains.
    pub var: VarId,
    /// `true`: the predicate is `var ≥ val`; `false`: `var ≤ val`.
    pub is_lb: bool,
    /// The bound value.
    pub val: i64,
}

impl Lit {
    /// The predicate `x ≥ v`.
    #[inline]
    pub fn geq(var: VarId, val: i64) -> Self {
        Lit { var, is_lb: true, val }
    }

    /// The predicate `x ≤ v`.
    #[inline]
    pub fn leq(var: VarId, val: i64) -> Self {
        Lit { var, is_lb: false, val }
    }

    /// Logical negation over the integers: `¬(x ≥ v) = x ≤ v − 1` and
    /// `¬(x ≤ v) = x ≥ v + 1`.
    #[inline]
    pub fn negation(self) -> Self {
        if self.is_lb {
            Lit::leq(self.var, self.val - 1)
        } else {
            Lit::geq(self.var, self.val + 1)
        }
    }

    /// Whether the predicate currently holds under `d` (the domain of
    /// [`Lit::var`]).
    #[inline]
    pub fn is_true(&self, d: &Domain) -> bool {
        if self.is_lb {
            d.min() >= self.val
        } else {
            d.max() <= self.val
        }
    }

    /// Whether the predicate is currently falsified under `d` (its
    /// negation holds).
    #[inline]
    pub fn is_false(&self, d: &Domain) -> bool {
        if self.is_lb {
            d.max() < self.val
        } else {
            d.min() > self.val
        }
    }
}

#[derive(Debug, Clone)]
enum Repr {
    /// universe = { base, base+1, ... }
    Range { base: i64 },
    /// universe = `vals[off .. off + len]`, a window of a (possibly
    /// shared arena) sorted value array; `len` is implied by the
    /// domain's initial `hi` bound
    Explicit { vals: Arc<Vec<i64>>, off: u32 },
}

impl Repr {
    #[inline]
    fn value_at(&self, idx: u32) -> i64 {
        match self {
            Repr::Range { base } => base + idx as i64,
            Repr::Explicit { vals, off } => vals[(off + idx) as usize],
        }
    }
}

/// A finite integer domain.
#[derive(Debug, Clone)]
pub struct Domain {
    repr: Repr,
    /// inclusive index bounds into the universe
    pub(crate) lo: u32,
    pub(crate) hi: u32,
}

impl Domain {
    /// Domain over explicit sorted distinct values.
    pub fn new(values: Arc<Vec<i64>>) -> Self {
        assert!(!values.is_empty());
        let hi = values.len() as u32 - 1;
        Domain { repr: Repr::Explicit { vals: values, off: 0 }, lo: 0, hi }
    }

    /// Domain over the sorted distinct values `arena[off .. off + len]`
    /// — a window of a flat value arena shared (via `Arc`) by many
    /// variables of one model, so building n variables costs one
    /// allocation instead of n.
    pub fn new_arena(arena: Arc<Vec<i64>>, off: usize, len: usize) -> Self {
        assert!(len > 0 && off + len <= arena.len(), "arena window out of bounds");
        debug_assert!(
            arena[off..off + len].windows(2).all(|w| w[0] < w[1]),
            "arena window must be sorted/unique"
        );
        Domain {
            repr: Repr::Explicit { vals: arena, off: off as u32 },
            lo: 0,
            hi: len as u32 - 1,
        }
    }

    /// Domain over the contiguous range `[lb, ub]`.
    pub fn new_range(lb: i64, ub: i64) -> Self {
        assert!(lb <= ub && (ub - lb) < u32::MAX as i64);
        Domain { repr: Repr::Range { base: lb }, lo: 0, hi: (ub - lb) as u32 }
    }

    #[inline]
    fn value_at(&self, idx: u32) -> i64 {
        self.repr.value_at(idx)
    }

    /// Smallest value still in the domain.
    #[inline]
    pub fn min(&self) -> i64 {
        self.value_at(self.lo)
    }

    /// Largest value still in the domain.
    #[inline]
    pub fn max(&self) -> i64 {
        self.value_at(self.hi)
    }

    /// Whether the domain is a singleton.
    #[inline]
    pub fn is_fixed(&self) -> bool {
        self.lo == self.hi
    }

    /// Number of values still in the domain.
    #[inline]
    pub fn size(&self) -> usize {
        (self.hi - self.lo + 1) as usize
    }

    /// Whether `v` is still in the domain.
    pub fn contains(&self, v: i64) -> bool {
        if v < self.min() || v > self.max() {
            return false;
        }
        match &self.repr {
            Repr::Range { .. } => true,
            Repr::Explicit { vals, off } => {
                let (lo, hi) = ((off + self.lo) as usize, (off + self.hi) as usize);
                vals[lo..=hi].binary_search(&v).is_ok()
            }
        }
    }

    /// Tighten to `>= v`. Returns whether the domain changed; `Err` on
    /// wipe-out.
    pub fn remove_below(&mut self, v: i64) -> Result<bool, ()> {
        if v <= self.min() {
            return Ok(false);
        }
        if v > self.max() {
            return Err(());
        }
        match &self.repr {
            Repr::Range { base } => {
                self.lo = (v - base) as u32;
            }
            Repr::Explicit { vals, off } => {
                let s = &vals[(off + self.lo) as usize..=(off + self.hi) as usize];
                let skip = s.partition_point(|&x| x < v);
                self.lo += skip as u32;
            }
        }
        Ok(true)
    }

    /// Tighten to `<= v`. Returns whether the domain changed; `Err` on
    /// wipe-out.
    pub fn remove_above(&mut self, v: i64) -> Result<bool, ()> {
        if v >= self.max() {
            return Ok(false);
        }
        if v < self.min() {
            return Err(());
        }
        match &self.repr {
            Repr::Range { base } => {
                self.hi = (v - base) as u32;
            }
            Repr::Explicit { vals, off } => {
                let s = &vals[(off + self.lo) as usize..=(off + self.hi) as usize];
                let keep = s.partition_point(|&x| x <= v);
                self.hi = self.lo + keep as u32 - 1;
            }
        }
        Ok(true)
    }

    /// Assign (must be contained).
    pub fn assign(&mut self, v: i64) {
        let ok1 = self.remove_below(v).expect("assign outside domain");
        let ok2 = self.remove_above(v).expect("assign outside domain");
        let _ = (ok1, ok2);
        debug_assert!(self.is_fixed() && self.min() == v);
    }

    /// The fixed value (panics if unfixed).
    pub fn value(&self) -> i64 {
        debug_assert!(self.is_fixed());
        self.min()
    }

    /// Snapshot of the index bounds for trailing.
    #[inline]
    pub fn bounds(&self) -> (u32, u32) {
        (self.lo, self.hi)
    }

    /// Restore trailed index bounds.
    #[inline]
    pub fn restore(&mut self, b: (u32, u32)) {
        self.lo = b.0;
        self.hi = b.1;
    }
}

/// Structure-of-arrays store of every variable's trailed bounds, owned
/// by the propagation engine.
///
/// The per-variable `[lo, hi]` index bounds — the only solver-time
/// mutable state — live in two packed `Vec<u32>` arrays so the hot
/// paths (`drain_events`, backtracking `restore`, the timetable /
/// edge-finding filter scans) walk contiguous cache lines instead of
/// pointer-hopping per-variable `Domain` structs. The immutable value
/// universes stay in a parallel `reprs` array that is only consulted
/// when an index bound must be mapped to a value. [`Domain`] remains
/// the model-layer representation; `load_from` adopts a model's
/// domains into the store at solve start, reusing the store's
/// capacity across LNS window re-solves (the `Arc` value arenas are
/// shared, so adoption is refcount bumps, not copies).
#[derive(Debug, Clone, Default)]
pub struct DomStore {
    reprs: Vec<Repr>,
    lo: Vec<u32>,
    hi: Vec<u32>,
}

impl DomStore {
    /// Number of variables in the store.
    #[inline]
    pub fn len(&self) -> usize {
        self.lo.len()
    }

    /// Whether the store holds no variables.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.lo.is_empty()
    }

    /// Adopt `doms` as the store's contents, reusing capacity.
    pub fn load_from(&mut self, doms: &[Domain]) {
        self.reprs.clear();
        self.lo.clear();
        self.hi.clear();
        self.reprs.extend(doms.iter().map(|d| d.repr.clone()));
        self.lo.extend(doms.iter().map(|d| d.lo));
        self.hi.extend(doms.iter().map(|d| d.hi));
    }

    /// Smallest value still in `v`'s domain.
    #[inline]
    pub fn min(&self, v: VarId) -> i64 {
        let i = v.0 as usize;
        self.reprs[i].value_at(self.lo[i])
    }

    /// Largest value still in `v`'s domain.
    #[inline]
    pub fn max(&self, v: VarId) -> i64 {
        let i = v.0 as usize;
        self.reprs[i].value_at(self.hi[i])
    }

    /// Whether `v`'s domain is a singleton.
    #[inline]
    pub fn is_fixed(&self, v: VarId) -> bool {
        let i = v.0 as usize;
        self.lo[i] == self.hi[i]
    }

    /// Number of values still in `v`'s domain.
    #[inline]
    pub fn size(&self, v: VarId) -> usize {
        let i = v.0 as usize;
        (self.hi[i] - self.lo[i] + 1) as usize
    }

    /// The fixed value of `v` (debug-asserts the domain is fixed).
    #[inline]
    pub fn value(&self, v: VarId) -> i64 {
        debug_assert!(self.is_fixed(v));
        self.min(v)
    }

    /// Whether `val` is still in `v`'s domain.
    pub fn contains(&self, v: VarId, val: i64) -> bool {
        if val < self.min(v) || val > self.max(v) {
            return false;
        }
        let i = v.0 as usize;
        match &self.reprs[i] {
            Repr::Range { .. } => true,
            Repr::Explicit { vals, off } => {
                let (lo, hi) = ((off + self.lo[i]) as usize, (off + self.hi[i]) as usize);
                vals[lo..=hi].binary_search(&val).is_ok()
            }
        }
    }

    /// Tighten `v` to `>= val`. Returns whether the domain changed;
    /// `Err` on wipe-out.
    pub fn remove_below(&mut self, v: VarId, val: i64) -> Result<bool, ()> {
        let i = v.0 as usize;
        let (lo, hi) = (self.lo[i], self.hi[i]);
        let repr = &self.reprs[i];
        if val <= repr.value_at(lo) {
            return Ok(false);
        }
        if val > repr.value_at(hi) {
            return Err(());
        }
        self.lo[i] = match repr {
            Repr::Range { base } => (val - base) as u32,
            Repr::Explicit { vals, off } => {
                let s = &vals[(off + lo) as usize..=(off + hi) as usize];
                lo + s.partition_point(|&x| x < val) as u32
            }
        };
        Ok(true)
    }

    /// Tighten `v` to `<= val`. Returns whether the domain changed;
    /// `Err` on wipe-out.
    pub fn remove_above(&mut self, v: VarId, val: i64) -> Result<bool, ()> {
        let i = v.0 as usize;
        let (lo, hi) = (self.lo[i], self.hi[i]);
        let repr = &self.reprs[i];
        if val >= repr.value_at(hi) {
            return Ok(false);
        }
        if val < repr.value_at(lo) {
            return Err(());
        }
        self.hi[i] = match repr {
            Repr::Range { base } => (val - base) as u32,
            Repr::Explicit { vals, off } => {
                let s = &vals[(off + lo) as usize..=(off + hi) as usize];
                lo + s.partition_point(|&x| x <= val) as u32 - 1
            }
        };
        Ok(true)
    }

    /// Snapshot of `v`'s index bounds for trailing.
    #[inline]
    pub fn bounds(&self, v: VarId) -> (u32, u32) {
        let i = v.0 as usize;
        (self.lo[i], self.hi[i])
    }

    /// Restore `v`'s trailed index bounds — two packed array writes.
    #[inline]
    pub fn restore(&mut self, v: VarId, b: (u32, u32)) {
        let i = v.0 as usize;
        self.lo[i] = b.0;
        self.hi[i] = b.1;
    }
}

impl Lit {
    /// Whether the predicate currently holds in `d`.
    #[inline]
    pub fn is_true_in(&self, d: &DomStore) -> bool {
        if self.is_lb {
            d.min(self.var) >= self.val
        } else {
            d.max(self.var) <= self.val
        }
    }

    /// Whether the predicate is currently falsified in `d` (its
    /// negation holds).
    #[inline]
    pub fn is_false_in(&self, d: &DomStore) -> bool {
        if self.is_lb {
            d.max(self.var) < self.val
        } else {
            d.min(self.var) > self.val
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dom(vals: &[i64]) -> Domain {
        Domain::new(Arc::new(vals.to_vec()))
    }

    #[test]
    fn basic_bounds() {
        let d = dom(&[2, 5, 9, 12]);
        assert_eq!(d.min(), 2);
        assert_eq!(d.max(), 12);
        assert_eq!(d.size(), 4);
        assert!(!d.is_fixed());
        assert!(d.contains(9));
        assert!(!d.contains(3));
    }

    #[test]
    fn range_domain_no_materialization() {
        let mut d = Domain::new_range(10, 1_000_000);
        assert_eq!(d.min(), 10);
        assert_eq!(d.max(), 1_000_000);
        assert!(d.contains(500_000));
        assert_eq!(d.remove_below(99), Ok(true));
        assert_eq!(d.min(), 99);
        assert_eq!(d.remove_above(200), Ok(true));
        assert_eq!(d.max(), 200);
        assert_eq!(d.size(), 102);
        assert_eq!(d.remove_below(300), Err(()));
    }

    #[test]
    fn remove_below_snaps_to_next_value() {
        let mut d = dom(&[2, 5, 9, 12]);
        assert_eq!(d.remove_below(3), Ok(true));
        assert_eq!(d.min(), 5);
        assert_eq!(d.remove_below(5), Ok(false));
        assert_eq!(d.remove_below(13), Err(()));
    }

    #[test]
    fn remove_above_snaps_to_prev_value() {
        let mut d = dom(&[2, 5, 9, 12]);
        assert_eq!(d.remove_above(11), Ok(true));
        assert_eq!(d.max(), 9);
        assert_eq!(d.remove_above(1), Err(()));
    }

    #[test]
    fn assign_and_restore() {
        let mut d = dom(&[2, 5, 9, 12]);
        let snap = d.bounds();
        d.assign(9);
        assert!(d.is_fixed());
        assert_eq!(d.value(), 9);
        d.restore(snap);
        assert_eq!((d.min(), d.max()), (2, 12));
    }

    #[test]
    #[should_panic]
    fn assign_outside_panics() {
        let mut d = dom(&[2, 5]);
        d.assign(3);
    }

    #[test]
    fn arena_windows_are_independent() {
        // two domains share one arena: [2,5,9 | 4,8,15,16]
        let arena = Arc::new(vec![2, 5, 9, 4, 8, 15, 16]);
        let mut a = Domain::new_arena(Arc::clone(&arena), 0, 3);
        let mut b = Domain::new_arena(Arc::clone(&arena), 3, 4);
        assert_eq!((a.min(), a.max(), a.size()), (2, 9, 3));
        assert_eq!((b.min(), b.max(), b.size()), (4, 16, 4));
        assert!(a.contains(5) && !a.contains(4));
        assert!(b.contains(15) && !b.contains(5));
        assert_eq!(a.remove_below(3), Ok(true));
        assert_eq!(a.min(), 5);
        assert_eq!(b.min(), 4, "windows must not interfere");
        assert_eq!(b.remove_above(14), Ok(true));
        assert_eq!(b.max(), 8);
        let snap = b.bounds();
        b.assign(8);
        assert_eq!(b.value(), 8);
        b.restore(snap);
        assert_eq!((b.min(), b.max()), (4, 8));
        assert_eq!(a.remove_below(10), Err(()));
    }

    #[test]
    #[should_panic]
    fn arena_window_out_of_bounds_panics() {
        let arena = Arc::new(vec![1, 2, 3]);
        let _ = Domain::new_arena(arena, 2, 2);
    }

    /// The SoA store must agree with per-struct `Domain` semantics on
    /// every operation, including snap-to-next-value tightenings.
    #[test]
    fn dom_store_matches_domain_semantics() {
        let doms = vec![dom(&[2, 5, 9, 12]), Domain::new_range(10, 40), dom(&[7])];
        let mut store = DomStore::default();
        store.load_from(&doms);
        assert_eq!(store.len(), 3);
        let (a, b, c) = (VarId(0), VarId(1), VarId(2));
        assert_eq!((store.min(a), store.max(a), store.size(a)), (2, 12, 4));
        assert_eq!((store.min(b), store.max(b)), (10, 40));
        assert!(store.is_fixed(c) && store.value(c) == 7);
        assert!(store.contains(a, 9) && !store.contains(a, 3));

        // snap-to-value tightenings on the explicit universe
        assert_eq!(store.remove_below(a, 3), Ok(true));
        assert_eq!(store.min(a), 5);
        assert_eq!(store.remove_below(a, 5), Ok(false));
        assert_eq!(store.remove_above(a, 11), Ok(true));
        assert_eq!(store.max(a), 9);
        assert_eq!(store.remove_above(a, 1), Err(()));

        // range universe stays index-arithmetic only
        assert_eq!(store.remove_below(b, 15), Ok(true));
        assert_eq!(store.remove_above(b, 20), Ok(true));
        assert_eq!((store.min(b), store.max(b), store.size(b)), (15, 20, 6));
        assert_eq!(store.remove_below(b, 21), Err(()));

        // trail round-trip
        let snap = store.bounds(a);
        assert_eq!(store.remove_below(a, 9), Ok(true));
        assert!(store.is_fixed(a));
        store.restore(a, snap);
        assert_eq!((store.min(a), store.max(a)), (5, 9));

        // Lit truth in the store
        assert!(Lit::geq(a, 5).is_true_in(&store));
        assert!(Lit::geq(a, 10).is_false_in(&store));
        assert!(!Lit::leq(a, 7).is_true_in(&store));
        assert!(Lit::leq(a, 4).is_false_in(&store));

        // load_from resets contents while reusing the store
        store.load_from(&doms[..2]);
        assert_eq!(store.len(), 2);
        assert_eq!((store.min(a), store.max(a)), (2, 12));
    }
}
