//! DFS branch-and-bound search over a [`Model`](super::Model).
//!
//! Chronological backtracking on top of the event-driven
//! `PropagationEngine` (see `engine.rs`): the engine owns the domains,
//! trail, two-tier queue and per-propagator incremental state; the
//! search layer owns the frame stack, a trailed first-unfixed branch
//! pointer over the caller-supplied branch order, min-value branching
//! (`x = min` on the left, `x ≥ min+1` on the right), and minimization
//! via the engine's persistent objective propagator whose rhs tightens
//! in place after every improving solution. Every emitted solution is
//! verified against all constraints before it is reported — filtering
//! bugs can cost time but never correctness.

use super::domain::{Lit, VarId};
use super::engine::{FilteringMode, ProfileMode, PropagationEngine, SolveCtx};
use super::learn::{analyze, luby, AnalyzeScratch, Analyzed, BranchHeap, VarActivity};
use super::Model;
use crate::util::{Csr, Deadline, Incumbent};
use std::mem;
use std::sync::Arc;

/// Terminal status of a search.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Status {
    /// Search space exhausted with at least one solution: the incumbent
    /// is optimal.
    Optimal,
    /// Limit hit with at least one solution.
    Feasible,
    /// Search space exhausted with no solution.
    Infeasible,
    /// Limit hit with no solution.
    Unknown,
}

/// Search statistics, including the propagation engine's event/queue
/// counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SearchStats {
    /// Branch decisions taken.
    pub nodes: u64,
    /// Dead ends (failed propagations / unverifiable leaves).
    pub conflicts: u64,
    /// Improving solutions emitted.
    pub solutions: u64,
    /// Propagator invocations.
    pub propagations: u64,
    /// Typed domain events posted (bound changes).
    pub events_posted: u64,
    /// Wakeups suppressed because the event kind did not match the
    /// propagator's watch mask (event filtering at work).
    pub wakeups_skipped: u64,
    /// Cumulative compulsory-part re-synchronisations (incremental
    /// forward updates plus backtrack undo).
    pub cum_resyncs: u64,
    /// Cumulative profile flattenings (linear profile mode only — each
    /// replaces what used to be a from-scratch rebuild per invocation;
    /// the segment-tree profile never re-flattens, so this stays 0
    /// under `--profile segtree`).
    pub cum_rebuilds: u64,
    /// Luby restarts taken by the learned search.
    pub restarts: u64,
    /// No-goods added to the learned-constraint database (conflict
    /// analyses plus decision no-goods from exhausted leaves).
    pub nogoods_learned: u64,
    /// Bound tightenings asserted by the watched no-good propagator —
    /// each one prunes a subtree chronological search would re-explore.
    pub nogoods_pruned: u64,
    /// Activity-based reductions of the no-good database.
    pub db_reductions: u64,
    /// Bound tightenings contributed by the timetable edge-finding
    /// rules beyond plain timetable filtering (`--filtering
    /// edge-finding` only; stays 0 under `--filtering timetable`).
    pub ef_prunes: u64,
    /// Bound tightenings and deactivations asserted by the disjunctive
    /// propagator over presolve-detected serialized heavy-item cliques.
    pub disj_prunes: u64,
    /// Heavy-item pairs covered by presolve-detected [`Disjunctive`]
    /// propagators in the model this engine ran on (`h·(h−1)/2` summed
    /// over cliques; 0 when detection found nothing or was disabled).
    ///
    /// [`Disjunctive`]: super::Propagator::Disjunctive
    pub disj_pairs_detected: u64,
    /// Root-presolve counters folded in at model-build time (see
    /// [`crate::presolve::PresolveStats`]), accumulated like every
    /// other counter — an LNS run adds one contribution per window
    /// re-solve.
    pub presolve: crate::presolve::PresolveStats,
    /// Poisoned mutexes recovered by `lock_recover` during this solve
    /// (portfolio shared state after a contained member panic).
    pub lock_recoveries: u64,
    /// Solves/members cancelled by a watchdog: heartbeat stall, wall
    /// overrun past the budget slice, or the RSS guard.
    pub watchdog_kills: u64,
    /// Panics contained by `catch_unwind` (portfolio members,
    /// `solve_many` workers, degradation-ladder rungs).
    pub member_panics: u64,
    /// Transient member failures retried (once, with jittered backoff)
    /// by `solve_many`.
    pub member_retries: u64,
}

impl SearchStats {
    /// Accumulate another run's counters into this one (used to
    /// aggregate across LNS window re-solves and portfolio members).
    pub fn merge(&mut self, o: &SearchStats) {
        self.nodes += o.nodes;
        self.conflicts += o.conflicts;
        self.solutions += o.solutions;
        self.propagations += o.propagations;
        self.events_posted += o.events_posted;
        self.wakeups_skipped += o.wakeups_skipped;
        self.cum_resyncs += o.cum_resyncs;
        self.cum_rebuilds += o.cum_rebuilds;
        self.restarts += o.restarts;
        self.nogoods_learned += o.nogoods_learned;
        self.nogoods_pruned += o.nogoods_pruned;
        self.db_reductions += o.db_reductions;
        self.ef_prunes += o.ef_prunes;
        self.disj_prunes += o.disj_prunes;
        self.disj_pairs_detected += o.disj_pairs_detected;
        self.presolve.add(&o.presolve);
        self.lock_recoveries += o.lock_recoveries;
        self.watchdog_kills += o.watchdog_kills;
        self.member_panics += o.member_panics;
        self.member_retries += o.member_retries;
    }

    /// Fold a delta of the process-global resilience counters (see
    /// [`crate::util::events`]) into this run's stats — how recovery
    /// events observed by code with no `SearchStats` in scope (lock
    /// recovery, watchdog kills) surface in `merge` output and
    /// `solve --verbose`.
    pub fn absorb_events(&mut self, d: &crate::util::events::EventSnapshot) {
        self.lock_recoveries += d.lock_recoveries;
        self.watchdog_kills += d.watchdog_kills;
        self.member_panics += d.member_panics;
        self.member_retries += d.member_retries;
    }
}

/// How the branch & bound explores the tree.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SearchMode {
    /// Chronological DFS over the static branch order with min-value
    /// branching — every conflict is forgotten on backtrack. The proof
    /// baseline (and PR-3-and-earlier behavior).
    Chronological,
    /// Conflict-driven search: explained propagation feeds 1UIP
    /// conflict analysis, learned bound-predicate no-goods prune
    /// repeated subtrees, branching follows conflict activity (VSIDS)
    /// with solution-phase value saving, and Luby restarts keep learned
    /// state (see `cp::learn`).
    Learned,
}

/// Search-strategy configuration threaded from the CLI / coordinator
/// down to the kernel: the exploration mode, the Luby restart unit,
/// the learned-no-good database cap, and the cumulative
/// timetable-profile structure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SearchStrategy {
    /// Exploration mode.
    pub mode: SearchMode,
    /// Luby restart unit in conflicts (learned mode; `0` disables
    /// restarts entirely).
    pub restart_base: u64,
    /// No-good database size triggering an activity-based reduction at
    /// the next restart (`0` = never reduce).
    pub nogood_cap: usize,
    /// Incremental `Cumulative` timetable structure (`--profile`):
    /// the O(log H) segment tree by default, with the linear diff-map
    /// profile retained as the A/B baseline and fuzz oracle. Both are
    /// exact and walk the same search tree (see
    /// `prop_segtree_profile_matches_linear`), so — like `restart_base`
    /// — this does not discriminate coordinator cache keys.
    pub profile: ProfileMode,
    /// Cumulative filtering strength (`--filtering`): plain timetable
    /// filtering (the default, and the reference semantics the naive
    /// engine mirrors) or timetable edge-finding, which additionally
    /// runs energy-based start/end filtering over the compulsory-part
    /// profile. Both are exact; edge-finding can only shrink the tree
    /// (asserted by `prop_edge_finding_preserves_optimum`).
    pub filtering: FilteringMode,
    /// Whether presolve-detected [`Disjunctive`] propagators run
    /// (`--disjunctive on|off`). Detection itself always happens at
    /// model build; this knob gates propagation so one built model can
    /// be A/B'd with and without the serialization reasoning.
    ///
    /// [`Disjunctive`]: super::Propagator::Disjunctive
    pub disjunctive: bool,
}

impl Default for SearchStrategy {
    fn default() -> Self {
        Self::chronological()
    }
}

impl SearchStrategy {
    /// The chronological baseline (no learning).
    pub fn chronological() -> Self {
        SearchStrategy {
            mode: SearchMode::Chronological,
            restart_base: 0,
            nogood_cap: 0,
            profile: ProfileMode::SegTree,
            filtering: FilteringMode::Timetable,
            disjunctive: true,
        }
    }

    /// Conflict-driven search with the default Luby-128 restart policy
    /// and a 10k no-good cap.
    pub fn learned() -> Self {
        SearchStrategy {
            mode: SearchMode::Learned,
            restart_base: 128,
            nogood_cap: 10_000,
            profile: ProfileMode::SegTree,
            filtering: FilteringMode::Timetable,
            disjunctive: true,
        }
    }

    /// The same strategy with a different cumulative timetable-profile
    /// structure (the `--profile linear|segtree` A/B knob).
    pub fn with_profile(mut self, profile: ProfileMode) -> Self {
        self.profile = profile;
        self
    }

    /// The same strategy with a different cumulative filtering strength
    /// (the `--filtering timetable|edge-finding` knob).
    pub fn with_filtering(mut self, filtering: FilteringMode) -> Self {
        self.filtering = filtering;
        self
    }

    /// The same strategy with disjunctive propagation toggled (the
    /// `--disjunctive on|off` knob).
    pub fn with_disjunctive(mut self, disjunctive: bool) -> Self {
        self.disjunctive = disjunctive;
        self
    }

    /// Parse a CLI strategy name.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "chronological" => Some(Self::chronological()),
            "learned" => Some(Self::learned()),
            _ => None,
        }
    }

    /// Stable display / cache-key name. Both modes provably reach the
    /// same optimum, so coordinator cache keys only discriminate the
    /// mode, not the restart/cap tuning.
    pub fn name(&self) -> &'static str {
        match self.mode {
            SearchMode::Chronological => "chronological",
            SearchMode::Learned => "learned",
        }
    }

    /// Cache-key discriminant (see [`SearchStrategy::name`]). All
    /// encoded knobs are exact — they never change the reported status
    /// or optimum — but filtering and disjunctive change the *tree*
    /// (node counts, learned clauses), so cached search results keyed
    /// without them would silently mix A/B measurements. Layout:
    /// bit 0 = mode, bit 1 = filtering, bit 2 = disjunctive.
    pub fn cache_key(&self) -> u8 {
        let mode = match self.mode {
            SearchMode::Chronological => 0u8,
            SearchMode::Learned => 1,
        };
        let filtering = match self.filtering {
            FilteringMode::Timetable => 0u8,
            FilteringMode::EdgeFinding => 1,
        };
        mode | filtering << 1 | (self.disjunctive as u8) << 2
    }
}

/// Result of a search: status, best assignment + objective, stats.
pub struct SearchResult {
    /// Terminal status (optimal / feasible / infeasible / unknown).
    pub status: Status,
    /// Best assignment found and its objective value, if any.
    pub best: Option<(Vec<i64>, i64)>,
    /// Search statistics.
    pub stats: SearchStats,
}

impl SearchResult {
    /// Whether at least one solution was found.
    pub fn found(&self) -> bool {
        self.best.is_some()
    }
}

/// Solver configuration.
pub struct Solver {
    /// Wall-clock limit; when it carries a shared [`Incumbent`], the
    /// search observes portfolio cancellation on every limit poll.
    pub deadline: Deadline,
    /// Optional shared pruning bound: the objective bound is seeded
    /// from (and periodically tightened to) the best duration published
    /// here by any cooperating solver. Kept separate from `deadline`'s
    /// cancellation channel on purpose: full-model solves (exact,
    /// CHECKMATE) want global pruning, while LNS window re-solves must
    /// prune only against their *local* incumbent or a member behind
    /// the global best could never make incremental progress.
    pub bound: Option<Arc<Incumbent>>,
    /// Hard cap on branch decisions.
    pub node_limit: u64,
    /// Stop as soon as the first solution is found (Phase-1 style).
    pub first_solution: bool,
    /// Optional branch guards, parallel to `branch_order`: if
    /// `guards[i]` is fixed to 0, branch var `i` is skipped (used for
    /// start/end vars of inactive optional intervals).
    pub guards: Option<Vec<Option<VarId>>>,
    /// Use the naive reference propagation semantics (wake every
    /// watcher on any event, single queue, from-scratch `Cumulative`,
    /// re-enqueue everything on backtrack) instead of the event-driven
    /// engine. Exists for equivalence testing; both modes explore the
    /// same tree because bounds propagation is confluent. Forces the
    /// chronological strategy.
    pub naive: bool,
    /// Search strategy: chronological DFS (the default, and the mode
    /// optimality proofs are cross-checked against in the portfolio) or
    /// conflict-driven learned search. Both are exact — learning is
    /// purely pruning — so they always report the same status and
    /// optimum (asserted by `prop_learned_matches_chronological`).
    pub strategy: SearchStrategy,
}

impl Default for Solver {
    fn default() -> Self {
        Solver {
            deadline: Deadline::unlimited(),
            bound: None,
            node_limit: u64::MAX,
            first_solution: false,
            guards: None,
            naive: false,
            strategy: SearchStrategy::default(),
        }
    }
}

struct Frame {
    trail_len: usize,
    var: VarId,
    /// value tried on the left branch
    value: i64,
    /// whether the right branch (x ≥ value+1) has been taken
    right_done: bool,
    /// first-unfixed pointer to restore on backtrack
    saved_ptr: usize,
}

/// Search-layer scratch pooled in the [`SolveCtx`]: everything the two
/// search loops used to allocate per solve — the frame stack, the
/// brancher (activities, heap, position maps), 1UIP analysis buffers,
/// value-saving and leaf scratch, and a pool of recycled solution
/// vectors. Reset per solve with lengths for the model at hand;
/// capacity is never given back, so window re-solves on a reused
/// context stay allocation-free.
#[derive(Default)]
pub(crate) struct SearchScratch {
    /// 1UIP conflict-analysis buffers (see `learn::AnalyzeScratch`).
    analyze: AnalyzeScratch,
    /// VSIDS activities (learned search).
    act: VarActivity,
    /// Indexed max-heap over branch positions (learned search).
    heap: BranchHeap,
    /// Branch position → variable id.
    pos_var: Vec<u32>,
    /// Nested-row scratch `var_positions` is rebuilt from (rows are
    /// cleared, not dropped).
    pos_rows: Vec<Vec<u32>>,
    /// Flattened var → branch positions map.
    var_positions: Csr<u32>,
    /// Solution-phase saved values per variable.
    saved: Vec<i64>,
    /// Candidate-leaf assignment scratch.
    leaf_buf: Vec<i64>,
    /// Activity-bump drain buffer.
    bumped: Vec<u32>,
    /// Chronological DFS frame stack.
    frames: Vec<Frame>,
    /// Recycled solution vectors: popped to hold the incumbent, handed
    /// out in `SearchResult::best`, returned by
    /// [`SolveCtx::recycle_solution`].
    sol_pool: Vec<Vec<i64>>,
}

impl SearchScratch {
    /// Return a solution vector to the pool (see
    /// [`SolveCtx::recycle_solution`]).
    pub(crate) fn recycle_solution(&mut self, mut v: Vec<i64>) {
        v.clear();
        self.sol_pool.push(v);
    }
}

impl Solver {
    /// Minimize `objective` (a linear expression, empty = satisfaction)
    /// over `model`, branching on `branch_order` (vars absent from the
    /// order must be fixed by propagation — all model vars is always a
    /// safe choice). `on_solution` fires for every *improving* solution.
    ///
    /// Dispatches on [`Solver::strategy`]; `naive` mode always runs the
    /// chronological reference (the naive engine exists to pin down the
    /// propagation semantics, not the search order).
    pub fn solve(
        &self,
        model: &Model,
        objective: &[(i64, VarId)],
        branch_order: &[VarId],
        on_solution: impl FnMut(&[i64], i64),
    ) -> SearchResult {
        let mut ctx = SolveCtx::default();
        self.solve_with_ctx(model, objective, branch_order, on_solution, &mut ctx)
    }

    /// [`Solver::solve`] on a reusable [`SolveCtx`]: the engine and
    /// search layers steal every scratch buffer from `ctx` and hand
    /// them back (capacity intact) before returning, so repeat solves —
    /// LNS window re-solves above all — stop paying per-solve
    /// allocation. Behavior-identical to a fresh-context solve
    /// (asserted by `prop_solve_ctx_reuse_matches_fresh`).
    pub fn solve_with_ctx(
        &self,
        model: &Model,
        objective: &[(i64, VarId)],
        branch_order: &[VarId],
        on_solution: impl FnMut(&[i64], i64),
        ctx: &mut SolveCtx,
    ) -> SearchResult {
        if self.strategy.mode == SearchMode::Learned && !self.naive {
            self.solve_learned(model, objective, branch_order, on_solution, ctx)
        } else {
            self.solve_chronological(model, objective, branch_order, on_solution, ctx)
        }
    }

    /// Chronological DFS branch & bound (see module docs).
    fn solve_chronological(
        &self,
        model: &Model,
        objective: &[(i64, VarId)],
        branch_order: &[VarId],
        mut on_solution: impl FnMut(&[i64], i64),
        ctx: &mut SolveCtx,
    ) -> SearchResult {
        let mut eng =
            PropagationEngine::new(model, objective, self.naive, false, &self.strategy, ctx);
        // watchdog channel: fixpoint publishes heartbeats into the
        // deadline's incumbent and aborts on cancellation / hard stop,
        // so even a single long propagation pass stays cancellable
        eng.set_watchdog(self.deadline.incumbent().cloned(), self.deadline.hard_stop());
        let mut scratch = mem::take(&mut ctx.search);
        scratch.frames.clear();
        scratch.leaf_buf.clear();
        // incumbent storage off the solution pool (handed out in the
        // result; the context caller recycles it)
        let mut best_vec = scratch.sol_pool.pop().unwrap_or_default();
        best_vec.clear();
        let mut best_obj: Option<i64> = None;

        // single exit: `break 'run` funnels every terminal path through
        // the recycle below, so the context always gets its buffers back
        let status = 'run: {
            // seed the objective bound from the shared pruning bound
            // when one is attached (any solver may prune against the
            // best solution found anywhere)
            if !objective.is_empty() {
                if let Some(g) = self.bound.as_ref().and_then(|i| i.best()) {
                    eng.tighten_obj_bound(g as i64 - 1);
                }
            }

            // root propagation
            eng.enqueue_all();
            if eng.fixpoint(model).is_err() {
                break 'run Status::Infeasible;
            }
            if eng.aborted {
                break 'run Status::Unknown;
            }

            let nvars = eng.doms.len();
            // Trailed first-unfixed pointer into `branch_order`: entries
            // before it are fixed or permanently guard-disabled on the
            // current path (both conditions are monotone between
            // backtracks), so selection never rescans them. Frames save
            // the pointer; backtracking restores it.
            let mut ptr: usize = 0;
            let mut limit_hit = false;
            // Loop-iteration counter driving the deadline/cancellation
            // and shared-bound polls. Counting iterations — not nodes —
            // matters: solution-leaf and backtrack iterations leave
            // `nodes` unchanged, so a node-count cadence could spin
            // through them without ever observing the deadline or a
            // portfolio cancellation.
            let mut iters: u64 = 0;

            'search: loop {
                iters += 1;
                // limits (the deadline poll also observes portfolio
                // cancellation; `aborted` is the engine's in-fixpoint
                // watchdog having tripped on the previous iteration)
                if eng.stats.nodes >= self.node_limit
                    || eng.aborted
                    || (iters % 128 == 0 && self.deadline.exceeded())
                {
                    limit_hit = true;
                    break 'search;
                }
                // portfolio pruning: tighten the bound to the best
                // duration published by any cooperating solver
                if iters % 128 == 0 && !objective.is_empty() {
                    if let Some(g) = self.bound.as_ref().and_then(|i| i.best()) {
                        eng.tighten_obj_bound(g as i64 - 1);
                    }
                }

                // advance the pointer past fixed / guard-disabled vars
                while ptr < branch_order.len() {
                    let v = branch_order[ptr];
                    if eng.doms.is_fixed(v) {
                        ptr += 1;
                        continue;
                    }
                    if let Some(gs) = &self.guards {
                        if let Some(Some(g)) = gs.get(ptr) {
                            if eng.doms.is_fixed(*g) && eng.doms.min(*g) == 0 {
                                ptr += 1;
                                continue;
                            }
                        }
                    }
                    break;
                }

                if ptr >= branch_order.len() {
                    // all branch vars fixed → candidate solution (any
                    // remaining model vars must be fixed by propagation;
                    // if not, take their minimum — sound because we
                    // verify below).
                    scratch.leaf_buf.clear();
                    scratch
                        .leaf_buf
                        .extend((0..nvars as u32).map(|i| eng.doms.min(VarId(i))));
                    if model.check(&scratch.leaf_buf).is_none() {
                        let obj_val: i64 = objective
                            .iter()
                            .map(|&(c, v)| c * scratch.leaf_buf[v.0 as usize])
                            .sum();
                        if best_obj.map(|b| obj_val < b).unwrap_or(true) {
                            eng.stats.solutions += 1;
                            on_solution(&scratch.leaf_buf, obj_val);
                            best_vec.clear();
                            best_vec.extend_from_slice(&scratch.leaf_buf);
                            best_obj = Some(obj_val);
                            eng.tighten_obj_bound(obj_val - 1);
                            if self.first_solution || objective.is_empty() {
                                break 'search;
                            }
                        }
                    } else {
                        // propagation left an unverifiable relaxed
                        // point; treat as conflict
                        eng.stats.conflicts += 1;
                    }
                    // backtrack to continue the search
                    if !backtrack(model, &mut eng, &mut scratch.frames, &mut ptr) {
                        break 'search;
                    }
                } else {
                    let x = branch_order[ptr];
                    eng.stats.nodes += 1;
                    let v = eng.doms.min(x);
                    scratch.frames.push(Frame {
                        trail_len: eng.trail.len(),
                        var: x,
                        value: v,
                        right_done: false,
                        saved_ptr: ptr,
                    });
                    // left branch: x = v
                    if eng.decide_eq(model, x, v).is_err() {
                        eng.stats.conflicts += 1;
                        if !backtrack(model, &mut eng, &mut scratch.frames, &mut ptr) {
                            break 'search;
                        }
                    }
                }
            }

            let status = match (best_obj.is_some(), limit_hit) {
                (true, false) => Status::Optimal,
                (true, true) => Status::Feasible,
                (false, false) => Status::Infeasible,
                (false, true) => Status::Unknown,
            };
            // first_solution mode exits the loop without exhausting:
            // report Feasible, not Optimal (unless infeasible/unknown).
            if self.first_solution && best_obj.is_some() {
                Status::Feasible
            } else if !limit_hit && objective.is_empty() && best_obj.is_some() {
                Status::Feasible // satisfaction problem: "a" solution
            } else {
                status
            }
        };

        let best = match best_obj {
            Some(o) => Some((mem::take(&mut best_vec), o)),
            None => {
                scratch.sol_pool.push(best_vec);
                None
            }
        };
        ctx.search = scratch;
        let stats = eng.stats;
        eng.recycle(ctx);
        SearchResult { status, best, stats }
    }

    /// Conflict-driven search (see `cp::learn`): explained propagation
    /// feeds 1UIP analysis; learned bound-predicate no-goods backjump
    /// and prune; branching follows conflict activity with
    /// solution-phase value saving; Luby restarts keep learned state.
    ///
    /// Decisions are single bound literals: with no saved phase the
    /// decision `x ≤ min(x)` fixes the variable exactly like the
    /// chronological left branch, and its learned negation `x ≥ min+1`
    /// is the chronological right branch — so with learning off this
    /// search degenerates to a remembered version of the same tree.
    /// Exhausted leaves that produce no propagation conflict
    /// (unverifiable or non-improving relaxed points) learn their
    /// *decision no-good* instead, which is exactly the chronological
    /// backtrack, remembered.
    fn solve_learned(
        &self,
        model: &Model,
        objective: &[(i64, VarId)],
        branch_order: &[VarId],
        mut on_solution: impl FnMut(&[i64], i64),
        ctx: &mut SolveCtx,
    ) -> SearchResult {
        let mut eng =
            PropagationEngine::new(model, objective, false, true, &self.strategy, ctx);
        eng.set_watchdog(self.deadline.incumbent().cloned(), self.deadline.hard_stop());
        let nvars = eng.doms.len();
        let mut scratch = mem::take(&mut ctx.search);
        let mut best_vec = scratch.sol_pool.pop().unwrap_or_default();
        best_vec.clear();
        let mut best_obj: Option<i64> = None;

        // single exit: `break 'run` funnels every terminal path through
        // the recycle below, so the context always gets its buffers back
        let status = 'run: {
            if !objective.is_empty() {
                if let Some(g) = self.bound.as_ref().and_then(|i| i.best()) {
                    eng.tighten_obj_bound(g as i64 - 1);
                }
            }
            eng.enqueue_all();
            if eng.fixpoint(model).is_err() {
                break 'run Status::Infeasible;
            }
            if eng.aborted {
                break 'run Status::Unknown;
            }

            // Brancher state: an indexed max-heap over branch positions
            // keyed by variable activity, plus the var → positions map
            // that re-queues a position whenever its variable (or
            // guard) has a trail entry undone. Invariant: the heap
            // always contains every unfixed, guard-enabled position — a
            // popped position is either used (and re-inserted while
            // unfixed), or dropped because it is fixed/disabled, in
            // which case the trail entry that fixed or disabled it
            // re-inserts it on undo.
            let npos = branch_order.len();
            scratch.pos_var.clear();
            scratch.pos_var.extend(branch_order.iter().map(|v| v.0));
            for r in scratch.pos_rows.iter_mut() {
                r.clear();
            }
            if scratch.pos_rows.len() < nvars {
                scratch.pos_rows.resize_with(nvars, Vec::new);
            }
            for (p, v) in branch_order.iter().enumerate() {
                scratch.pos_rows[v.0 as usize].push(p as u32);
            }
            if let Some(gs) = &self.guards {
                for (p, g) in gs.iter().enumerate() {
                    if let Some(g) = g {
                        scratch.pos_rows[g.0 as usize].push(p as u32);
                    }
                }
            }
            // flattened var → branch positions map: walked on every
            // undo and every activity bump, so it gets the CSR
            // treatment too (rebuilt in place, rows kept for next time)
            scratch.var_positions.rebuild_from_rows(&scratch.pos_rows[..nvars]);
            scratch.act.reset(nvars);
            scratch.heap.reset(npos);
            for p in 0..npos as u32 {
                scratch.heap.insert(p, &scratch.act, &scratch.pos_var);
            }
            // Solution-phase saving: branch toward the incumbent's
            // value once one exists (i64::MIN = no saved phase).
            scratch.saved.clear();
            scratch.saved.resize(nvars, i64::MIN);
            scratch.leaf_buf.clear();
            scratch.bumped.clear();

            let mut limit_hit = false;
            let mut iters: u64 = 0;
            let mut restart_idx: u64 = 1;
            let mut conflicts_since_restart: u64 = 0;

            'search: loop {
                iters += 1;
                if eng.stats.nodes >= self.node_limit
                    || eng.aborted
                    || (iters % 128 == 0 && self.deadline.exceeded())
                {
                    limit_hit = true;
                    break 'search;
                }
                if iters % 128 == 0 && !objective.is_empty() {
                    if let Some(g) = self.bound.as_ref().and_then(|i| i.best()) {
                        eng.tighten_obj_bound(g as i64 - 1);
                    }
                }
                // Luby restart: back to the root with no-goods and
                // activities kept; the database is reduced here (and
                // only here) so no trail entry can reference a
                // renumbered id.
                if self.strategy.restart_base > 0
                    && conflicts_since_restart
                        >= self.strategy.restart_base * luby(restart_idx)
                {
                    restart_idx += 1;
                    conflicts_since_restart = 0;
                    eng.stats.restarts += 1;
                    requeue_undone(
                        &mut eng,
                        0,
                        &mut scratch.heap,
                        &scratch.act,
                        &scratch.pos_var,
                        &scratch.var_positions,
                    );
                    if self.strategy.nogood_cap > 0
                        && eng.ng.len() > self.strategy.nogood_cap
                    {
                        crate::fail_point!("search.nogood_reduce");
                        eng.ng.reduce();
                        eng.stats.db_reductions += 1;
                    }
                    if eng.fixpoint(model).is_err() {
                        break 'search; // tightened bound closed the root
                    }
                    continue 'search;
                }

                // variable selection: highest-activity unfixed position
                let mut chosen: Option<(u32, VarId)> = None;
                while let Some(p) = scratch.heap.pop(&scratch.act, &scratch.pos_var) {
                    let x = branch_order[p as usize];
                    if eng.doms.is_fixed(x) {
                        continue;
                    }
                    if let Some(gs) = &self.guards {
                        if let Some(Some(g)) = gs.get(p as usize) {
                            if eng.doms.is_fixed(*g) && eng.doms.min(*g) == 0 {
                                continue;
                            }
                        }
                    }
                    chosen = Some((p, x));
                    break;
                }

                let conflict = if let Some((p, x)) = chosen {
                    // value selection: saved phase when available, else
                    // min
                    let (mn, mx) = (eng.doms.min(x), eng.doms.max(x));
                    let w = scratch.saved[x.0 as usize];
                    let lit = if w == i64::MIN || w <= mn {
                        Lit::leq(x, mn) // fix at min (chronological left branch)
                    } else if w >= mx {
                        Lit::geq(x, mx) // fix at max
                    } else {
                        Lit::geq(x, w) // aim at the incumbent's value
                    };
                    eng.stats.nodes += 1;
                    let r = eng.decide_lit(model, lit);
                    if r.is_ok() && !eng.doms.is_fixed(x) {
                        // half-decision (aimed at a phase): the
                        // variable stays branchable
                        scratch.heap.insert(p, &scratch.act, &scratch.pos_var);
                    }
                    r.is_err()
                } else {
                    // leaf: every branch var fixed or guard-disabled →
                    // candidate solution (min-completion, verified
                    // below)
                    scratch.leaf_buf.clear();
                    scratch
                        .leaf_buf
                        .extend((0..nvars as u32).map(|i| eng.doms.min(VarId(i))));
                    let mut surfaced = false;
                    if model.check(&scratch.leaf_buf).is_none() {
                        let obj_val: i64 = objective
                            .iter()
                            .map(|&(c, v)| c * scratch.leaf_buf[v.0 as usize])
                            .sum();
                        if best_obj.map(|b| obj_val < b).unwrap_or(true) {
                            eng.stats.solutions += 1;
                            on_solution(&scratch.leaf_buf, obj_val);
                            scratch.saved.copy_from_slice(&scratch.leaf_buf);
                            best_vec.clear();
                            best_vec.extend_from_slice(&scratch.leaf_buf);
                            best_obj = Some(obj_val);
                            if self.first_solution || objective.is_empty() {
                                break 'search;
                            }
                            // the trail now violates the tightened
                            // bound; propagating surfaces a conflict
                            // whose analysis backjumps — often far,
                            // since the explanation only involves
                            // objective terms
                            eng.tighten_obj_bound(obj_val - 1);
                            surfaced = eng.fixpoint(model).is_err();
                        }
                    } else {
                        // unverifiable relaxed point (chronological
                        // search treats these as dead ends too)
                        eng.stats.conflicts += 1;
                    }
                    if surfaced {
                        true
                    } else {
                        // no propagation conflict to analyze: learn the
                        // decision no-good (the remembered
                        // chronological backtrack) and continue
                        let lvl = eng.current_level();
                        if lvl == 0 {
                            break 'search; // root leaf: space exhausted
                        }
                        let mut lits: Vec<Lit> = Vec::with_capacity(lvl);
                        lits.push(eng.expl.lit[eng.level_marks[lvl - 1] as usize]);
                        for i in 0..lvl - 1 {
                            lits.push(eng.expl.lit[eng.level_marks[i] as usize]);
                        }
                        match apply_learned(
                            model,
                            &mut eng,
                            lits,
                            lvl - 1,
                            &mut scratch.heap,
                            &scratch.act,
                            &scratch.pos_var,
                            &scratch.var_positions,
                        ) {
                            Ok(()) => false,
                            Err(_) => true,
                        }
                    }
                };

                if conflict {
                    // analyze → learn → backjump → propagate; repeat
                    // while the propagation after the backjump keeps
                    // failing
                    loop {
                        eng.stats.conflicts += 1;
                        conflicts_since_restart += 1;
                        scratch.act.decay();
                        eng.ng.decay();
                        let confl = mem::take(&mut eng.expl.conflict);
                        let analyzed =
                            analyze(&eng, &confl, &mut scratch.act, &mut scratch.analyze);
                        eng.expl.conflict = confl; // hand the buffer back
                        for &g in &scratch.analyze.ng_bumps {
                            eng.ng.bump(g);
                        }
                        scratch.act.swap_bumped(&mut scratch.bumped);
                        for &v in &scratch.bumped {
                            for &p in scratch.var_positions.row(v as usize) {
                                scratch.heap.resift(p, &scratch.act, &scratch.pos_var);
                            }
                        }
                        match analyzed {
                            Analyzed::Root => break 'search,
                            Analyzed::NoGood { lits, level } => {
                                let r = apply_learned(
                                    model,
                                    &mut eng,
                                    lits,
                                    level,
                                    &mut scratch.heap,
                                    &scratch.act,
                                    &scratch.pos_var,
                                    &scratch.var_positions,
                                );
                                if r.is_ok() {
                                    break; // fixpoint reached: resume search
                                }
                            }
                        }
                    }
                }
            }

            let status = match (best_obj.is_some(), limit_hit) {
                (true, false) => Status::Optimal,
                (true, true) => Status::Feasible,
                (false, false) => Status::Infeasible,
                (false, true) => Status::Unknown,
            };
            if self.first_solution && best_obj.is_some() {
                Status::Feasible
            } else if !limit_hit && objective.is_empty() && best_obj.is_some() {
                Status::Feasible // satisfaction problem: "a" solution
            } else {
                status
            }
        };

        let best = match best_obj {
            Some(o) => Some((mem::take(&mut best_vec), o)),
            None => {
                scratch.sol_pool.push(best_vec);
                None
            }
        };
        ctx.search = scratch;
        let stats = eng.stats;
        eng.recycle(ctx);
        SearchResult { status, best, stats }
    }
}

/// Re-queue the branch positions of every variable with a trail entry
/// above the backjump target, then backjump. Inserting before the undo
/// is fine — the heap only tracks *candidacy*; fixedness is re-checked
/// at selection time.
fn requeue_undone(
    eng: &mut PropagationEngine,
    level: usize,
    heap: &mut BranchHeap,
    act: &VarActivity,
    pos_var: &[u32],
    var_positions: &Csr<u32>,
) {
    if level >= eng.current_level() {
        return;
    }
    let mark = eng.level_marks[level] as usize;
    for e in &eng.trail[mark..] {
        for &p in var_positions.row(e.var as usize) {
            heap.insert(p, act, pos_var);
        }
    }
    eng.backjump_to(level);
}

/// Backjump to `level`, store the learned no-good (size-1 no-goods are
/// asserted as root facts instead), and propagate to fixpoint. An `Err`
/// means the propagation conflicted again — the caller analyzes the new
/// conflict.
#[allow(clippy::too_many_arguments)]
fn apply_learned(
    model: &Model,
    eng: &mut PropagationEngine,
    lits: Vec<Lit>,
    level: usize,
    heap: &mut BranchHeap,
    act: &VarActivity,
    pos_var: &[u32],
    var_positions: &Csr<u32>,
) -> Result<(), super::propagators::Conflict> {
    requeue_undone(eng, level, heap, act, pos_var, var_positions);
    eng.stats.nogoods_learned += 1;
    if lits.len() == 1 {
        eng.assert_root(model, lits[0].negation())
    } else {
        eng.ng.add(lits);
        eng.fixpoint(model)
    }
}

/// Undo frames until a right branch can be taken; apply it and
/// re-propagate (the engine re-enqueues only watchers of undone
/// variables plus the objective). Returns false when the root is
/// exhausted.
fn backtrack(
    model: &Model,
    eng: &mut PropagationEngine,
    frames: &mut Vec<Frame>,
    ptr: &mut usize,
) -> bool {
    loop {
        // peek instead of pop/push: the frame stays on the stack while
        // its right branch is tried, so there is no "re-pop" that could
        // ever see an empty stack (the empty case is exactly root
        // exhaustion, reported as `false` — never a panic)
        let Some(f) = frames.last_mut() else {
            return false;
        };
        eng.undo_to(f.trail_len);
        *ptr = f.saved_ptr;
        if f.right_done {
            frames.pop(); // both branches exhausted here; keep unwinding
            continue;
        }
        // right branch: x >= value + 1
        f.right_done = true;
        let (x, v) = (f.var, f.value);
        if eng.decide_ge(model, x, v + 1).is_ok() {
            return true;
        }
        eng.stats.conflicts += 1;
        // right branch failed too: the next iteration undoes its trail
        // (right_done is set), pops this frame and keeps unwinding
    }
}
