//! DFS branch-and-bound search over a [`Model`](super::Model).
//!
//! Chronological backtracking on top of the event-driven
//! `PropagationEngine` (see `engine.rs`): the engine owns the domains,
//! trail, two-tier queue and per-propagator incremental state; the
//! search layer owns the frame stack, a trailed first-unfixed branch
//! pointer over the caller-supplied branch order, min-value branching
//! (`x = min` on the left, `x ≥ min+1` on the right), and minimization
//! via the engine's persistent objective propagator whose rhs tightens
//! in place after every improving solution. Every emitted solution is
//! verified against all constraints before it is reported — filtering
//! bugs can cost time but never correctness.

use super::domain::VarId;
use super::engine::PropagationEngine;
use super::Model;
use crate::util::{Deadline, Incumbent};
use std::sync::Arc;

/// Terminal status of a search.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Status {
    /// Search space exhausted with at least one solution: the incumbent
    /// is optimal.
    Optimal,
    /// Limit hit with at least one solution.
    Feasible,
    /// Search space exhausted with no solution.
    Infeasible,
    /// Limit hit with no solution.
    Unknown,
}

/// Search statistics, including the propagation engine's event/queue
/// counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SearchStats {
    /// Branch decisions taken.
    pub nodes: u64,
    /// Dead ends (failed propagations / unverifiable leaves).
    pub conflicts: u64,
    /// Improving solutions emitted.
    pub solutions: u64,
    /// Propagator invocations.
    pub propagations: u64,
    /// Typed domain events posted (bound changes).
    pub events_posted: u64,
    /// Wakeups suppressed because the event kind did not match the
    /// propagator's watch mask (event filtering at work).
    pub wakeups_skipped: u64,
    /// Cumulative compulsory-part re-synchronisations (incremental
    /// forward updates plus backtrack undo).
    pub cum_resyncs: u64,
    /// Cumulative profile flattenings (each replaces what used to be a
    /// from-scratch rebuild per invocation).
    pub cum_rebuilds: u64,
    /// Root-presolve counters folded in at model-build time (see
    /// [`crate::presolve::PresolveStats`]), accumulated like every
    /// other counter — an LNS run adds one contribution per window
    /// re-solve.
    pub presolve: crate::presolve::PresolveStats,
}

impl SearchStats {
    /// Accumulate another run's counters into this one (used to
    /// aggregate across LNS window re-solves and portfolio members).
    pub fn merge(&mut self, o: &SearchStats) {
        self.nodes += o.nodes;
        self.conflicts += o.conflicts;
        self.solutions += o.solutions;
        self.propagations += o.propagations;
        self.events_posted += o.events_posted;
        self.wakeups_skipped += o.wakeups_skipped;
        self.cum_resyncs += o.cum_resyncs;
        self.cum_rebuilds += o.cum_rebuilds;
        self.presolve.add(&o.presolve);
    }
}

/// Result of a search: status, best assignment + objective, stats.
pub struct SearchResult {
    /// Terminal status (optimal / feasible / infeasible / unknown).
    pub status: Status,
    /// Best assignment found and its objective value, if any.
    pub best: Option<(Vec<i64>, i64)>,
    /// Search statistics.
    pub stats: SearchStats,
}

impl SearchResult {
    /// Whether at least one solution was found.
    pub fn found(&self) -> bool {
        self.best.is_some()
    }
}

/// Solver configuration.
pub struct Solver {
    /// Wall-clock limit; when it carries a shared [`Incumbent`], the
    /// search observes portfolio cancellation on every limit poll.
    pub deadline: Deadline,
    /// Optional shared pruning bound: the objective bound is seeded
    /// from (and periodically tightened to) the best duration published
    /// here by any cooperating solver. Kept separate from `deadline`'s
    /// cancellation channel on purpose: full-model solves (exact,
    /// CHECKMATE) want global pruning, while LNS window re-solves must
    /// prune only against their *local* incumbent or a member behind
    /// the global best could never make incremental progress.
    pub bound: Option<Arc<Incumbent>>,
    /// Hard cap on branch decisions.
    pub node_limit: u64,
    /// Stop as soon as the first solution is found (Phase-1 style).
    pub first_solution: bool,
    /// Optional branch guards, parallel to `branch_order`: if
    /// `guards[i]` is fixed to 0, branch var `i` is skipped (used for
    /// start/end vars of inactive optional intervals).
    pub guards: Option<Vec<Option<VarId>>>,
    /// Use the naive reference propagation semantics (wake every
    /// watcher on any event, single queue, from-scratch `Cumulative`,
    /// re-enqueue everything on backtrack) instead of the event-driven
    /// engine. Exists for equivalence testing; both modes explore the
    /// same tree because bounds propagation is confluent.
    pub naive: bool,
}

impl Default for Solver {
    fn default() -> Self {
        Solver {
            deadline: Deadline::unlimited(),
            bound: None,
            node_limit: u64::MAX,
            first_solution: false,
            guards: None,
            naive: false,
        }
    }
}

struct Frame {
    trail_len: usize,
    var: VarId,
    /// value tried on the left branch
    value: i64,
    /// whether the right branch (x ≥ value+1) has been taken
    right_done: bool,
    /// first-unfixed pointer to restore on backtrack
    saved_ptr: usize,
}

impl Solver {
    /// Minimize `objective` (a linear expression, empty = satisfaction)
    /// over `model`, branching on `branch_order` (vars absent from the
    /// order must be fixed by propagation — all model vars is always a
    /// safe choice). `on_solution` fires for every *improving* solution.
    pub fn solve(
        &self,
        model: &Model,
        objective: &[(i64, VarId)],
        branch_order: &[VarId],
        mut on_solution: impl FnMut(&[i64], i64),
    ) -> SearchResult {
        let mut eng = PropagationEngine::new(model, objective, self.naive);
        let mut best: Option<(Vec<i64>, i64)> = None;
        // seed the objective bound from the shared pruning bound when
        // one is attached (any solver may prune against the best
        // solution found anywhere)
        if !objective.is_empty() {
            if let Some(g) = self.bound.as_ref().and_then(|i| i.best()) {
                eng.tighten_obj_bound(g as i64 - 1);
            }
        }

        // root propagation
        eng.enqueue_all();
        if eng.fixpoint(model).is_err() {
            return SearchResult { status: Status::Infeasible, best: None, stats: eng.stats };
        }

        let mut frames: Vec<Frame> = Vec::new();
        // Trailed first-unfixed pointer into `branch_order`: entries
        // before it are fixed or permanently guard-disabled on the
        // current path (both conditions are monotone between
        // backtracks), so selection never rescans them. Frames save the
        // pointer; backtracking restores it.
        let mut ptr: usize = 0;
        let mut limit_hit = false;

        'search: loop {
            // limits (the deadline poll also observes portfolio
            // cancellation)
            if eng.stats.nodes >= self.node_limit
                || (eng.stats.nodes % 128 == 0 && self.deadline.exceeded())
            {
                limit_hit = true;
                break 'search;
            }
            // portfolio pruning: tighten the bound to the best duration
            // published by any cooperating solver
            if eng.stats.nodes % 128 == 0 && !objective.is_empty() {
                if let Some(g) = self.bound.as_ref().and_then(|i| i.best()) {
                    eng.tighten_obj_bound(g as i64 - 1);
                }
            }

            // advance the pointer past fixed / guard-disabled vars
            while ptr < branch_order.len() {
                let v = branch_order[ptr];
                if eng.domains[v.0 as usize].is_fixed() {
                    ptr += 1;
                    continue;
                }
                if let Some(gs) = &self.guards {
                    if let Some(Some(g)) = gs.get(ptr) {
                        let gd = &eng.domains[g.0 as usize];
                        if gd.is_fixed() && gd.min() == 0 {
                            ptr += 1;
                            continue;
                        }
                    }
                }
                break;
            }

            if ptr >= branch_order.len() {
                // all branch vars fixed → candidate solution (any
                // remaining model vars must be fixed by propagation;
                // if not, take their minimum — sound because we
                // verify below).
                let assignment: Vec<i64> = eng.domains.iter().map(|d| d.min()).collect();
                if model.check(&assignment).is_none() {
                    let obj_val: i64 =
                        objective.iter().map(|&(c, v)| c * assignment[v.0 as usize]).sum();
                    if best.as_ref().map(|&(_, b)| obj_val < b).unwrap_or(true) {
                        eng.stats.solutions += 1;
                        on_solution(&assignment, obj_val);
                        best = Some((assignment, obj_val));
                        eng.tighten_obj_bound(obj_val - 1);
                        if self.first_solution || objective.is_empty() {
                            break 'search;
                        }
                    }
                } else {
                    // propagation left an unverifiable relaxed point;
                    // treat as conflict
                    eng.stats.conflicts += 1;
                }
                // backtrack to continue the search
                if !backtrack(model, &mut eng, &mut frames, &mut ptr) {
                    break 'search;
                }
            } else {
                let x = branch_order[ptr];
                eng.stats.nodes += 1;
                let v = eng.domains[x.0 as usize].min();
                frames.push(Frame {
                    trail_len: eng.trail.len(),
                    var: x,
                    value: v,
                    right_done: false,
                    saved_ptr: ptr,
                });
                // left branch: x = v
                if eng.decide_eq(model, x, v).is_err() {
                    eng.stats.conflicts += 1;
                    if !backtrack(model, &mut eng, &mut frames, &mut ptr) {
                        break 'search;
                    }
                }
            }
        }

        let status = match (&best, limit_hit) {
            (Some(_), false) => Status::Optimal,
            (Some(_), true) => Status::Feasible,
            (None, false) => Status::Infeasible,
            (None, true) => Status::Unknown,
        };
        // first_solution mode exits the loop without exhausting: report
        // Feasible, not Optimal (unless infeasible/unknown).
        let status = if self.first_solution && best.is_some() {
            Status::Feasible
        } else if !limit_hit && objective.is_empty() && best.is_some() {
            Status::Feasible // satisfaction problem: "a" solution
        } else {
            status
        };
        SearchResult { status, best, stats: eng.stats }
    }
}

/// Undo frames until a right branch can be taken; apply it and
/// re-propagate (the engine re-enqueues only watchers of undone
/// variables plus the objective). Returns false when the root is
/// exhausted.
fn backtrack(
    model: &Model,
    eng: &mut PropagationEngine,
    frames: &mut Vec<Frame>,
    ptr: &mut usize,
) -> bool {
    loop {
        let Some(mut f) = frames.pop() else {
            return false;
        };
        eng.undo_to(model, f.trail_len);
        *ptr = f.saved_ptr;
        if f.right_done {
            continue; // both branches exhausted here; keep unwinding
        }
        // right branch: x >= value + 1
        f.right_done = true;
        let x = f.var;
        let v = f.value;
        frames.push(f);
        if eng.decide_ge(model, x, v + 1).is_ok() {
            return true;
        }
        eng.stats.conflicts += 1;
        // right branch failed too: unwind further
        let f = frames.pop().unwrap();
        eng.undo_to(model, f.trail_len);
        *ptr = f.saved_ptr;
    }
}
