//! DFS branch-and-bound search over a [`Model`](super::Model).
//!
//! Chronological backtracking with a `(var, old_lo, old_hi)` trail;
//! first-unfixed variable selection over a caller-supplied branch order;
//! min-value branching (`x = min` on the left, `x ≥ min+1` on the right).
//! Minimization via an incumbent bound propagated as an implicit
//! `LinearLe` whose rhs tightens in place after every improving solution.
//! Every emitted solution is verified against all constraints before it
//! is reported — filtering bugs can cost time but never correctness.

use super::domain::{Domain, VarId};
use super::propagators::{Conflict, Ctx, Propagator};
use super::Model;
use crate::util::{Deadline, Incumbent};
use std::sync::Arc;

/// Terminal status of a search.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Status {
    /// Search space exhausted with at least one solution: the incumbent
    /// is optimal.
    Optimal,
    /// Limit hit with at least one solution.
    Feasible,
    /// Search space exhausted with no solution.
    Infeasible,
    /// Limit hit with no solution.
    Unknown,
}

/// Search statistics.
#[derive(Debug, Clone, Copy, Default)]
pub struct SearchStats {
    /// Branch decisions taken.
    pub nodes: u64,
    /// Dead ends (failed propagations / unverifiable leaves).
    pub conflicts: u64,
    /// Improving solutions emitted.
    pub solutions: u64,
    /// Propagator invocations.
    pub propagations: u64,
}

/// Result of a search: status, best assignment + objective, stats.
pub struct SearchResult {
    /// Terminal status (optimal / feasible / infeasible / unknown).
    pub status: Status,
    /// Best assignment found and its objective value, if any.
    pub best: Option<(Vec<i64>, i64)>,
    /// Search statistics.
    pub stats: SearchStats,
}

impl SearchResult {
    /// Whether at least one solution was found.
    pub fn found(&self) -> bool {
        self.best.is_some()
    }
}

/// Solver configuration.
pub struct Solver {
    /// Wall-clock limit; when it carries a shared [`Incumbent`], the
    /// search observes portfolio cancellation on every limit poll.
    pub deadline: Deadline,
    /// Optional shared pruning bound: the objective bound is seeded
    /// from (and periodically tightened to) the best duration published
    /// here by any cooperating solver. Kept separate from `deadline`'s
    /// cancellation channel on purpose: full-model solves (exact,
    /// CHECKMATE) want global pruning, while LNS window re-solves must
    /// prune only against their *local* incumbent or a member behind
    /// the global best could never make incremental progress.
    pub bound: Option<Arc<Incumbent>>,
    /// Hard cap on branch decisions.
    pub node_limit: u64,
    /// Stop as soon as the first solution is found (Phase-1 style).
    pub first_solution: bool,
    /// Optional branch guards, parallel to `branch_order`: if
    /// `guards[i]` is fixed to 0, branch var `i` is skipped (used for
    /// start/end vars of inactive optional intervals).
    pub guards: Option<Vec<Option<VarId>>>,
}

impl Default for Solver {
    fn default() -> Self {
        Solver {
            deadline: Deadline::unlimited(),
            bound: None,
            node_limit: u64::MAX,
            first_solution: false,
            guards: None,
        }
    }
}

struct Frame {
    trail_len: usize,
    var: VarId,
    /// value tried on the left branch
    value: i64,
    /// whether the right branch (x ≥ value+1) has been taken
    right_done: bool,
}

impl Solver {
    /// Minimize `objective` (a linear expression, empty = satisfaction)
    /// over `model`, branching on `branch_order` (vars absent from the
    /// order must be fixed by propagation — all model vars is always a
    /// safe choice). `on_solution` fires for every *improving* solution.
    pub fn solve(
        &self,
        model: &Model,
        objective: &[(i64, VarId)],
        branch_order: &[VarId],
        mut on_solution: impl FnMut(&[i64], i64),
    ) -> SearchResult {
        let mut domains: Vec<Domain> = model.domains.clone();
        let mut trail: Vec<(u32, u32, u32)> = Vec::new();
        let mut stats = SearchStats::default();
        let mut best: Option<(Vec<i64>, i64)> = None;
        // incumbent bound as rhs of the implicit objective constraint;
        // seeded from the shared pruning bound when one is attached
        // (any solver may prune against the best solution found anywhere)
        let mut obj_bound: i64 = i64::MAX / 4;
        if !objective.is_empty() {
            if let Some(g) = self.bound.as_ref().and_then(|i| i.best()) {
                obj_bound = obj_bound.min(g as i64 - 1);
            }
        }

        // propagation queue state
        let nprops = model.props.len();
        let mut queue: Vec<u32> = Vec::with_capacity(nprops);
        let mut in_queue = vec![false; nprops + 1]; // +1 = objective pseudo-prop
        let obj_prop_id = nprops as u32;

        let objective_prop = if objective.is_empty() {
            None
        } else {
            Some(objective.to_vec())
        };

        // returns Err(Conflict) on failure
        #[allow(clippy::too_many_arguments)]
        fn propagate_fixpoint(
            model: &Model,
            domains: &mut Vec<Domain>,
            trail: &mut Vec<(u32, u32, u32)>,
            queue: &mut Vec<u32>,
            in_queue: &mut [bool],
            objective_prop: &Option<Vec<(i64, VarId)>>,
            obj_bound: i64,
            obj_prop_id: u32,
            stats: &mut SearchStats,
        ) -> Result<(), Conflict> {
            let mut changed: Vec<VarId> = Vec::new();
            while let Some(pid) = queue.pop() {
                in_queue[pid as usize] = false;
                stats.propagations += 1;
                changed.clear();
                let res = {
                    let mut ctx = Ctx { domains, trail, changed: &mut changed };
                    if pid == obj_prop_id {
                        // objective bound: Σ c x ≤ obj_bound
                        let terms = objective_prop.as_ref().unwrap();
                        let tmp = Propagator::LinearLe { terms: terms.clone(), rhs: obj_bound };
                        tmp.propagate(&mut ctx)
                    } else {
                        model.props[pid as usize].propagate(&mut ctx)
                    }
                };
                if res.is_err() {
                    if std::env::var("MOCCASIN_DEBUG_PROP").is_ok() {
                        let kind = if pid == obj_prop_id {
                            "objective".to_string()
                        } else {
                            match &model.props[pid as usize] {
                                Propagator::LinearLe { rhs, terms } => {
                                    format!("LinearLe(rhs={rhs},terms={})", terms.len())
                                }
                                Propagator::LeOffset { .. } => "LeOffset".into(),
                                Propagator::Cumulative { .. } => "Cumulative".into(),
                                Propagator::Cover { active, start, .. } => {
                                    format!("Cover(active={active:?},start={start:?})")
                                }
                                Propagator::AllDifferent { .. } => "AllDifferent".into(),
                            }
                        };
                        eprintln!("root conflict in {kind}");
                    }
                    queue.clear();
                    in_queue.iter_mut().for_each(|b| *b = false);
                    return Err(Conflict);
                }
                for &v in changed.iter() {
                    for &w in &model.watches[v.0 as usize] {
                        if !in_queue[w as usize] {
                            in_queue[w as usize] = true;
                            queue.push(w);
                        }
                    }
                    if objective_prop.is_some() && !in_queue[obj_prop_id as usize] {
                        in_queue[obj_prop_id as usize] = true;
                        queue.push(obj_prop_id);
                    }
                }
            }
            Ok(())
        }

        let enqueue_all = |queue: &mut Vec<u32>, in_queue: &mut [bool]| {
            queue.clear();
            for p in 0..nprops as u32 {
                queue.push(p);
                in_queue[p as usize] = true;
            }
            if objective_prop.is_some() {
                queue.push(obj_prop_id);
                in_queue[obj_prop_id as usize] = true;
            }
        };

        // root propagation
        enqueue_all(&mut queue, &mut in_queue);
        if propagate_fixpoint(
            model,
            &mut domains,
            &mut trail,
            &mut queue,
            &mut in_queue,
            &objective_prop,
            obj_bound,
            obj_prop_id,
            &mut stats,
        )
        .is_err()
        {
            return SearchResult { status: Status::Infeasible, best: None, stats };
        }

        let mut frames: Vec<Frame> = Vec::new();
        let mut limit_hit = false;

        'search: loop {
            // limits (the deadline poll also observes portfolio
            // cancellation)
            if stats.nodes >= self.node_limit
                || (stats.nodes % 128 == 0 && self.deadline.exceeded())
            {
                limit_hit = true;
                break 'search;
            }
            // portfolio pruning: tighten the bound to the best duration
            // published by any cooperating solver
            if stats.nodes % 128 == 0 && !objective.is_empty() {
                if let Some(g) = self.bound.as_ref().and_then(|i| i.best()) {
                    obj_bound = obj_bound.min(g as i64 - 1);
                }
            }

            // pick first unfixed branch var whose guard is not fixed 0
            let pick = branch_order
                .iter()
                .enumerate()
                .find(|&(i, v)| {
                    if domains[v.0 as usize].is_fixed() {
                        return false;
                    }
                    if let Some(gs) = &self.guards {
                        if let Some(Some(g)) = gs.get(i) {
                            let gd = &domains[g.0 as usize];
                            if gd.is_fixed() && gd.min() == 0 {
                                return false;
                            }
                        }
                    }
                    true
                })
                .map(|(_, &v)| v);

            match pick {
                None => {
                    // all branch vars fixed → candidate solution (any
                    // remaining model vars must be fixed by propagation;
                    // if not, take their minimum — sound because we
                    // verify below).
                    let assignment: Vec<i64> =
                        domains.iter().map(|d| d.min()).collect();
                    if model.check(&assignment).is_none() {
                        let obj_val: i64 =
                            objective.iter().map(|&(c, v)| c * assignment[v.0 as usize]).sum();
                        if best.as_ref().map(|&(_, b)| obj_val < b).unwrap_or(true) {
                            stats.solutions += 1;
                            on_solution(&assignment, obj_val);
                            best = Some((assignment, obj_val));
                            obj_bound = obj_val - 1;
                            if self.first_solution || objective.is_empty() {
                                break 'search;
                            }
                        }
                    } else {
                        // propagation left an unverifiable relaxed point;
                        // treat as conflict
                        stats.conflicts += 1;
                    }
                    // backtrack to continue the search
                    if !backtrack(
                        model,
                        &mut frames,
                        &mut domains,
                        &mut trail,
                        &mut queue,
                        &mut in_queue,
                        &objective_prop,
                        obj_bound,
                        obj_prop_id,
                        &mut stats,
                    ) {
                        break 'search;
                    }
                }
                Some(x) => {
                    stats.nodes += 1;
                    let v = domains[x.0 as usize].min();
                    frames.push(Frame {
                        trail_len: trail.len(),
                        var: x,
                        value: v,
                        right_done: false,
                    });
                    // left branch: x = v
                    let ok = {
                        let mut changed = Vec::new();
                        let mut ctx =
                            Ctx { domains: &mut domains, trail: &mut trail, changed: &mut changed };
                        let r = ctx.fix_var(x, v).is_ok();
                        if r {
                            for &cv in changed.iter() {
                                for &w in &model.watches[cv.0 as usize] {
                                    if !in_queue[w as usize] {
                                        in_queue[w as usize] = true;
                                        queue.push(w);
                                    }
                                }
                                if objective_prop.is_some() && !in_queue[obj_prop_id as usize] {
                                    in_queue[obj_prop_id as usize] = true;
                                    queue.push(obj_prop_id);
                                }
                            }
                        }
                        r
                    } && propagate_fixpoint(
                        model,
                        &mut domains,
                        &mut trail,
                        &mut queue,
                        &mut in_queue,
                        &objective_prop,
                        obj_bound,
                        obj_prop_id,
                        &mut stats,
                    )
                    .is_ok();
                    if !ok {
                        stats.conflicts += 1;
                        if !backtrack(
                            model,
                            &mut frames,
                            &mut domains,
                            &mut trail,
                            &mut queue,
                            &mut in_queue,
                            &objective_prop,
                            obj_bound,
                            obj_prop_id,
                            &mut stats,
                        ) {
                            break 'search;
                        }
                    }
                }
            }
        }

        let status = match (&best, limit_hit) {
            (Some(_), false) => Status::Optimal,
            (Some(_), true) => Status::Feasible,
            (None, false) => Status::Infeasible,
            (None, true) => Status::Unknown,
        };
        // first_solution mode exits the loop without exhausting: report
        // Feasible, not Optimal (unless infeasible/unknown).
        let status = if self.first_solution && best.is_some() {
            Status::Feasible
        } else if !limit_hit && objective.is_empty() && best.is_some() {
            Status::Feasible // satisfaction problem: "a" solution
        } else {
            status
        };
        SearchResult { status, best, stats }
    }
}

/// Undo frames until a right branch can be taken; apply it and
/// re-propagate. Returns false when the root is exhausted.
#[allow(clippy::too_many_arguments)]
fn backtrack(
    model: &Model,
    frames: &mut Vec<Frame>,
    domains: &mut Vec<Domain>,
    trail: &mut Vec<(u32, u32, u32)>,
    queue: &mut Vec<u32>,
    in_queue: &mut [bool],
    objective_prop: &Option<Vec<(i64, VarId)>>,
    obj_bound: i64,
    obj_prop_id: u32,
    stats: &mut SearchStats,
) -> bool {
    loop {
        let Some(mut f) = frames.pop() else {
            return false;
        };
        // undo to the frame's trail mark
        while trail.len() > f.trail_len {
            let (var, lo, hi) = trail.pop().unwrap();
            domains[var as usize].restore((lo, hi));
        }
        if f.right_done {
            continue; // both branches exhausted here; keep unwinding
        }
        // right branch: x >= value + 1
        f.right_done = true;
        let x = f.var;
        let v = f.value;
        frames.push(f);
        let ok = {
            let mut changed = Vec::new();
            let mut ctx = Ctx { domains, trail, changed: &mut changed };
            let r = ctx.set_min(x, v + 1).is_ok();
            if r {
                for &cv in changed.iter() {
                    for &w in &model.watches[cv.0 as usize] {
                        if !in_queue[w as usize] {
                            in_queue[w as usize] = true;
                            queue.push(w);
                        }
                    }
                    if objective_prop.is_some() && !in_queue[obj_prop_id as usize] {
                        in_queue[obj_prop_id as usize] = true;
                        queue.push(obj_prop_id);
                    }
                }
            }
            r
        };
        // also re-propagate with the (possibly tightened) objective bound
        let ok = ok
            && propagate_fixpoint_outer(
                model, domains, trail, queue, in_queue, objective_prop, obj_bound, obj_prop_id,
                stats,
            )
            .is_ok();
        if ok {
            return true;
        }
        stats.conflicts += 1;
        // right branch failed too: unwind further
        let f = frames.pop().unwrap();
        while trail.len() > f.trail_len {
            let (var, lo, hi) = trail.pop().unwrap();
            domains[var as usize].restore((lo, hi));
        }
    }
}

/// Fixpoint propagation (free function twin of the closure inside
/// `solve`, used by `backtrack`).
#[allow(clippy::too_many_arguments)]
fn propagate_fixpoint_outer(
    model: &Model,
    domains: &mut Vec<Domain>,
    trail: &mut Vec<(u32, u32, u32)>,
    queue: &mut Vec<u32>,
    in_queue: &mut [bool],
    objective_prop: &Option<Vec<(i64, VarId)>>,
    obj_bound: i64,
    obj_prop_id: u32,
    stats: &mut SearchStats,
) -> Result<(), Conflict> {
    // after a right branch, conservatively re-run everything (bound may
    // have tightened since this subtree was entered)
    queue.clear();
    for p in 0..model.props.len() as u32 {
        queue.push(p);
        in_queue[p as usize] = true;
    }
    if objective_prop.is_some() {
        queue.push(obj_prop_id);
        in_queue[obj_prop_id as usize] = true;
    }
    let mut changed: Vec<VarId> = Vec::new();
    while let Some(pid) = queue.pop() {
        in_queue[pid as usize] = false;
        stats.propagations += 1;
        changed.clear();
        let res = {
            let mut ctx = Ctx { domains, trail, changed: &mut changed };
            if pid == obj_prop_id {
                let terms = objective_prop.as_ref().unwrap();
                let tmp = Propagator::LinearLe { terms: terms.clone(), rhs: obj_bound };
                tmp.propagate(&mut ctx)
            } else {
                model.props[pid as usize].propagate(&mut ctx)
            }
        };
        if res.is_err() {
            queue.clear();
            in_queue.iter_mut().for_each(|b| *b = false);
            return Err(Conflict);
        }
        for &v in changed.iter() {
            for &w in &model.watches[v.0 as usize] {
                if !in_queue[w as usize] {
                    in_queue[w as usize] = true;
                    queue.push(w);
                }
            }
            if objective_prop.is_some() && !in_queue[obj_prop_id as usize] {
                in_queue[obj_prop_id as usize] = true;
                queue.push(obj_prop_id);
            }
        }
    }
    Ok(())
}
