//! A from-scratch constraint-programming engine.
//!
//! The paper solves its retention-interval model with OR-Tools CP-SAT;
//! Gurobi/OR-Tools are unavailable here, so this module provides the CP
//! substrate the reproduction runs on (see DESIGN.md "Substitutions").
//! It is a classic propagate-and-branch solver:
//!
//! * **Variables** hold finite integer domains represented as a shared
//!   sorted value array plus trailed `[lo, hi]` index bounds — bounds
//!   consistency only, which keeps trailing O(1) per change and is the
//!   right trade-off for scheduling models (Booleans are 2-value
//!   domains).
//! * **Propagators** (constraints) are stored in an enum (static
//!   dispatch): `LinearLe` (Σ cᵢ·xᵢ ≤ rhs, general integer coefficients),
//!   `LeOffset` / conditional `LeOffset` (x + c ≤ y, optionally guarded
//!   by a Boolean — interval validity), `CumulativeTimetable` (renewable
//!   resource / the paper's memory constraint (4)), `Cover` (the
//!   reservoir-style precedence constraint (5): an active start must be
//!   covered by an active producer interval), `AllDifferent`
//!   (constraint (6), used only by the unstaged model), and
//!   `Disjunctive` (a redundant unary-resource constraint over
//!   presolve-detected heavy cliques of the cumulative — see
//!   `disjunctive.rs`).
//! * **Propagation** runs on a persistent, event-driven engine
//!   (`engine::PropagationEngine`): typed lower-bound / upper-bound / fixed domain
//!   events with per-event watch lists (a propagator wakes only on the
//!   bounds it actually reads), a two-tier priority queue that drains
//!   cheap propagators to fixpoint before running `Cumulative`, and
//!   incremental `Cumulative` state (a cached timetable profile of
//!   compulsory parts, updated from events and re-synchronised on
//!   backtrack) so the profile is never rebuilt from scratch inside the
//!   search loop. The profile structure is selectable
//!   ([`ProfileMode`]): a sparse lazy segment tree (`segtree.rs`,
//!   O(log H) per part move/query — the large-graph default) or the
//!   linear diff-map step profile retained as the A/B oracle.
//! * **Search** comes in two strategies (see [`SearchStrategy`]). The
//!   *chronological* baseline is DFS with first-unfixed variable
//!   selection via a trailed pointer over a caller-supplied branch
//!   order, min-value-first branching (`x = min` / `x ≥ min+1`), and
//!   branch-and-bound on a linear objective implemented as one
//!   persistent propagator whose rhs tightens in place; backtracking
//!   re-enqueues only the propagators watching undone variables plus
//!   the objective. The *learned* strategy is conflict-driven
//!   (`learn.rs`): every pruning and failure carries an explanation —
//!   a conjunction of bound predicates ([`Lit`]) — which 1UIP conflict
//!   analysis resolves into learned no-goods propagated by watched
//!   literals, with VSIDS activity branching, solution-phase value
//!   saving, and Luby restarts that keep learned state. Both
//!   strategies are exact and report identical optima; learned search
//!   reaches them in fewer branch decisions because no-goods prune
//!   symmetric retention-interval orderings presolve cannot remove.
//!
//! The engine is deliberately small but complete: every solution it emits
//! is checked against all constraints (`Model::check`), and the MOCCASIN
//! layer re-validates each extracted sequence against the Appendix-A.3
//! evaluator, so no solver bug can silently corrupt reported numbers.

mod disjunctive;
mod domain;
mod engine;
mod learn;
mod propagators;
mod search;
mod segtree;

pub use disjunctive::DisjItem;
pub use domain::{event, Domain, DomainEvent, Lit, VarId};
pub use engine::{FilteringMode, ProfileMode, SolveCtx};
pub use propagators::{CumItem, Propagator};
pub use search::{SearchMode, SearchResult, SearchStats, SearchStrategy, Solver, Status};

use std::sync::Arc;

/// A CP model: variables + constraints. Build once, solve with
/// [`Solver`].
pub struct Model {
    pub(crate) domains: Vec<Domain>,
    pub(crate) props: Vec<Propagator>,
    /// var -> (propagator index, event mask) pairs: which propagators
    /// watch this variable and which [`event`] kinds wake them.
    pub(crate) watches: Vec<Vec<(u32, u8)>>,
}

impl Default for Model {
    fn default() -> Self {
        Self::new()
    }
}

impl Model {
    /// Empty model.
    pub fn new() -> Self {
        Model { domains: Vec::new(), props: Vec::new(), watches: Vec::new() }
    }

    /// New variable over an explicit (strictly increasing) value set.
    pub fn new_var_values(&mut self, values: Arc<Vec<i64>>) -> VarId {
        assert!(!values.is_empty(), "empty domain");
        debug_assert!(values.windows(2).all(|w| w[0] < w[1]), "values must be sorted/unique");
        let id = VarId(self.domains.len() as u32);
        self.domains.push(Domain::new(values));
        self.watches.push(Vec::new());
        id
    }

    /// New variable over the sorted distinct values
    /// `arena[off .. off + len]` — a window of a flat value arena shared
    /// by many variables (see [`Domain::new_arena`]).
    pub fn new_var_arena(&mut self, arena: &Arc<Vec<i64>>, off: usize, len: usize) -> VarId {
        let id = VarId(self.domains.len() as u32);
        self.domains.push(Domain::new_arena(Arc::clone(arena), off, len));
        self.watches.push(Vec::new());
        id
    }

    /// New variable over the contiguous range `[lb, ub]`.
    pub fn new_var(&mut self, lb: i64, ub: i64) -> VarId {
        assert!(lb <= ub);
        let id = VarId(self.domains.len() as u32);
        self.domains.push(Domain::new_range(lb, ub));
        self.watches.push(Vec::new());
        id
    }

    /// New Boolean variable (domain {0, 1}).
    pub fn new_bool(&mut self) -> VarId {
        self.new_var(0, 1)
    }

    /// Number of variables.
    pub fn num_vars(&self) -> usize {
        self.domains.len()
    }

    /// Number of constraints.
    pub fn num_constraints(&self) -> usize {
        self.props.len()
    }

    /// Total size of all variable domains — the presolve layer's
    /// `domain_shrink_pct` metric compares this between the raw and the
    /// compacted model.
    pub fn domain_size_sum(&self) -> u64 {
        self.domains.iter().map(|d| d.size() as u64).sum()
    }

    /// Fix a variable at model-build time.
    pub fn fix(&mut self, x: VarId, v: i64) {
        let d = &mut self.domains[x.0 as usize];
        assert!(d.contains(v), "fix({x:?}, {v}) outside domain");
        d.assign(v);
    }

    fn push_prop(&mut self, p: Propagator) -> u32 {
        let idx = self.props.len() as u32;
        for (v, mask) in p.watch_masks() {
            self.watches[v.0 as usize].push((idx, mask));
        }
        self.props.push(p);
        idx
    }

    /// Σ cᵢ·xᵢ ≤ rhs.
    pub fn linear_le(&mut self, terms: Vec<(i64, VarId)>, rhs: i64) {
        self.push_prop(Propagator::LinearLe { terms, rhs });
    }

    /// Σ cᵢ·xᵢ ≥ rhs (encoded as the negated ≤).
    pub fn linear_ge(&mut self, terms: Vec<(i64, VarId)>, rhs: i64) {
        let neg = terms.into_iter().map(|(c, v)| (-c, v)).collect();
        self.linear_le(neg, -rhs);
    }

    /// x + c ≤ y.
    pub fn le_offset(&mut self, x: VarId, c: i64, y: VarId) {
        self.push_prop(Propagator::LeOffset { b: None, x, c, y });
    }

    /// b = 1 → x + c ≤ y.
    pub fn cond_le_offset(&mut self, b: VarId, x: VarId, c: i64, y: VarId) {
        self.push_prop(Propagator::LeOffset { b: Some(b), x, c, y });
    }

    /// b1 = 1 → b2 = 1.
    pub fn implies(&mut self, b1: VarId, b2: VarId) {
        // b1 <= b2
        self.linear_le(vec![(1, b1), (-1, b2)], 0);
    }

    /// Renewable-resource constraint: at every time point, the demands of
    /// the active intervals covering it sum to ≤ `cap` (paper constraint
    /// (4), CP-SAT's `AddCumulative`).
    pub fn cumulative(&mut self, items: Vec<CumItem>, cap: i64) {
        self.push_prop(Propagator::Cumulative { items, cap });
    }

    /// Unary-resource (disjunctive) constraint over a presolve-detected
    /// heavy clique: active intervals are pairwise disjoint. Redundant
    /// with the [`Model::cumulative`] constraint it was detected in
    /// (every pair of members exceeds its capacity), but propagates
    /// order information the timetable cannot see; gated at propagation
    /// time by `SearchStrategy::disjunctive`.
    pub fn disjunctive(&mut self, items: Vec<DisjItem>) {
        self.push_prop(Propagator::Disjunctive { items });
    }

    /// Reservoir-style precedence (paper constraint (5), CP-SAT's
    /// `AddReservoirConstraintWithActive` specialisation): whenever
    /// `active` = 1, some candidate `(a_j, s_j, e_j)` must satisfy
    /// `s_j + 1 ≤ start ≤ e_j` with `a_j = 1`. The candidate list is a
    /// shared slice so covers of the same producer reuse one allocation.
    pub fn cover(
        &mut self,
        active: VarId,
        start: VarId,
        candidates: Arc<[(VarId, VarId, VarId)]>,
    ) {
        self.cover_multi(Arc::from(vec![(active, start)]), candidates);
    }

    /// Multi-target cover: one propagator enforcing the
    /// [`Model::cover`] condition for *every* `(active, start)` target
    /// against one shared candidate list — the presolve compaction that
    /// replaces the per-consumer-copy cover clones with a single
    /// propagator per precedence edge.
    pub fn cover_multi(
        &mut self,
        targets: Arc<[(VarId, VarId)]>,
        candidates: Arc<[(VarId, VarId, VarId)]>,
    ) {
        self.push_prop(Propagator::Cover { targets, candidates });
    }

    /// All variables take pairwise distinct values (paper constraint (6);
    /// only needed by the unstaged model).
    pub fn all_different(&mut self, vars: Vec<VarId>) {
        self.push_prop(Propagator::AllDifferent { vars });
    }

    /// Check a full assignment against every constraint (used to verify
    /// emitted solutions; `None` = satisfied).
    pub fn check(&self, assignment: &[i64]) -> Option<usize> {
        self.props.iter().position(|p| !p.is_satisfied(assignment))
    }
}

#[cfg(test)]
mod tests;
