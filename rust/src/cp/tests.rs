//! Engine-level integration tests on problems with known answers.

use super::engine::PropagationEngine;
use super::*;
use crate::util::Deadline;
use std::sync::Arc;
use std::time::Duration;

fn all_vars(m: &Model) -> Vec<VarId> {
    (0..m.num_vars() as u32).map(VarId).collect()
}

#[test]
fn satisfaction_simple() {
    // x + y <= 4, x >= 3 → first solution x=3, y in {0,1}
    let mut m = Model::new();
    let x = m.new_var(0, 9);
    let y = m.new_var(0, 9);
    m.linear_le(vec![(1, x), (1, y)], 4);
    m.linear_ge(vec![(1, x)], 3);
    let s = Solver { first_solution: true, ..Default::default() };
    let r = s.solve(&m, &[], &all_vars(&m), |_, _| {});
    assert!(r.found());
    let (a, _) = r.best.unwrap();
    assert!(a[0] >= 3 && a[0] + a[1] <= 4);
}

#[test]
fn infeasible_detected() {
    let mut m = Model::new();
    let x = m.new_var(0, 3);
    m.linear_ge(vec![(1, x)], 10);
    let s = Solver::default();
    let r = s.solve(&m, &[], &all_vars(&m), |_, _| {});
    assert_eq!(r.status, Status::Infeasible);
}

#[test]
fn optimize_knapsack_like() {
    // maximize 5a + 4b + 3c with 2a + 3b + c <= 4 over Booleans
    // = minimize -(...). Optimal: a=1, c=1 (value 8), b=0.
    let mut m = Model::new();
    let a = m.new_bool();
    let b = m.new_bool();
    let c = m.new_bool();
    m.linear_le(vec![(2, a), (3, b), (1, c)], 4);
    let s = Solver::default();
    let r = s.solve(&m, &[(-5, a), (-4, b), (-3, c)], &all_vars(&m), |_, _| {});
    assert_eq!(r.status, Status::Optimal);
    let (sol, obj) = r.best.unwrap();
    assert_eq!(obj, -8);
    assert_eq!((sol[0], sol[1], sol[2]), (1, 0, 1));
}

#[test]
fn objective_bound_prunes_and_callback_improves() {
    // minimize x subject to x >= 2 after propagation through y
    let mut m = Model::new();
    let x = m.new_var(0, 50);
    let y = m.new_var(10, 20);
    // y - x <= 8  →  x >= y - 8 >= 2
    m.linear_le(vec![(1, y), (-1, x)], 8);
    let s = Solver::default();
    let mut seen = Vec::new();
    let r = s.solve(&m, &[(1, x)], &all_vars(&m), |_, o| seen.push(o));
    assert_eq!(r.status, Status::Optimal);
    assert_eq!(r.best.unwrap().1, 2);
    // objective values must be strictly improving
    assert!(seen.windows(2).all(|w| w[1] < w[0]));
    assert_eq!(*seen.last().unwrap(), 2);
}

#[test]
fn cumulative_scheduling_tiny() {
    // 3 unit-demand intervals of length 2 on capacity 1, horizon [0,9]:
    // must be pairwise disjoint.
    let mut m = Model::new();
    let mut items = Vec::new();
    let mut vars = Vec::new();
    for _ in 0..3 {
        let a = m.new_bool();
        m.fix(a, 1);
        let s = m.new_var(0, 9);
        let e = m.new_var(0, 9);
        m.le_offset(s, 1, e); // length >= 2 (end inclusive)
        m.le_offset(e, -9, s); // end - s <= ... keep simple: e <= s+9 always true
        items.push(CumItem { active: a, start: s, end: e, demand: 1 });
        vars.push((s, e));
    }
    m.cumulative(items.clone(), 1);
    let s = Solver { first_solution: true, ..Default::default() };
    let r = s.solve(&m, &[], &all_vars(&m), |_, _| {});
    assert!(r.found());
    let (sol, _) = r.best.unwrap();
    // verify disjoint
    for i in 0..3 {
        for j in i + 1..3 {
            let (si, ei) = (sol[vars[i].0 .0 as usize], sol[vars[i].1 .0 as usize]);
            let (sj, ej) = (sol[vars[j].0 .0 as usize], sol[vars[j].1 .0 as usize]);
            assert!(ei < sj || ej < si, "intervals overlap: [{si},{ei}] [{sj},{ej}]");
        }
    }
}

#[test]
fn cover_requires_producer_interval() {
    // consumer starts at t in [1,5]; producer interval (a,s,e) with s
    // fixed 0, e in [0,5]; consumer active → e >= t.
    let mut m = Model::new();
    let ca = m.new_bool();
    m.fix(ca, 1);
    let ct = m.new_var(3, 5);
    let pa = m.new_bool();
    let ps = m.new_var(0, 0);
    let pe = m.new_var(0, 5);
    m.cover(ca, ct, Arc::from(vec![(pa, ps, pe)]));
    let s = Solver { first_solution: true, ..Default::default() };
    let r = s.solve(&m, &[], &all_vars(&m), |_, _| {});
    assert!(r.found());
    let (sol, _) = r.best.unwrap();
    assert_eq!(sol[pa.0 as usize], 1);
    assert!(sol[pe.0 as usize] >= sol[ct.0 as usize]);
}

#[test]
fn all_different_permutation() {
    let mut m = Model::new();
    let vars: Vec<VarId> = (0..4).map(|_| m.new_var(0, 3)).collect();
    m.all_different(vars.clone());
    // force descending-ish via linear constraints: x0 >= 2, x1 >= 2
    m.linear_ge(vec![(1, vars[0])], 2);
    m.linear_ge(vec![(1, vars[1])], 2);
    let s = Solver { first_solution: true, ..Default::default() };
    let empty_obj =
        all_vars(&m).iter().map(|&v| (0i64, v)).collect::<Vec<_>>()[..0].to_vec();
    let r = s.solve(&m, &empty_obj, &all_vars(&m), |_, _| {});
    assert!(r.found());
    let (sol, _) = r.best.unwrap();
    let mut vals: Vec<i64> = vars.iter().map(|v| sol[v.0 as usize]).collect();
    assert!(vals[0] >= 2 && vals[1] >= 2);
    vals.sort_unstable();
    assert_eq!(vals, vec![0, 1, 2, 3]);
}

#[test]
fn node_limit_reports_unknown_or_feasible() {
    // a problem big enough not to finish in 1 node
    let mut m = Model::new();
    let vars: Vec<VarId> = (0..20).map(|_| m.new_var(0, 9)).collect();
    m.all_different(vars[..10].to_vec());
    let s = Solver { node_limit: 1, ..Default::default() };
    let r = s.solve(&m, &[(1, vars[0])], &all_vars(&m), |_, _| {});
    assert!(matches!(r.status, Status::Unknown | Status::Feasible));
}

#[test]
fn deadline_zero_stops_quickly() {
    let mut m = Model::new();
    let vars: Vec<VarId> = (0..30).map(|_| m.new_var(0, 29)).collect();
    m.all_different(vars.clone());
    let s = Solver {
        deadline: Deadline::after(Duration::from_millis(0)),
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let _ = s.solve(&m, &[(1, vars[0])], &all_vars(&m), |_, _| {});
    assert!(t0.elapsed() < Duration::from_secs(2));
}

#[test]
fn implies_propagates() {
    let mut m = Model::new();
    let b1 = m.new_bool();
    let b2 = m.new_bool();
    m.implies(b1, b2);
    m.fix(b1, 1);
    let s = Solver { first_solution: true, ..Default::default() };
    let r = s.solve(&m, &[], &all_vars(&m), |_, _| {});
    let (sol, _) = r.best.unwrap();
    assert_eq!(sol[b2.0 as usize], 1);
}

#[test]
fn check_rejects_violating_assignment() {
    let mut m = Model::new();
    let x = m.new_var(0, 5);
    let y = m.new_var(0, 5);
    m.le_offset(x, 1, y);
    assert_eq!(m.check(&[2, 3]), None);
    assert_eq!(m.check(&[3, 3]), Some(0));
}

#[test]
fn variable_counts_reported() {
    let mut m = Model::new();
    let _ = m.new_var(0, 5);
    let _ = m.new_bool();
    m.linear_le(vec![], 0);
    assert_eq!(m.num_vars(), 2);
    assert_eq!(m.num_constraints(), 1);
}

/// A small cumulative + precedence minimization instance exercising
/// every engine path: two-tier queue, incremental profile, backtrack
/// resync, persistent objective.
fn scheduling_model() -> (Model, Vec<(i64, VarId)>, Vec<VarId>) {
    let mut m = Model::new();
    let mut items = Vec::new();
    let mut starts = Vec::new();
    let mut ends = Vec::new();
    for _ in 0..4 {
        let a = m.new_bool();
        m.fix(a, 1);
        let s = m.new_var(0, 11);
        let e = m.new_var(0, 11);
        m.le_offset(s, 1, e); // length >= 2
        items.push(CumItem { active: a, start: s, end: e, demand: 1 });
        starts.push(s);
        ends.push(e);
    }
    // loose precedences so Cover-free models still mix binary + heavy
    // propagators
    m.le_offset(starts[0], 0, starts[2]);
    m.le_offset(starts[1], 0, starts[3]);
    m.cumulative(items, 2);
    // minimize the makespan proxy: sum of ends
    let objective: Vec<(i64, VarId)> = ends.iter().map(|&e| (1, e)).collect();
    let bo = all_vars(&m);
    (m, objective, bo)
}

#[test]
fn engine_matches_naive_on_cumulative_optimization() {
    let (m, obj, bo) = scheduling_model();
    let ev = Solver::default().solve(&m, &obj, &bo, |_, _| {});
    let na = Solver { naive: true, ..Default::default() }.solve(&m, &obj, &bo, |_, _| {});
    assert_eq!(ev.status, Status::Optimal);
    assert_eq!(na.status, Status::Optimal);
    assert_eq!(
        ev.best.as_ref().unwrap().1,
        na.best.as_ref().unwrap().1,
        "engines disagree on the optimum"
    );
    // confluence: both engines explore the identical tree
    assert_eq!(ev.stats.nodes, na.stats.nodes, "search trees diverged");
}

#[test]
fn engine_reports_event_counters() {
    let (m, obj, bo) = scheduling_model();
    let r = Solver::default().solve(&m, &obj, &bo, |_, _| {});
    assert_eq!(r.status, Status::Optimal);
    assert!(r.stats.events_posted > 0, "no events recorded");
    assert!(
        r.stats.wakeups_skipped > 0,
        "event filtering never suppressed a wakeup (masks too coarse?)"
    );
    assert!(r.stats.cum_rebuilds > 0, "cumulative profile never flattened");
    // the naive reference must skip nothing
    let na = Solver { naive: true, ..Default::default() }.solve(&m, &obj, &bo, |_, _| {});
    assert_eq!(na.stats.wakeups_skipped, 0);
}

#[test]
fn engine_matches_naive_on_knapsack() {
    let mut m = Model::new();
    let a = m.new_bool();
    let b = m.new_bool();
    let c = m.new_bool();
    m.linear_le(vec![(2, a), (3, b), (1, c)], 4);
    let obj = vec![(-5, a), (-4, b), (-3, c)];
    let ev = Solver::default().solve(&m, &obj, &all_vars(&m), |_, _| {});
    let na = Solver { naive: true, ..Default::default() }.solve(&m, &obj, &all_vars(&m), |_, _| {});
    assert_eq!(ev.status, Status::Optimal);
    assert_eq!(ev.best.unwrap().1, -8);
    assert_eq!(na.best.unwrap().1, -8);
}

#[test]
fn learned_matches_chronological_on_scheduling() {
    let (m, obj, bo) = scheduling_model();
    let ch = Solver::default().solve(&m, &obj, &bo, |_, _| {});
    let ln = Solver { strategy: SearchStrategy::learned(), ..Default::default() }
        .solve(&m, &obj, &bo, |_, _| {});
    assert_eq!(ch.status, Status::Optimal);
    assert_eq!(ln.status, Status::Optimal);
    assert_eq!(
        ch.best.as_ref().unwrap().1,
        ln.best.as_ref().unwrap().1,
        "strategies disagree on the optimum"
    );
    assert!(ln.stats.nogoods_learned > 0, "learned search never learned");
}

#[test]
fn learned_finds_knapsack_optimum() {
    let mut m = Model::new();
    let a = m.new_bool();
    let b = m.new_bool();
    let c = m.new_bool();
    m.linear_le(vec![(2, a), (3, b), (1, c)], 4);
    let obj = vec![(-5, a), (-4, b), (-3, c)];
    let r = Solver { strategy: SearchStrategy::learned(), ..Default::default() }
        .solve(&m, &obj, &all_vars(&m), |_, _| {});
    assert_eq!(r.status, Status::Optimal);
    assert_eq!(r.best.unwrap().1, -8);
}

#[test]
fn learned_detects_infeasible() {
    let mut m = Model::new();
    let x = m.new_var(0, 3);
    m.linear_ge(vec![(1, x)], 10);
    let r = Solver { strategy: SearchStrategy::learned(), ..Default::default() }
        .solve(&m, &[], &all_vars(&m), |_, _| {});
    assert_eq!(r.status, Status::Infeasible);
}

/// The watched-literal invariant across backtracking: a learned no-good
/// whose watches moved during one descent must still propagate on a
/// later descent that reaches its literals in a different order —
/// without any watch maintenance on undo (undoing only relaxes bounds,
/// which never turns a watched non-true literal true).
#[test]
fn nogood_watches_survive_backtrack() {
    let mut m = Model::new();
    let x = m.new_var(0, 5);
    let y = m.new_var(0, 5);
    let z = m.new_var(0, 5);
    let mut ctx = SolveCtx::default();
    let mut eng =
        PropagationEngine::new(&m, &[], false, true, &SearchStrategy::learned(), &mut ctx);
    // forbid x ≥ 3 ∧ y ≥ 2 ∧ z ≥ 4
    eng.ng.add(vec![Lit::geq(x, 3), Lit::geq(y, 2), Lit::geq(z, 4)]);
    assert!(eng.fixpoint(&m).is_ok(), "nothing entailed yet");
    // first descent: x then y → the no-good must assert z ≤ 3
    assert!(eng.decide_lit(&m, Lit::geq(x, 3)).is_ok());
    assert!(eng.decide_lit(&m, Lit::geq(y, 2)).is_ok());
    assert_eq!(eng.doms.max(z), 3, "no-good must prune z");
    assert_eq!(eng.stats.nogoods_pruned, 1);
    // backtrack to the root: bounds relax, watches stay put
    eng.backjump_to(0);
    assert_eq!(eng.doms.max(z), 5);
    assert_eq!(eng.doms.max(y), 5);
    // second descent in a different order: z then x → y ≤ 1
    assert!(eng.decide_lit(&m, Lit::geq(z, 4)).is_ok());
    assert!(eng.decide_lit(&m, Lit::geq(x, 3)).is_ok());
    assert_eq!(eng.doms.max(y), 1, "watches must keep firing after backtrack");
    assert_eq!(eng.stats.nogoods_pruned, 2);
}

/// Regression (distilled from the PR-5 fuzz divergence): an optional
/// item whose *fixed* placement is degenerate (start beyond end) still
/// reaches the fixed-placement overload probe, whose window `[s, e]`
/// has `s > e`. `ProfileView::first_over` must probe `load(s)` for the
/// degenerate window in both profile structures, or the linear and the
/// segment-tree engines diverge on which branch deactivates the item.
#[test]
fn regression_degenerate_window_load_probe() {
    let build = || {
        let mut m = Model::new();
        let a0 = m.new_bool();
        m.fix(a0, 1);
        let s0 = m.new_var(4, 4);
        let e0 = m.new_var(6, 6);
        let a1 = m.new_bool();
        let s1 = m.new_var(5, 5);
        let e1 = m.new_var(3, 3);
        let items = vec![
            CumItem { active: a0, start: s0, end: e0, demand: 1 },
            CumItem { active: a1, start: s1, end: e1, demand: 1 },
        ];
        m.cumulative(items, 1);
        (m, a1)
    };
    let mut results = Vec::new();
    for profile in [ProfileMode::Linear, ProfileMode::SegTree] {
        let (m, a1) = build();
        let s = Solver {
            strategy: SearchStrategy::chronological().with_profile(profile),
            ..Default::default()
        };
        let r = s.solve(&m, &[], &all_vars(&m), |_, _| {});
        assert!(r.found(), "feasible with the degenerate item deactivated");
        let (sol, _) = r.best.as_ref().unwrap();
        assert_eq!(sol[a1.0 as usize], 0, "degenerate placement must deactivate");
        results.push((r.status, r.stats.nodes));
    }
    assert_eq!(results[0], results[1], "profile structures diverged");
}

/// Regression (distilled from the PR-4 fuzz divergence): an infeasible
/// packing whose refutation cascades conflicts with explanations lying
/// entirely below the failing decision level — 1UIP analysis must
/// backjump through them without losing the infeasibility proof. All
/// three engines must agree.
#[test]
fn regression_all_lower_level_conflict() {
    // three mandatory length-3 unit-demand intervals on capacity 1 need
    // 9 disjoint slots; the horizon [0, 7] offers 8 → infeasible
    let mut m = Model::new();
    let mut items = Vec::new();
    for _ in 0..3 {
        let a = m.new_bool();
        m.fix(a, 1);
        let s = m.new_var(0, 7);
        let e = m.new_var(0, 7);
        m.le_offset(s, 2, e); // length >= 3
        items.push(CumItem { active: a, start: s, end: e, demand: 1 });
    }
    m.cumulative(items, 1);
    let ch = Solver::default().solve(&m, &[], &all_vars(&m), |_, _| {});
    let na = Solver { naive: true, ..Default::default() }.solve(&m, &[], &all_vars(&m), |_, _| {});
    let ln = Solver { strategy: SearchStrategy::learned(), ..Default::default() }
        .solve(&m, &[], &all_vars(&m), |_, _| {});
    assert_eq!(ch.status, Status::Infeasible);
    assert_eq!(na.status, Status::Infeasible);
    assert_eq!(ln.status, Status::Infeasible);
    assert!(ln.stats.conflicts > 0, "refutation must be conflict-driven");
}

/// The disjunctive propagator is redundant strengthening: solving a
/// heavy-clique model with it on and off must agree on status and
/// optimum, and the on-side must actually detect the clique. Runs under
/// both search strategies (the learned one also exercises the
/// explanation-soundness audit on disjunctive explanations).
#[test]
fn disjunctive_knob_preserves_optimum() {
    let build = || {
        let mut m = Model::new();
        let mut items = Vec::new();
        let mut ends = Vec::new();
        for _ in 0..3 {
            let a = m.new_bool();
            m.fix(a, 1);
            let s = m.new_var(0, 11);
            let e = m.new_var(0, 11);
            m.le_offset(s, 1, e); // length >= 2
            items.push(CumItem { active: a, start: s, end: e, demand: 3 });
            ends.push(e);
        }
        // cap 4 < 2·3: all three demands are heavy → pairwise disjoint
        let clique = crate::presolve::detect_serialized_clique(&items, 4);
        assert_eq!(clique.len(), 3);
        m.cumulative(items, 4);
        m.disjunctive(clique);
        let obj: Vec<(i64, VarId)> = ends.iter().map(|&e| (1, e)).collect();
        (m, obj)
    };
    for (i, base) in
        [SearchStrategy::chronological(), SearchStrategy::learned()].into_iter().enumerate()
    {
        let (m, obj) = build();
        let on = Solver { strategy: base.clone().with_disjunctive(true), ..Default::default() }
            .solve(&m, &obj, &all_vars(&m), |_, _| {});
        let (m2, obj2) = build();
        let off = Solver { strategy: base.with_disjunctive(false), ..Default::default() }
            .solve(&m2, &obj2, &all_vars(&m2), |_, _| {});
        assert_eq!(on.status, Status::Optimal);
        assert_eq!(off.status, Status::Optimal);
        assert_eq!(on.best.as_ref().unwrap().1, off.best.as_ref().unwrap().1);
        assert_eq!(on.stats.disj_pairs_detected, 3, "3 heavy items = 3 pairs");
        if i == 0 {
            // chronological DFS with fixed branch order: monotone
            // filtering can only shrink the tree (learned search is
            // exempt — restarts and VSIDS make node counts non-monotone)
            assert!(on.stats.nodes <= off.stats.nodes, "filtering must not grow the tree");
        }
    }
}

/// Edge-finding is exact strengthening over the timetable: equal status
/// and optimum, never a larger tree (on this instance), and the
/// learned run audits every EF explanation conjunction.
#[test]
fn edge_finding_knob_preserves_optimum() {
    let (m, obj, bo) = scheduling_model();
    for base in [SearchStrategy::chronological(), SearchStrategy::learned()] {
        let tt = Solver {
            strategy: base.clone().with_filtering(FilteringMode::Timetable),
            ..Default::default()
        }
        .solve(&m, &obj, &bo, |_, _| {});
        let ef = Solver {
            strategy: base.clone().with_filtering(FilteringMode::EdgeFinding),
            ..Default::default()
        }
        .solve(&m, &obj, &bo, |_, _| {});
        assert_eq!(tt.status, Status::Optimal);
        assert_eq!(ef.status, Status::Optimal);
        assert_eq!(tt.best.as_ref().unwrap().1, ef.best.as_ref().unwrap().1);
    }
}

/// The data-oriented memory pass, held as an exact equality: once a
/// [`SolveCtx`] is warmed up, repeat solves of the same model — the LNS
/// window re-solve pattern — perform **zero** heap allocations. The
/// crate's test build runs under `util::alloc_count::CountingAlloc`
/// (see `lib.rs`), so any stray `clone()`/`vec![]`/rebuild sneaking
/// back into the kernel hot path fails this test with an exact count.
///
/// Scope: chronological search (the LNS window default) with the
/// SegTree profile (the default; the Linear A/B oracle's `BTreeMap`
/// frees its nodes on `clear`, so it can never be zero-alloc — see
/// `CumState::reset`). Learned search is exempt by design: learned
/// no-good literal vectors intentionally stay freshly allocated because
/// `NoGoodDb` keeps them alive across the solve.
#[test]
fn reused_ctx_steady_state_is_allocation_free() {
    let (m, obj, bo) = scheduling_model();
    let solver = Solver::default();
    let mut ctx = SolveCtx::default();
    // two warm-up solves: the first grows every pooled buffer, the
    // second catches capacity ratchets (e.g. a Vec that doubled late)
    for _ in 0..2 {
        let r = solver.solve_with_ctx(&m, &obj, &bo, |_, _| {}, &mut ctx);
        assert_eq!(r.status, Status::Optimal);
        if let Some((v, _)) = r.best {
            ctx.recycle_solution(v);
        }
    }
    let before = crate::util::alloc_count::thread_allocations();
    let r = solver.solve_with_ctx(&m, &obj, &bo, |_, _| {}, &mut ctx);
    let after = crate::util::alloc_count::thread_allocations();
    assert_eq!(r.status, Status::Optimal);
    assert_eq!(
        after - before,
        0,
        "steady-state solve on a warmed SolveCtx allocated {} time(s)",
        after - before
    );
    if let Some((v, _)) = r.best {
        ctx.recycle_solution(v);
    }
}

/// Same steady-state discipline for an *infeasible* re-solve (the other
/// common LNS window outcome): no solution vector is produced and the
/// context still round-trips allocation-free.
#[test]
fn reused_ctx_infeasible_resolve_is_allocation_free() {
    let mut m = Model::new();
    let mut items = Vec::new();
    for _ in 0..3 {
        let a = m.new_bool();
        m.fix(a, 1);
        let s = m.new_var(0, 7);
        let e = m.new_var(0, 7);
        m.le_offset(s, 2, e); // length >= 3; 9 slots into 8 → infeasible
        items.push(CumItem { active: a, start: s, end: e, demand: 1 });
    }
    m.cumulative(items, 1);
    let bo = all_vars(&m);
    let solver = Solver::default();
    let mut ctx = SolveCtx::default();
    for _ in 0..2 {
        let r = solver.solve_with_ctx(&m, &[], &bo, |_, _| {}, &mut ctx);
        assert_eq!(r.status, Status::Infeasible);
    }
    let before = crate::util::alloc_count::thread_allocations();
    let r = solver.solve_with_ctx(&m, &[], &bo, |_, _| {}, &mut ctx);
    let after = crate::util::alloc_count::thread_allocations();
    assert_eq!(r.status, Status::Infeasible);
    assert_eq!(after - before, 0, "infeasible re-solve allocated");
}

#[test]
fn stats_merge_accumulates() {
    let mut a = SearchStats { nodes: 3, propagations: 10, events_posted: 7, ..Default::default() };
    let b = SearchStats {
        nodes: 2,
        conflicts: 1,
        wakeups_skipped: 4,
        cum_resyncs: 5,
        restarts: 2,
        nogoods_learned: 6,
        nogoods_pruned: 9,
        db_reductions: 1,
        ef_prunes: 11,
        disj_prunes: 12,
        disj_pairs_detected: 13,
        ..Default::default()
    };
    a.merge(&b);
    assert_eq!(a.nodes, 5);
    assert_eq!(a.conflicts, 1);
    assert_eq!(a.propagations, 10);
    assert_eq!(a.events_posted, 7);
    assert_eq!(a.wakeups_skipped, 4);
    assert_eq!(a.cum_resyncs, 5);
    assert_eq!(a.restarts, 2);
    assert_eq!(a.nogoods_learned, 6);
    assert_eq!(a.nogoods_pruned, 9);
    assert_eq!(a.db_reductions, 1);
    assert_eq!(a.ef_prunes, 11);
    assert_eq!(a.disj_prunes, 12);
    assert_eq!(a.disj_pairs_detected, 13);
}
